#!/usr/bin/env bash
# CI entry: collection health gate first (import errors surface as a
# clean failure instead of a half-run suite), then the tier-1 suite,
# then the serving perf smokes (BENCH_paged_kv.json tracks the paged
# KV cache's memory/throughput trajectory per PR).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

echo "== collection gate =="
python -m pytest --collect-only -q

echo "== tier-1 =="
python -m pytest -x -q

echo "== perf smoke =="
python benchmarks/paged_kv.py --smoke
python benchmarks/prefix_cache.py --smoke
python benchmarks/continuous_batching.py --smoke
python benchmarks/multi_replica.py --smoke
python benchmarks/combined_fabric.py --smoke
python benchmarks/multi_lora.py --smoke
python benchmarks/chaos.py --smoke
