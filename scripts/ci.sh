#!/usr/bin/env bash
# CI entry: static-analysis gate first (reprolint + gated typecheck —
# a lint finding fails CI before any test runs), then the collection
# health gate (import errors surface as a clean failure instead of a
# half-run suite), then the tier-1 suite — once plain and once with
# REPRO_SANITIZE=1 arming the shadow-state sanitizers (reprosan), so
# every allocator/registry/lifecycle invariant is cross-checked on the
# full suite — then the serving perf smokes (BENCH_paged_kv.json
# tracks the paged KV cache's memory/throughput trajectory per PR).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src:.${PYTHONPATH:+:$PYTHONPATH}"

echo "== lint gate (reprolint + typecheck) =="
python tools/analysis/reprolint.py
python tools/analysis/run_typecheck.py

echo "== collection gate =="
python -m pytest --collect-only -q

echo "== tier-1 =="
python -m pytest -x -q

echo "== tier-1 (REPRO_SANITIZE=1 shadow-state sanitizers) =="
REPRO_SANITIZE=1 python -m pytest -x -q

echo "== perf smoke =="
python benchmarks/paged_kv.py --smoke
# oversubscribed-pool gate: with the pool below worst-case demand,
# preemption (host swap / drop+re-prefill) must complete 100% of the
# trace bit-identically at >= 1.3x the preemption-free goodput — the
# assertions live inside the benchmark
python benchmarks/preemption.py --smoke
python benchmarks/prefix_cache.py --smoke
python benchmarks/continuous_batching.py --smoke
python benchmarks/multi_replica.py --smoke
python benchmarks/combined_fabric.py --smoke
# token-level co-scheduling gate: the combined fabric must retain
# >= 0.8x serve-only goodput (chunked prefill + SLO tick budgets defer
# train work off busy ticks) while round avg train loss still falls
python - <<'EOF'
import json
d = json.load(open("BENCH_combined_fabric.json"))
ratio = d["goodput_ratio_combined_vs_serve_only"]
losses = d["round_avg_loss"]
assert ratio >= 0.8, f"combined/serve-only goodput {ratio} < 0.8"
assert losses[-1] < losses[0], f"round avg loss not falling: {losses}"
print(f"co-scheduling gate: ratio={ratio} loss={losses[0]}->{losses[-1]}")
EOF
python benchmarks/multi_lora.py --smoke
REPRO_SANITIZE=1 python benchmarks/chaos.py --smoke
