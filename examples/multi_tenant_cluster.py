"""Paper-scale cluster simulation (16 replicas, bursty multi-tenant
trace): watch CoLLM's state machine, FL launcher, coordinator, and
subflow dispatcher work together — and compare against a baseline.

  PYTHONPATH=src python examples/multi_tenant_cluster.py --duration 900
"""
import argparse

from repro.runtime.experiment import ExperimentConfig, run_experiment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=900.0)
    ap.add_argument("--replicas", type=int, default=16)
    ap.add_argument("--scale", type=float, default=2.0)
    ap.add_argument("--baseline", default="dlora",
                    choices=["dlora", "shepherd", "peft", "rr"])
    args = ap.parse_args()

    print(f"== CoLLM on {args.replicas} replicas, "
          f"{args.duration:.0f}s x{args.scale:g} merged trace ==")
    c = run_experiment(ExperimentConfig(
        policy="collm", n_replicas=args.replicas,
        duration=args.duration, scale=args.scale, seed=0))
    print(f"  goodput      {c['goodput_tok_s']:9.0f} tok/s")
    print(f"  Q-goodput    {c['q_goodput']:9.0f}")
    print(f"  SLO rate     {c['slo_rate']:9.3f}")
    print(f"  utilization  {c['mean_util']:9.3f}")
    print(f"  FL rounds    {c['fl_rounds']:9d}  "
          f"(mean replica CE {c['mean_loss']:.3f})")
    print(f"  states at end {c['final_states']}")
    print(f"  overhead     {c['overhead_frac'] * 100:9.2f}%")

    b = run_experiment(ExperimentConfig(
        policy=args.baseline, n_replicas=args.replicas,
        duration=args.duration, scale=args.scale, seed=0))
    print(f"== {args.baseline} baseline ==")
    print(f"  goodput      {b['goodput_tok_s']:9.0f} tok/s   "
          f"(CoLLM {c['goodput_tok_s'] / max(b['goodput_tok_s'], 1):.2f}x)")
    print(f"  Q-goodput    {b['q_goodput']:9.0f}   "
          f"(CoLLM {c['q_goodput'] / max(b['q_goodput'], 1):.2f}x)")
    print(f"  SLO rate     {b['slo_rate']:9.3f}")
    print(f"  utilization  {b['mean_util']:9.3f}")


if __name__ == "__main__":
    main()
