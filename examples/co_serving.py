"""End-to-end CoLLM driver on a LIVE JAX replica (deliverable b):
a ~100M-class model serves a stream of generation requests through the
continuous-batching runtime while every decode tick co-runs the fused
``combined_step`` — LoRA fine-tuning + decoding in ONE XLA program over
shared base weights.  Response quality (1/CE on held-out requests)
improves in real time, reproducing the paper's continuous-adaptation
effect without a simulator.

  PYTHONPATH=src python examples/co_serving.py --steps 150
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset
from repro.runtime.serving_loop import ContinuousBatcher, GenRequest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--serve-batch", type=int, default=8)
    ap.add_argument("--train-batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    # ~100M-class reduced config: wider than the smoke default
    cfg = get_config(args.arch).scaled(
        n_layers=4, d_model=256, n_heads=8, d_ff=1024, vocab_size=2048)
    print(f"live co-serving on {cfg.name}: "
          f"{cfg.param_count() / 1e6:.0f}M params, LoRA rank "
          f"{cfg.lora.rank}")

    engine = make_engine(cfg, lr=5e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = model.init_lora(jax.random.key(1))
    opt = engine.optimizer.init(lora)
    domain = SyntheticDataset("code_alpaca", vocab_size=cfg.vocab_size,
                              seq_len=48, seed=0)
    held = [{k: jnp.asarray(v) for k, v in domain.batch(4).items()}
            for _ in range(4)]
    jit_eval = jax.jit(lambda p, l, b: model.forward_loss(p, l, b)[0])

    batcher = ContinuousBatcher(
        engine, params, lora, n_slots=args.serve_batch,
        max_seq=args.prompt_len + args.gen, prompt_pad=args.prompt_len,
        opt_state=opt)
    # enough queued requests to keep the slots busy for ~steps ticks
    n_req = args.serve_batch * (args.steps // max(args.gen - 1, 1) + 2)
    prompts = domain.sample_tokens(n_req)[:, :args.prompt_len]
    for i in range(n_req):
        batcher.submit(GenRequest(request_id=i,
                                  prompt=prompts[i].astype(np.int32),
                                  max_new_tokens=args.gen))

    t0 = time.time()
    print(f"{'step':>5s} {'train_loss':>11s} {'serve_quality':>14s} "
          f"{'tok/s':>8s}")
    for step in range(args.steps):
        # ONE XLA program per tick: decode a token for every active slot
        # AND run a LoRA training step over the shared base weights
        tb = {k: jnp.asarray(v)
              for k, v in domain.batch(args.train_batch).items()}
        batcher.step(train_batch=tb)
        if step % 25 == 0 or step == args.steps - 1:
            q = 1.0 / max(float(jit_eval(params, batcher.lora,
                                         held[step % 4])), 1e-6)
            rate = batcher.stats.generated_tokens / (time.time() - t0)
            loss = batcher.train_losses[-1] if batcher.train_losses \
                else float("nan")
            print(f"{step:5d} {loss:11.4f} {q:14.4f} {rate:8.1f}")
    s = batcher.stats
    print(f"served {s.finished} requests / {s.generated_tokens} tokens "
          f"while co-training {s.train_steps} fused steps — "
          f"model sharing in action")


if __name__ == "__main__":
    main()
