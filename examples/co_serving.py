"""End-to-end CoLLM driver on LIVE JAX replicas (deliverable b):
a ~100M-class model serves batched requests while the fused
``combined_step`` fine-tunes its LoRA adapter — response quality
(1/CE on held-out requests) improves in real time, reproducing the
paper's continuous-adaptation effect without a simulator.

  PYTHONPATH=src python examples/co_serving.py --steps 150
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--serve-batch", type=int, default=8)
    ap.add_argument("--train-batch", type=int, default=8)
    args = ap.parse_args()

    # ~100M-class reduced config: wider than the smoke default
    cfg = get_config(args.arch).scaled(
        n_layers=4, d_model=256, n_heads=8, d_ff=1024, vocab_size=2048)
    print(f"live co-serving on {cfg.name}: "
          f"{cfg.param_count() / 1e6:.0f}M params, LoRA rank "
          f"{cfg.lora.rank}")

    engine = make_engine(cfg, lr=5e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = model.init_lora(jax.random.key(1))
    opt = engine.optimizer.init(lora)
    domain = SyntheticDataset("code_alpaca", vocab_size=cfg.vocab_size,
                              seq_len=48, seed=0)
    held = [{k: jnp.asarray(v) for k, v in domain.batch(4).items()}
            for _ in range(4)]

    jit_combined = jax.jit(engine.combined_step, donate_argnums=(2, 4))
    jit_eval = jax.jit(lambda p, l, b: model.forward_loss(p, l, b)[0])

    B, S = args.serve_batch, 48
    caches = model.init_caches(B, S + args.steps)
    tok = jnp.ones((B, 1), jnp.int32)
    t0 = time.time()
    print(f"{'step':>5s} {'train_loss':>11s} {'serve_quality':>14s} "
          f"{'tok/s':>8s}")
    for step in range(args.steps):
        tb = {k: jnp.asarray(v)
              for k, v in domain.batch(args.train_batch).items()}
        # ONE XLA program: decode a token for the serving batch AND run
        # a LoRA training step over the shared base weights
        lora, opt, logits, caches, metrics = jit_combined(
            params, lora, opt, tb, caches, tok, jnp.int32(step))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        if step % 25 == 0 or step == args.steps - 1:
            q = 1.0 / max(float(jit_eval(params, lora,
                                         held[step % 4])), 1e-6)
            rate = B * (step + 1) / (time.time() - t0)
            print(f"{step:5d} {float(metrics['ce_loss']):11.4f} "
                  f"{q:14.4f} {rate:8.1f}")
    print("quality improved while serving — model sharing in action")


if __name__ == "__main__":
    main()
