"""Quickstart: build an assigned architecture, run a LoRA train step, a
prefill, and a decode step — the whole public API in 40 lines.

  PYTHONPATH=src python examples/quickstart.py --arch llama3-8b
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    args = ap.parse_args()

    cfg = get_config(args.arch).scaled()      # reduced config for CPU
    print(f"arch={args.arch} family={cfg.family.value} "
          f"full-size params={get_config(args.arch).param_count() / 1e9:.1f}B"
          f" (smoke model: {cfg.param_count() / 1e6:.1f}M)")

    engine = make_engine(cfg, lr=3e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = model.init_lora(jax.random.key(1))
    opt = engine.optimizer.init(lora)

    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size, seq_len=32)
    batch = {k: jnp.asarray(v) for k, v in data.batch(8).items()}
    if cfg.encoder_only:
        batch["embeds"] = jax.random.normal(jax.random.key(2),
                                            (8, 32, cfg.d_model))
    if cfg.family.value == "vlm":
        batch["vision"] = jnp.zeros((8, cfg.vision_tokens, cfg.d_model))

    # one LoRA training step (base weights frozen — the PEFT interface)
    lora, opt, metrics = jax.jit(engine.train_step)(params, lora, opt,
                                                    batch)
    print(f"train step: loss={float(metrics['ce_loss']):.4f} "
          f"grad_norm={float(metrics['grad_norm']):.3f}")

    if cfg.has_decode:
        prompt = {k: v for k, v in batch.items()
                  if k not in ("labels", "mask")}
        logits, caches = jax.jit(model.prefill)(params, lora, prompt)
        print(f"prefill: last-token logits {logits.shape}")
        dc = model.init_caches(8, 40)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        lg, dc = jax.jit(model.decode_step)(params, lora, dc, tok,
                                            jnp.int32(0))
        print(f"decode: logits {lg.shape} (KV/SSM caches updated)")
    else:
        print("encoder-only arch: serving = full-sequence classification")


if __name__ == "__main__":
    main()
