"""Multi-LoRA multi-tenant serving in ~50 lines: one continuous
batcher serves several tenants' LoRA adapters from an
``AdapterRegistry``, mixing tenants inside a single decode wave through
the batched segmented LoRA kernels.  The registry holds fewer device
slots than there are tenants, so residency rotates LRU-style under
refcounted pinning — and one tenant's weights are hot-swapped
mid-trace (the publish path) without perturbing any other tenant's
greedy stream.

  PYTHONPATH=src python examples/multi_tenant.py --tenants 4 --slots 3
"""
import argparse

import numpy as np

from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset
from repro.runtime.fabric import make_tenant_adapters
from repro.runtime.serving_loop import (
    AdapterRegistry, ContinuousBatcher, GenRequest,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--slots", type=int, default=3,
                    help="device adapter slots (< tenants forces LRU "
                         "rotation)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    args = ap.parse_args()

    import jax
    cfg = get_config(args.arch).scaled()
    engine = make_engine(cfg, lr=3e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    tenants = make_tenant_adapters(model, args.tenants, seed=1)
    registry = AdapterRegistry(model, capacity=args.slots)
    for t, tree in enumerate(tenants):
        registry.register(f"tenant{t}", tree)

    batcher = ContinuousBatcher(
        engine, params, tenants[0], n_slots=4,
        max_seq=args.prompt_len + args.gen, prompt_pad=args.prompt_len,
        adapters=registry)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=args.prompt_len, seed=0)
    prompts = data.sample_tokens(args.requests)[:, :args.prompt_len]
    reqs = [GenRequest(request_id=i, prompt=prompts[i],
                       max_new_tokens=args.gen,
                       adapter_id=f"tenant{i % args.tenants}")
            for i in range(args.requests)]

    half = args.requests // 2
    stats = batcher.run(reqs[:half])
    # hot-swap tenant1's weights mid-trace: the publish path rewrites
    # ONE device slot in place; every other tenant's stream is untouched
    registry.update("tenant1", tenants[-1], version=1)
    stats = batcher.run(reqs[half:])

    print(f"served {args.requests} requests across {args.tenants} "
          f"tenants on {args.slots} device slots: "
          f"{stats.generated_tokens} tokens")
    print(f"per-tenant requests: "
          f"{dict(sorted(stats.adapter_requests.items()))}")
    print(f"registry: {registry.hits} hits, {registry.loads} loads, "
          f"{registry.evictions} LRU evictions; resident now: "
          f"{list(registry.resident_ids())}")
    print(f"tenant1 republished at v{registry.version('tenant1')} "
          "mid-trace; other tenants' streams bit-identical throughout")


if __name__ == "__main__":
    main()
