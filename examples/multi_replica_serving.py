"""Live multi-replica serving fabric in ~40 lines: one
``ClusterController`` routes a bursty request stream across a pool of
``ContinuousBatcher``-backed replicas with placement-aware admission,
then one replica is killed mid-trace and its unfinished requests fail
over to the survivors — no request lost, greedy outputs unchanged.

  PYTHONPATH=src python examples/multi_replica_serving.py --replicas 3
"""
import argparse

import numpy as np

from repro.core.interfaces import Request
from repro.data.synthetic import SyntheticDataset
from repro.runtime.fabric import build_fabric


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--kill", default="r1",
                    help="replica to fail mid-trace ('' = no failure)")
    args = ap.parse_args()

    fabric, cfg = build_fabric(
        args.arch, args.replicas, n_slots=4,
        prompt_len=args.prompt_len, gen_tokens=args.gen,
        paged=True, block_size=8)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=args.prompt_len, seed=0)
    prompts = data.sample_tokens(args.requests)[:, :args.prompt_len]
    rng = np.random.default_rng(0)
    requests = [
        Request(request_id=i, stream_id=cfg.name,
                arrival=float(rng.uniform(0.0, 1.0)), deadline=1e9,
                tokens=int(rng.integers(2, args.gen + 1)),
                prompt=prompts[i].astype(np.int32))
        for i in range(args.requests)]

    failures = [(0.6, args.kill)] if args.kill else []
    summary = fabric.run(requests, failures=failures)
    c = summary["cluster"]
    done = sum(1 for r in requests if r.completed_at is not None)
    print(f"completed {done}/{args.requests} requests on "
          f"{len(fabric.replicas)} survivors "
          f"({'killed ' + args.kill + ' mid-trace' if args.kill else 'no failures'})")
    print(f"aggregate {c['throughput_sum_tok_s']:.0f} tok/s "
          f"({c['throughput_wall_tok_s']:.0f} on the shared device), "
          f"{c['generated_tokens']} tokens / {c['decode_steps']} steps")
    for rid, row in summary["replicas"].items():
        print(f"  {rid}: {row['finished']:3d} finished, "
              f"{row['throughput_tok_s']:8.1f} tok/s")
    d = summary["dispatchers"][cfg.name]
    print(f"dispatcher: {d['dispatched']} dispatched, "
          f"{d['affinity_routed']} affinity-routed, "
          f"{d['rebalanced']} rebalanced, {d['dropped']} dropped")


if __name__ == "__main__":
    main()
