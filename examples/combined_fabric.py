"""Live co-execution on the multi-replica fabric — the paper's headline
system end to end on real JAX execution.

Two live replicas share one frozen base model.  The launcher cohorts
them into an FL PEFT session; each fabric tick then advances BOTH
worlds at once on every replica:

  serving     the dispatcher routes the request stream by headroom and
              each ``pump_once`` decodes one token per active slot,
              reading the replica's PUBLISHED adapter snapshot;
  training    the same tick's fused ``combined_step`` takes one
              optimizer step on the replica's SHADOW adapter — one XLA
              program over shared base weights, so fine-tuning rides
              along without a second model copy.

Rounds never block the loop: the launcher polls ``round_progress`` and,
when the slowest member finishes, FedAvg-aggregates the shadows and
publishes the merged adapter to every member — serving output is
bit-identical to serve-only WITHIN a round and adapts at round
boundaries only.

Run:
  PYTHONPATH=src python examples/combined_fabric.py
"""
from repro.launch.serve import run_combined_fabric_serving


def main() -> None:
    out = run_combined_fabric_serving(
        "qwen1.5-0.5b", n_replicas=2, n_requests=12, prompt_len=16,
        gen_tokens=8, batch_size=4, rounds=2, steps_per_round=4,
        train_batch=4)
    c = out["cluster"]
    print(f"\nadapter versions coherent: "
          f"v{c['adapter_version_min']} == v{c['adapter_version_max']}")
    print("the same trace, serve-only, for comparison:")
    from repro.launch.serve import run_multi_replica_serving
    run_multi_replica_serving("qwen1.5-0.5b", n_replicas=2,
                              n_requests=12, prompt_len=16, gen_tokens=8,
                              batch_size=4)


if __name__ == "__main__":
    main()
