"""Federated LoRA fine-tuning across live replicas with heterogeneous
data (paper §4.2): FedAvg rounds over adapters, quality scores, early
stopping — on real JAX models, no simulator.

  PYTHONPATH=src python examples/federated_finetune.py --rounds 6
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.core.federated import FederatedSession, FLRoundResult
from repro.data.synthetic import SyntheticDataset, DOMAINS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=15)
    args = ap.parse_args()

    cfg = get_config("qwen1.5-0.5b").scaled()
    engine = make_engine(cfg, lr=5e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    global_adapter = model.init_lora(jax.random.key(1))
    # no donation: every client starts local training from the SAME
    # broadcast global adapter (donating would free it after client 0)
    jit_train = jax.jit(engine.train_step)
    jit_eval = jax.jit(lambda p, l, b: model.forward_loss(p, l, b)[0])

    clients = {}
    for i in range(args.clients):
        domain = DOMAINS[i % len(DOMAINS)]
        clients[f"r{i}"] = SyntheticDataset(
            domain, vocab_size=cfg.vocab_size, seq_len=32, seed=i)
        print(f"r{i}: local data domain = {domain}")

    held = {rid: {k: jnp.asarray(v) for k, v in ds.batch(8).items()}
            for rid, ds in clients.items()}
    sess = FederatedSession("qwen", list(clients), server="r0",
                            global_adapter=global_adapter)

    for rnd in range(args.rounds):
        results = []
        for rid, ds in clients.items():
            if rid not in sess.members:
                continue
            lora = sess.global_adapter            # broadcast (Eq. 5 in)
            opt = engine.optimizer.init(lora)
            loss = None
            for _ in range(args.local_steps):     # local training
                batch = {k: jnp.asarray(v) for k, v in ds.batch(8).items()}
                lora, opt, m = jit_train(params, lora, opt, batch)
                loss = float(m["ce_loss"])
            results.append(FLRoundResult(rid, lora, loss,
                                         samples=8 * args.local_steps))
        sess.aggregate(results)                    # FedAvg (Eq. 5)
        stopped = sess.early_stops(results)
        # cross-domain generalization of the aggregated adapter
        cross = np.mean([float(jit_eval(params, sess.global_adapter, b))
                         for b in held.values()])
        print(f"round {rnd}: avg_local_loss="
              f"{np.mean([r.local_loss for r in results]):.4f} "
              f"cross_domain_ce={cross:.4f} "
              f"quality={ {k: round(v, 2) for k, v in sess.quality.items()} }"
              + (f" early-stopped: {stopped}" if stopped else ""))
        if not sess.alive:
            print("cohort dissolved (early stopping)")
            break


if __name__ == "__main__":
    main()
