#!/usr/bin/env python
"""Gated typecheck runner for `make lint`.

Runs mypy (basic mode, pinned in mypy.ini) over the scoped targets when
mypy is importable; prints a skip notice and exits 0 when it is not.
The serving container does not bake mypy in, so the lint gate must not
hard-depend on it — same stub-or-gate pattern as the optional
accelerator deps.
"""
from __future__ import annotations

import importlib.util
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[2]
TARGETS = ["src/repro/core", "src/repro/runtime/paging.py"]


def main() -> int:
    if importlib.util.find_spec("mypy") is None:
        print("typecheck: mypy not installed in this environment; "
              "skipping (config pinned in mypy.ini)")
        return 0
    cmd = [sys.executable, "-m", "mypy",
           "--config-file", str(REPO / "mypy.ini"), *TARGETS]
    print("typecheck:", " ".join(cmd[2:]))
    return subprocess.call(cmd, cwd=REPO)


if __name__ == "__main__":
    sys.exit(main())
