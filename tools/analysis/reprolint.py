#!/usr/bin/env python
"""reprolint — repository-specific AST lint for the CoLLM serving stack.

Static rules over hazards this codebase maintains by hand (see
tools/analysis/README.md for the full catalogue and pragma format):

  JAX hazards
    RL001 host-sync     host-device sync (``.item()`` / ``float(...)`` /
                        ``np.asarray`` / ``jax.device_get`` of device
                        values) reachable from a per-token hot root
                        (``ContinuousBatcher.step``,
                        ``LiveReplica.pump_once``, ...)
    RL002 time-in-jit   impure ``time.*`` clock calls reachable from a
                        jit-traced function (baked in at trace time)
    RL003 static-args   ``jax.jit``/``pallas_call`` static arguments
                        that are unhashable/mutable (list/dict/set
                        displays, comprehensions, array constructors)
    RL004 donation      a buffer passed to a donating jit wrapper
                        (``donate_argnums``) and read again afterwards
                        instead of being rebound from the call's result

  architectural conformance
    RL101 replica-conformance   every public ``ReplicaHandle`` protocol
                                method implemented by BOTH SimReplica
                                and LiveReplica
    RL102 stats-coverage        every ``ServeStats`` field folded by
                                ``aggregate_serve_stats``
    RL103 request-threading     every ``GenRequest``/``Request`` field
                                actually consumed outside its dataclass
                                (dead fields = dropped threading)
    RL104 bench-registration    every benchmark writing ``BENCH_*.json``
                                registered in ``scripts/ci.sh``
    RL105 sanitizer-hooks       every public ``BlockAllocator`` method
                                that mutates allocator state calls its
                                ``BlockSanitizer`` hook (``self.san``) —
                                an unhooked mutator silently desyncs the
                                shadow mirror (use-after-free /
                                use-after-swap checks go blind)

Per-line allowlisting: ``# lint: <alias>-ok <reason>`` on any line of
the flagged statement suppresses that rule there; a pragma with no
reason is itself an error (RL000).  Conformance findings (RL10x) for
field definitions accept the pragma on the definition line.

Usage: ``python tools/analysis/reprolint.py [--root PATH]`` — prints
``path:line: RULE[alias] message`` per finding, exit 1 if any.
``lint_root(path)`` is the API the regression tests drive.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import os
import re
import sys
from typing import Any, Dict, Iterable, List, Optional, Set, Tuple

ALIAS = {
    "RL000": "pragma",
    "RL001": "host-sync",
    "RL002": "time-in-jit",
    "RL003": "static-args",
    "RL004": "donation",
    "RL101": "replica-conformance",
    "RL102": "stats-coverage",
    "RL103": "request-threading",
    "RL104": "bench-registration",
    "RL105": "sanitizer-hooks",
}

PRAGMA_RE = re.compile(r"#\s*lint:\s*([a-z][a-z0-9-]*)-ok(?:\s+(\S.*))?")

# per-token hot roots: everything reachable from these is a decode /
# pump hot path and must not host-sync without a pragma
HOT_ROOTS = (
    ("ContinuousBatcher", "step"),
    ("ContinuousBatcher", "run"),
    ("LiveReplica", "pump_once"),
    ("ServingFabric", "tick"),
    (None, "static_batch_serve"),
)

# module roots whose attribute calls never resolve to repo functions
_EXTERNAL_ROOTS = {
    "np", "numpy", "jnp", "jax", "lax", "os", "time", "math", "json",
    "re", "sys", "collections", "functools", "dataclasses", "hashlib",
    "itertools", "logging", "ast", "pl", "plgpu", "optax",
}

_CLOCK_CALLS = {"time.time", "time.perf_counter", "time.monotonic",
                "datetime.datetime.now", "datetime.now"}


@dataclasses.dataclass
class Finding:
    path: str
    line: int
    rule: str
    msg: str

    def render(self, root: str) -> str:
        rel = os.path.relpath(self.path, root)
        return f"{rel}:{self.line}: {self.rule}[{ALIAS[self.rule]}] " \
            f"{self.msg}"


def _dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class Mod:
    """One parsed source file plus its pragma table."""

    def __init__(self, path: str):
        self.path = path
        with open(path, "r") as f:
            self.src = f.read()
        self.tree = ast.parse(self.src, filename=path)
        self.pragmas: Dict[int, Tuple[str, Optional[str]]] = {}
        for i, ln in enumerate(self.src.splitlines(), 1):
            m = PRAGMA_RE.search(ln)
            if m:
                self.pragmas[i] = (m.group(1), m.group(2))


@dataclasses.dataclass
class Func:
    qualname: str            # "Class.method" or "func"
    cls: Optional[str]
    name: str
    node: ast.AST            # FunctionDef
    mod: Mod


class Linter:
    def __init__(self, root: str):
        self.root = root
        self.findings: List[Finding] = []
        self.mods: List[Mod] = []
        self.runtime_mods: List[Mod] = []
        self.funcs: Dict[str, Func] = {}      # qualname -> Func
        self.by_name: Dict[str, List[str]] = {}     # bare -> qualnames
        self.methods: Dict[str, List[str]] = {}     # attr -> qualnames
        self._load()
        self._index()

    # ------------------------------------------------------------- load --
    def _load(self) -> None:
        src = os.path.join(self.root, "src")
        for base, _dirs, files in os.walk(src):
            for f in sorted(files):
                if not f.endswith(".py"):
                    continue
                path = os.path.join(base, f)
                try:
                    mod = Mod(path)
                except SyntaxError as e:
                    self._emit(path, e.lineno or 1, "RL000",
                               f"syntax error: {e.msg}")
                    continue
                self.mods.append(mod)
                if os.sep + os.path.join("repro", "runtime") + os.sep \
                        in path:
                    self.runtime_mods.append(mod)

    def _index(self) -> None:
        for mod in self.mods:
            for node in mod.tree.body:
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    self._add_func(None, node, mod)
                elif isinstance(node, ast.ClassDef):
                    for sub in node.body:
                        if isinstance(sub, (ast.FunctionDef,
                                            ast.AsyncFunctionDef)):
                            self._add_func(node.name, sub, mod)

    def _add_func(self, cls: Optional[str], node: ast.AST,
                  mod: Mod) -> None:
        qual = f"{cls}.{node.name}" if cls else node.name
        if qual in self.funcs:        # first definition wins
            return
        fn = Func(qual, cls, node.name, node, mod)
        self.funcs[qual] = fn
        if cls is None:
            self.by_name.setdefault(node.name, []).append(qual)
        else:
            self.methods.setdefault(node.name, []).append(qual)

    # ---------------------------------------------------------- pragmas --
    def _suppressed(self, mod: Mod, node: ast.AST, rule: str) -> bool:
        lo = getattr(node, "lineno", None)
        hi = getattr(node, "end_lineno", lo)
        if lo is None:
            return False
        want = ALIAS[rule]
        for ln in range(lo, (hi or lo) + 1):
            got = mod.pragmas.get(ln)
            if got and got[0] == want:
                if not got[1]:
                    self._emit(mod.path, ln, "RL000",
                               f"pragma '{want}-ok' has no reason — "
                               "state why the violation is safe")
                return True
        return False

    def _emit(self, path: str, line: int, rule: str, msg: str) -> None:
        self.findings.append(Finding(path, line, rule, msg))

    def _flag(self, mod: Mod, node: ast.AST, rule: str,
              msg: str) -> None:
        if not self._suppressed(mod, node, rule):
            self._emit(mod.path, node.lineno, rule, msg)

    # ------------------------------------------------------- call graph --
    def _edges(self, fn: Func) -> Set[str]:
        out: Set[str] = set()
        for node in ast.walk(fn.node):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Name):
                for q in self.by_name.get(f.id, ()):
                    out.add(q)
            elif isinstance(f, ast.Attribute):
                dn = _dotted(f)
                if dn and dn.split(".", 1)[0] in _EXTERNAL_ROOTS:
                    continue
                if isinstance(f.value, ast.Name) and f.value.id == "self" \
                        and fn.cls is not None \
                        and f"{fn.cls}.{f.attr}" in self.funcs:
                    out.add(f"{fn.cls}.{f.attr}")
                    continue
                for q in self.methods.get(f.attr, ()):
                    out.add(q)
                for q in self.by_name.get(f.attr, ()):
                    out.add(q)
        return out

    def _closure(self, roots: Iterable[str]) -> Set[str]:
        seen: Set[str] = set()
        work = [q for q in roots if q in self.funcs]
        while work:
            q = work.pop()
            if q in seen:
                continue
            seen.add(q)
            work.extend(self._edges(self.funcs[q]) - seen)
        return seen

    # =================================================== RL001 host-sync --
    def _device_call(self, node: ast.Call) -> bool:
        """A call that returns device-backed values."""
        dn = _dotted(node.func)
        if dn is None:
            return False
        if dn == "jax.device_get":
            return False          # device_get IS the sync; output is host
        root = dn.split(".", 1)[0]
        if root in ("jnp", "jax", "lax"):
            return True
        return any(p.startswith("_jit") for p in dn.split("."))

    def _tainted_names(self, fn: Func) -> Set[str]:
        """Flow-insensitive: dotted names assigned from device calls."""
        tainted: Set[str] = set()
        for node in ast.walk(fn.node):
            if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                continue
            value = node.value
            if value is None:
                continue
            if not any(isinstance(sub, ast.Call)
                       and self._device_call(sub)
                       for sub in ast.walk(value)):
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                for el in ast.walk(t):
                    dn = _dotted(el)
                    if dn:
                        tainted.add(dn)
        return tainted

    def _mentions_device(self, node: ast.AST, tainted: Set[str]) -> bool:
        for sub in ast.walk(node):
            dn = _dotted(sub)
            if dn is None:
                continue
            root = dn.split(".", 1)[0]
            if root in ("jnp", "lax"):
                return True
            if root == "jax" and dn != "jax.device_get":
                return True
            if dn in tainted:
                return True
            if any(p.startswith("_jit") for p in dn.split(".")):
                return True
        return False

    def check_host_sync(self) -> None:
        hot = self._closure(
            f"{c}.{m}" if c else m for c, m in HOT_ROOTS)
        for q in sorted(hot):
            fn = self.funcs[q]
            tainted = self._tainted_names(fn)
            for node in ast.walk(fn.node):
                if not isinstance(node, ast.Call):
                    continue
                f = node.func
                if isinstance(f, ast.Attribute) and f.attr == "item" \
                        and not node.args:
                    self._flag(fn.mod, node, "RL001",
                               f"{q}: .item() forces a host-device sync "
                               "in a per-token hot path")
                    continue
                dn = _dotted(f)
                if dn == "jax.device_get":
                    self._flag(fn.mod, node, "RL001",
                               f"{q}: jax.device_get in a per-token hot "
                               "path — batch it to one pull per wave")
                    continue
                if isinstance(f, ast.Name) and f.id == "float" \
                        and node.args \
                        and self._mentions_device(node.args[0], tainted):
                    self._flag(fn.mod, node, "RL001",
                               f"{q}: float() of a device value blocks "
                               "on the accelerator per call")
                    continue
                if dn in ("np.asarray", "np.array", "numpy.asarray",
                          "numpy.array") and node.args \
                        and self._mentions_device(node.args[0], tainted):
                    self._flag(fn.mod, node, "RL001",
                               f"{q}: {dn}() of a device value is a "
                               "host transfer in a per-token hot path")

    # ================================================= RL002 time-in-jit --
    def _jitted_roots(self) -> List[str]:
        roots: List[str] = []
        for mod in self.mods:
            for node in ast.walk(mod.tree):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                    for dec in node.decorator_list:
                        dd = _dotted(dec) or (
                            _dotted(dec.func)
                            if isinstance(dec, ast.Call) else None)
                        if dd == "jax.jit" or (
                                isinstance(dec, ast.Call)
                                and _dotted(dec.func)
                                == "functools.partial" and dec.args
                                and _dotted(dec.args[0]) == "jax.jit"):
                            roots.extend(self._resolve_ref(node.name))
                if isinstance(node, ast.Call) \
                        and _dotted(node.func) == "jax.jit" and node.args:
                    wrapped = node.args[0]
                    if isinstance(wrapped, ast.Name):
                        roots.extend(self._resolve_ref(wrapped.id))
                    elif isinstance(wrapped, ast.Attribute):
                        roots.extend(self._resolve_ref(wrapped.attr))
        return roots

    def _resolve_ref(self, name: str) -> List[str]:
        return list(self.by_name.get(name, ())) \
            + list(self.methods.get(name, ()))

    def check_time_in_jit(self) -> None:
        for q in sorted(self._closure(self._jitted_roots())):
            fn = self.funcs[q]
            for node in ast.walk(fn.node):
                if isinstance(node, ast.Call) \
                        and _dotted(node.func) in _CLOCK_CALLS:
                    self._flag(fn.mod, node, "RL002",
                               f"{q}: wall-clock call reachable from a "
                               "jitted function — the value is baked in "
                               "at trace time, not read per call")

    # ================================================ RL003 static args --
    _HASHABLE_KINDS = (ast.Constant, ast.Name, ast.Attribute,
                       ast.UnaryOp)

    def _hashable_expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Tuple):
            return all(self._hashable_expr(e) for e in node.elts)
        if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp,
                             ast.GeneratorExp)):
            return False
        if isinstance(node, ast.Call):
            dn = _dotted(node.func) or ""
            return dn not in ("list", "dict", "set", "np.array",
                              "np.asarray", "jnp.array", "jnp.asarray")
        return True

    def _jit_props(self, call: ast.Call) -> Dict[str, Any]:
        props: Dict[str, Any] = {"static": (), "donate": ()}
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                try:
                    props["static"] = tuple(ast.literal_eval(kw.value)) \
                        if not isinstance(kw.value, ast.Constant) \
                        else (kw.value.value,)
                except (ValueError, SyntaxError):
                    props["static"] = ()
            elif kw.arg == "donate_argnums":
                try:
                    v = ast.literal_eval(kw.value)
                    props["donate"] = tuple(v) if isinstance(
                        v, (tuple, list)) else (v,)
                except (ValueError, SyntaxError):
                    props["donate"] = ()
        return props

    def _jit_wrappers(self) -> Dict[str, Dict[str, Any]]:
        """Symbol -> jit props, for wrappers reachable by name.

        Covers: decorated defs, ``X = jax.jit(f, ...)`` bindings, and
        the jit-dict idiom — a function returning a dict literal of
        ``jax.jit`` calls, unpacked elsewhere as
        ``jits = _engine_jits(...); self._jit_x = jits["key"]``."""
        wrappers: Dict[str, Dict[str, Any]] = {}
        jitdicts: Dict[str, Dict[str, Dict[str, Any]]] = {}
        for mod in self.mods:
            for node in ast.walk(mod.tree):
                if isinstance(node, ast.FunctionDef):
                    for dec in node.decorator_list:
                        if isinstance(dec, ast.Call) \
                                and _dotted(dec.func) \
                                == "functools.partial" and dec.args \
                                and _dotted(dec.args[0]) == "jax.jit":
                            wrappers[node.name] = self._jit_props(dec)
                    for sub in ast.walk(node):
                        if isinstance(sub, ast.Return) \
                                and isinstance(sub.value, ast.Dict):
                            entries = {}
                            for k, v in zip(sub.value.keys,
                                            sub.value.values):
                                if isinstance(k, ast.Constant) \
                                        and isinstance(v, ast.Call) \
                                        and _dotted(v.func) == "jax.jit":
                                    entries[k.value] = self._jit_props(v)
                            if entries:
                                jitdicts[node.name] = entries
                if isinstance(node, ast.Assign) \
                        and isinstance(node.value, ast.Call) \
                        and _dotted(node.value.func) == "jax.jit":
                    for t in node.targets:
                        dn = _dotted(t)
                        if dn:
                            wrappers[dn.split(".")[-1]] = \
                                self._jit_props(node.value)
        # second pass: jits = <jitdict-func>(...); name = jits["key"]
        for mod in self.mods:
            for node in ast.walk(mod.tree):
                if not isinstance(node, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                    continue
                dict_vars: Dict[str, Dict[str, Dict[str, Any]]] = {}
                for sub in ast.walk(node):
                    if not isinstance(sub, ast.Assign):
                        continue
                    v = sub.value
                    if isinstance(v, ast.Call) \
                            and isinstance(v.func, ast.Name) \
                            and v.func.id in jitdicts:
                        for t in sub.targets:
                            if isinstance(t, ast.Name):
                                dict_vars[t.id] = jitdicts[v.func.id]
                    if isinstance(v, ast.Subscript) \
                            and isinstance(v.value, ast.Name) \
                            and v.value.id in dict_vars \
                            and isinstance(v.slice, ast.Constant) \
                            and v.slice.value in dict_vars[v.value.id]:
                        for t in sub.targets:
                            dn = _dotted(t)
                            if dn:
                                wrappers[dn.split(".")[-1]] = \
                                    dict_vars[v.value.id][v.slice.value]
        return wrappers

    def check_static_args(self) -> None:
        wrappers = self._jit_wrappers()
        for mod in self.mods:
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                dn = _dotted(node.func)
                if dn in ("jax.jit",) or (dn or "").endswith(
                        "pallas_call"):
                    for kw in node.keywords:
                        if kw.arg in ("static_argnames",
                                      "static_argnums", "grid") \
                                and not self._hashable_expr(kw.value):
                            self._flag(
                                mod, node, "RL003",
                                f"{dn}: {kw.arg} must be a hashable "
                                "tuple, not a mutable "
                                f"{type(kw.value).__name__}")
                sym = (dn or "").split(".")[-1]
                props = wrappers.get(sym)
                if not props or not props["static"]:
                    continue
                for kw in node.keywords:
                    if kw.arg in props["static"] \
                            and not self._hashable_expr(kw.value):
                        self._flag(
                            mod, node, "RL003",
                            f"{sym}: static arg {kw.arg!r} gets a "
                            f"mutable {type(kw.value).__name__} — "
                            "unhashable static args retrace or crash")

    # ================================================== RL004 donation --
    def check_donation(self) -> None:
        wrappers = self._jit_wrappers()
        for q, fn in sorted(self.funcs.items()):
            # SIMPLE statements only: a compound statement (for/if/...)
            # contains its children, so matching calls through it would
            # double-count every nested call against empty targets
            stmts = sorted(
                (s for s in ast.walk(fn.node)
                 if isinstance(s, ast.stmt)),
                key=lambda s: s.lineno)
            simple = [s for s in stmts
                      if isinstance(s, (ast.Assign, ast.AnnAssign,
                                        ast.AugAssign, ast.Expr,
                                        ast.Return))]
            for st in simple:
                for call in ast.walk(st):
                    if not isinstance(call, ast.Call):
                        continue
                    sym = (_dotted(call.func) or "").split(".")[-1]
                    props = wrappers.get(sym)
                    if not props or not props["donate"]:
                        continue
                    targets: Set[str] = set()
                    if isinstance(st, ast.Assign):
                        for t in st.targets:
                            for el in ast.walk(t):
                                dn = _dotted(el)
                                if dn:
                                    targets.add(dn)
                    for idx in props["donate"]:
                        if idx >= len(call.args):
                            continue
                        dn = _dotted(call.args[idx])
                        if dn is None or dn in targets:
                            continue
                        reuse = self._later_load(
                            simple, st, dn)
                        if reuse is not None:
                            self._flag(
                                fn.mod, reuse, "RL004",
                                f"{q}: reads {dn!r} after it was "
                                f"DONATED to {sym} (arg {idx}) — the "
                                "buffer is invalidated; rebind it from "
                                "the call's result")

    def _later_load(self, stmts: List[ast.stmt], after: ast.stmt,
                    dotted: str) -> Optional[ast.AST]:
        for st in stmts:
            if st.lineno <= (after.end_lineno or after.lineno):
                continue
            stored = False
            if isinstance(st, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = st.targets if isinstance(st, ast.Assign) \
                    else [st.target]
                for t in targets:
                    for el in ast.walk(t):   # tuple-unpack targets too
                        if _dotted(el) == dotted:
                            stored = True
            for sub in ast.walk(st):
                if isinstance(sub, (ast.Name, ast.Attribute)) \
                        and isinstance(getattr(sub, "ctx", None),
                                       ast.Load) \
                        and _dotted(sub) == dotted:
                    return sub
            if stored:
                return None
        return None

    # ========================================== RL101 replica protocol --
    def check_replica_conformance(self) -> None:
        proto = self._class_methods("ReplicaHandle")
        if proto is None:
            return
        names = {n for n in proto if not n.startswith("_")}
        for impl in ("SimReplica", "LiveReplica"):
            have = self._class_methods(impl)
            if have is None:
                self._emit(self._class_path("ReplicaHandle") or "",
                           1, "RL101", f"{impl}: class not found")
                continue
            for missing in sorted(names - set(have)):
                mod, node = self._class_node(impl)
                self._flag(mod, node, "RL101",
                           f"{impl} does not implement "
                           f"ReplicaHandle.{missing} — both replica "
                           "kinds must cover the whole protocol")

    def _class_node(self, name: str):
        for mod in self.mods:
            for node in mod.tree.body:
                if isinstance(node, ast.ClassDef) and node.name == name:
                    return mod, node
        return None, None

    def _class_methods(self, name: str) -> Optional[List[str]]:
        _mod, node = self._class_node(name)
        if node is None:
            return None
        out = []
        for sub in node.body:
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.append(sub.name)
        return out

    def _class_path(self, name: str) -> Optional[str]:
        mod, _ = self._class_node(name)
        return mod.path if mod else None

    # ============================================ RL102 stats coverage --
    def check_stats_coverage(self) -> None:
        mod, stats = self._class_node("ServeStats")
        agg = self.funcs.get("aggregate_serve_stats")
        if stats is None or agg is None:
            return
        mentioned: Set[str] = set()
        for node in ast.walk(agg.node):
            if isinstance(node, ast.Name):
                mentioned.add(node.id)
            elif isinstance(node, ast.Attribute):
                mentioned.add(node.attr)
            elif isinstance(node, ast.Constant) \
                    and isinstance(node.value, str):
                mentioned.add(node.value)
        # a Name in the fold body may refer to a module-level literal
        # (the _SERVE_COUNTERS idiom) — its strings count as folded
        for node in agg.mod.tree.body:
            if isinstance(node, ast.Assign) \
                    and any(isinstance(t, ast.Name)
                            and t.id in mentioned
                            for t in node.targets):
                try:
                    val = ast.literal_eval(node.value)
                except (ValueError, SyntaxError):
                    continue
                if isinstance(val, (tuple, list, set)):
                    mentioned.update(
                        v for v in val if isinstance(v, str))
        for sub in stats.body:
            if isinstance(sub, ast.AnnAssign) \
                    and isinstance(sub.target, ast.Name) \
                    and sub.target.id not in mentioned:
                self._flag(mod, sub, "RL102",
                           f"ServeStats.{sub.target.id} is never folded "
                           "by aggregate_serve_stats — cluster rollups "
                           "silently drop it")

    # ========================================== RL103 field threading --
    def check_request_threading(self) -> None:
        for cls in ("GenRequest", "Request"):
            mod, node = self._class_node(cls)
            if node is None:
                continue
            fields = [sub for sub in node.body
                      if isinstance(sub, ast.AnnAssign)
                      and isinstance(sub.target, ast.Name)]
            span = (node.lineno, node.end_lineno or node.lineno)
            used: Set[str] = set()
            for m in self.mods:
                for sub in ast.walk(m.tree):
                    if m is mod and span[0] <= getattr(
                            sub, "lineno", 0) <= span[1]:
                        continue
                    if isinstance(sub, ast.Attribute):
                        used.add(sub.attr)
                    elif isinstance(sub, ast.Call):
                        used.update(kw.arg for kw in sub.keywords
                                    if kw.arg)
            for f in fields:
                if f.target.id not in used:
                    self._flag(mod, f, "RL103",
                               f"{cls}.{f.target.id} is never read or "
                               "written outside its dataclass — the "
                               "admission->tick->eviction threading "
                               "dropped it")

    # ======================================= RL104 bench registration --
    def check_bench_registration(self) -> None:
        ci = os.path.join(self.root, "scripts", "ci.sh")
        bench_dir = os.path.join(self.root, "benchmarks")
        if not os.path.isfile(ci) or not os.path.isdir(bench_dir):
            return
        with open(ci) as f:
            ci_text = f.read()
        for f_name in sorted(os.listdir(bench_dir)):
            if not f_name.endswith(".py"):
                continue
            path = os.path.join(bench_dir, f_name)
            with open(path) as fh:
                src = fh.read()
            if not re.search(r"BENCH_\w+\.json", src):
                continue
            if f_name not in ci_text:
                self._emit(path, 1, "RL104",
                           f"benchmarks/{f_name} writes a BENCH_*.json "
                           "trajectory but is not registered in "
                           "scripts/ci.sh")

    # ========================================= RL105 sanitizer hooks --
    _MUTATOR_CALLS = {"append", "appendleft", "add", "clear", "discard",
                      "extend", "insert", "pop", "popleft", "remove",
                      "setdefault", "update", "difference_update"}

    @staticmethod
    def _roots_at_self(node: ast.AST) -> bool:
        """Does this attribute/subscript chain root at ``self``?"""
        while isinstance(node, (ast.Attribute, ast.Subscript)):
            node = node.value
        return isinstance(node, ast.Name) and node.id == "self"

    def check_sanitizer_hooks(self) -> None:
        mod, cls = self._class_node("BlockAllocator")
        if cls is None:
            return
        for fn in cls.body:
            if not isinstance(fn, (ast.FunctionDef,
                                   ast.AsyncFunctionDef)) \
                    or fn.name.startswith("_"):
                continue
            mutates = False
            hooked = False
            for sub in ast.walk(fn):
                if isinstance(sub, (ast.Assign, ast.AugAssign)):
                    targets = sub.targets \
                        if isinstance(sub, ast.Assign) else [sub.target]
                    if any(self._roots_at_self(t) for t in targets):
                        mutates = True
                elif isinstance(sub, ast.Call) \
                        and isinstance(sub.func, ast.Attribute) \
                        and sub.func.attr in self._MUTATOR_CALLS \
                        and self._roots_at_self(sub.func.value):
                    mutates = True
                if isinstance(sub, ast.Attribute) and sub.attr == "san" \
                        and isinstance(sub.value, ast.Name) \
                        and sub.value.id == "self":
                    hooked = True
            if mutates and not hooked:
                self._flag(mod, fn, "RL105",
                           f"BlockAllocator.{fn.name} mutates allocator "
                           "state without calling its BlockSanitizer "
                           "hook (self.san) — the shadow mirror desyncs "
                           "and use-after-free/use-after-swap checks go "
                           "blind")

    # -------------------------------------------------------------- run --
    def run(self, rules: Optional[Set[str]] = None) -> List[Finding]:
        checks = {
            "RL001": self.check_host_sync,
            "RL002": self.check_time_in_jit,
            "RL003": self.check_static_args,
            "RL004": self.check_donation,
            "RL101": self.check_replica_conformance,
            "RL102": self.check_stats_coverage,
            "RL103": self.check_request_threading,
            "RL104": self.check_bench_registration,
            "RL105": self.check_sanitizer_hooks,
        }
        for rule, check in checks.items():
            if rules is None or rule in rules:
                check()
        self.findings.sort(key=lambda f: (f.path, f.line, f.rule))
        return self.findings


def lint_root(root: str,
              rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint a repo tree; returns findings (empty = clean)."""
    return Linter(os.path.abspath(root)).run(
        set(rules) if rules is not None else None)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    default_root = os.path.abspath(
        os.path.join(os.path.dirname(os.path.abspath(__file__)),
                     "..", ".."))
    ap.add_argument("--root", default=default_root,
                    help="repo root to lint (default: this repo)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids (default: all)")
    args = ap.parse_args(argv)
    rules = set(args.rules.split(",")) if args.rules else None
    findings = lint_root(args.root, rules)
    for f in findings:
        print(f.render(args.root))
    n = len(findings)
    print(f"reprolint: {n} finding{'s' if n != 1 else ''}"
          if n else "reprolint: clean")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
