"""Transformer blocks for every assigned family, consumed via
``jax.lax.scan`` over stacked layer parameters.

Block wiring per family:
  DENSE / MOE / ENCODER : x += attn(norm1(x)); x += mlp|moe(norm2(x))
  SSM (mamba2)          : x += ssm(norm1(x))
  HYBRID (hymba)        : x += mean(attn, ssm)(norm1(x)); x += mlp(norm2(x))
  VLM                   : units of (cross_attn_every-1) self blocks + 1
                          cross-attention block over vision tokens
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import Family, ModelConfig
from repro.models import lora as lora_lib
from repro.models import mamba2
from repro.models.layers import (
    apply_rope, attention_blockwise, attention_decode,
    attention_decode_paged, attention_dense, attention_prefix_suffix,
    dense_init, rms_norm, rope_tables, swiglu,
)
from repro.models.sharding import shard


# --------------------------------------------------------------- params ----
def init_attn(key, cfg: ModelConfig, cross: bool = False) -> Dict:
    d, h = cfg.d_model, cfg.head_dim
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * h, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * h, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * h, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * h, d, dtype,
                         scale=1.0 / math.sqrt(cfg.n_heads * h)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((cfg.n_heads * h,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * h,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * h,), dtype)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((h,), dtype)
        p["k_norm"] = jnp.ones((h,), dtype)
    return p


def init_mlp(key, cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return {"wg": dense_init(ks[0], d, f, dtype),
            "wu": dense_init(ks[1], d, f, dtype),
            "wd": dense_init(ks[2], f, d, dtype)}


def init_block(key, cfg: ModelConfig) -> Dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if cfg.family is Family.SSM:
        p["ssm"] = mamba2.init_ssm(ks[0], cfg)._asdict()
        return p
    p["attn"] = init_attn(ks[0], cfg)
    if cfg.family is Family.HYBRID:
        p["ssm"] = mamba2.init_ssm(ks[1], cfg)._asdict()
    if cfg.d_ff > 0:
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        if cfg.family is Family.MOE:
            from repro.models.moe import init_moe
            p["moe"] = init_moe(ks[2], cfg)._asdict()
        else:
            p["mlp"] = init_mlp(ks[2], cfg)
    return p


def init_cross_block(key, cfg: ModelConfig) -> Dict:
    """Cross-attention block (VLM): gated cross-attn + MLP."""
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": init_attn(ks[0], cfg, cross=True),
        "gate_attn": jnp.zeros((), jnp.float32),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": init_mlp(ks[1], cfg),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


# ------------------------------------------------------------- attention ---
def _proj_qkv(p, x, cfg, lora, adapter_idx=None):
    sc = cfg.lora.scaling
    q = lora_lib.apply(x, x @ p["wq"], lora.get("q") if lora else None, sc,
                       adapter_idx)
    k = lora_lib.apply(x, x @ p["wk"], lora.get("k") if lora else None, sc,
                       adapter_idx)
    v = lora_lib.apply(x, x @ p["wv"], lora.get("v") if lora else None, sc,
                       adapter_idx)
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    b, s = x.shape[0], x.shape[1]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    return q, k, v


def use_dense_prefill(cfg: ModelConfig, s: int) -> bool:
    """Whether full-sequence attention at length ``s`` takes the dense
    (full score matrix) path rather than the blockwise online-softmax
    path.  Shared with the serving runtime's prefix-cache gate: suffix
    prefill mirrors the DENSE softmax formulation bit-for-bit, so
    prefix sharing is only sound for configs that prefill densely."""
    return cfg.attn_impl == "dense" or (
        cfg.attn_impl == "auto" and s * s <= 1024 * 1024
        and not cfg.unroll_attn_blocks)


def attn_full(p, x, cfg: ModelConfig, rope_cs, lora=None,
              block_kv: int = 512, skip_masked_blocks: bool = False,
              adapter_idx=None
              ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence attention (training / prefill).  Returns (out, (k, v))
    so prefill can stash the KV cache."""
    q, k, v = _proj_qkv(p, x, cfg, lora, adapter_idx)
    if rope_cs is not None:
        cos, sin = rope_cs
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    q = shard(q, "batch", "q_seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)
    causal = not cfg.encoder_only
    s = x.shape[1]
    if use_dense_prefill(cfg, s):
        o = attention_dense(q, k, v, causal=causal,
                            window=cfg.sliding_window)
    else:
        o = attention_blockwise(q, k, v, causal=causal,
                                window=cfg.sliding_window,
                                block_kv=block_kv,
                                skip_masked_blocks=skip_masked_blocks
                                and causal,
                                unroll=cfg.unroll_attn_blocks)
    o = o.reshape(x.shape[0], s, cfg.n_heads * cfg.head_dim)
    out = lora_lib.apply(o, o @ p["wo"], lora.get("o") if lora else None,
                         cfg.lora.scaling, adapter_idx)
    return out, (k, v)


def attn_decode(p, x, cfg: ModelConfig, cache_kv, pos, rope_cs, lora=None,
                backend=None, adapter_idx=None):
    """One-token attention against a KV cache.

    cache_kv: (k_cache, v_cache) [B,S,Hkv,Dh]; pos: scalar int32 absolute
    position of the new token, or [B] int32 per-sequence positions
    (ragged decode slots — continuous batching).  Sliding-window archs
    keep a *ring buffer* of window size (keys carry absolute RoPE, so
    ring order is irrelevant — attention is permutation-invariant over
    cache slots).  ``backend`` picks the decode-attention path (Pallas
    on TPU, jnp elsewhere; see ``layers.resolve_decode_backend``).
    Returns (out, updated cache)."""
    k_cache, v_cache = cache_kv
    cache_len = k_cache.shape[1]
    ragged = jnp.ndim(pos) > 0
    q, k, v = _proj_qkv(p, x, cfg, lora, adapter_idx)
    if rope_cs is not None:
        cos, sin = rope_cs  # [1, Dh/2] (shared) or [B, 1, Dh/2] (ragged)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)

    # sequence-sharded flash-decode (shard_map) when the cache's seq dim
    # is mesh-sharded: local write + partial-softmax reduction instead of
    # GSPMD resharding the whole cache around the dynamic write
    from repro.models.sharding import current_mesh, current_rules
    mesh = current_mesh()
    rules = current_rules() if mesh is not None else None
    use_sharded = (
        mesh is not None and rules is not None and not ragged
        and rules.kv_seq in getattr(mesh, "shape", {})
        and cfg.sliding_window == 0
        and cache_len % mesh.shape[rules.kv_seq] == 0)
    if use_sharded:
        from repro.models.layers import attention_decode_seqsharded
        o, k_cache, v_cache = attention_decode_seqsharded(
            q, k, v, k_cache, v_cache, pos)
        o = o.reshape(x.shape[0], 1, cfg.n_heads * cfg.head_dim)
        out = lora_lib.apply(o, o @ p["wo"],
                             lora.get("o") if lora else None,
                             cfg.lora.scaling, adapter_idx)
        return out, (k_cache, v_cache)

    wpos = lax.rem(pos, cache_len) if cfg.sliding_window > 0 else pos
    if ragged:
        # each sequence writes its new K/V at its own cache position
        row_update = jax.vmap(
            lambda c, new, w: lax.dynamic_update_slice_in_dim(
                c, new, w, axis=0))
        k_cache = row_update(k_cache, k.astype(k_cache.dtype), wpos)
        v_cache = row_update(v_cache, v.astype(v_cache.dtype), wpos)
    else:
        k_cache = lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), wpos, axis=1)
        v_cache = lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), wpos, axis=1)
    kv_len = jnp.minimum(pos + 1, cache_len)
    o = attention_decode(q, k_cache, v_cache, kv_len, backend=backend)
    o = o.reshape(x.shape[0], 1, cfg.n_heads * cfg.head_dim)
    out = lora_lib.apply(o, o @ p["wo"], lora.get("o") if lora else None,
                         cfg.lora.scaling, adapter_idx)
    return out, (k_cache, v_cache)


def attn_decode_paged(p, x, cfg: ModelConfig, pool_kv, rope_cs,
                      block_tables, write_block, write_off, kv_len,
                      lora=None, backend=None, adapter_idx=None):
    """One-token attention against one layer's paged KV block pool.

    pool_kv: (k_pool, v_pool) [n_blocks, block_size, Hkv, Dh];
    block_tables: [B, NB] int32; write_block/write_off: [B] int32 pool
    block id and in-block offset where each sequence's new K/V lands
    (precomputed once per step from the ragged positions — ring
    addressing for sliding-window archs included); kv_len: [B] valid
    logical length AFTER the write.  Returns (out, updated pools)."""
    k_pool, v_pool = pool_kv
    q, k, v = _proj_qkv(p, x, cfg, lora, adapter_idx)
    if rope_cs is not None:
        cos, sin = rope_cs
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    # scatter the new token's K/V into each sequence's current block —
    # distinct sequences own distinct blocks, so indices never collide
    # (inactive slots share scratch block 0, where last-write-wins
    # garbage is fine: their logits are discarded)
    k_pool = k_pool.at[write_block, write_off].set(
        k[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[write_block, write_off].set(
        v[:, 0].astype(v_pool.dtype))
    o = attention_decode_paged(q, k_pool, v_pool, block_tables, kv_len,
                               backend=backend)
    o = o.reshape(x.shape[0], 1, cfg.n_heads * cfg.head_dim)
    out = lora_lib.apply(o, o @ p["wo"], lora.get("o") if lora else None,
                         cfg.lora.scaling, adapter_idx)
    return out, (k_pool, v_pool)


def attn_prefill_suffix(p, x, cfg: ModelConfig, prefix_kv, prefix_len,
                        rope_cs, lora=None, adapter_idx=None):
    """Ragged suffix prefill attention for one layer: queries are the
    uncached suffix tokens (absolute positions ``prefix_len + i``, RoPE
    tables precomputed per row); keys are the cached prefix K/V
    (gathered from pool blocks) plus the suffix's own K/V.  Returns
    (out, (k_suf, v_suf)) so the runtime can scatter the fresh suffix
    K/V into its newly allocated blocks."""
    q, k, v = _proj_qkv(p, x, cfg, lora, adapter_idx)
    if rope_cs is not None:
        cos, sin = rope_cs
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    k_pre, v_pre = prefix_kv
    o = attention_prefix_suffix(q, k_pre, v_pre, k, v, prefix_len,
                                window=cfg.sliding_window)
    o = o.reshape(x.shape[0], x.shape[1], cfg.n_heads * cfg.head_dim)
    out = lora_lib.apply(o, o @ p["wo"], lora.get("o") if lora else None,
                         cfg.lora.scaling, adapter_idx)
    return out, (k, v)


def block_prefill_suffix(bp, x, cfg: ModelConfig, prefix_kv, prefix_len,
                         rope_cs, lora=None, adapter_idx=None):
    """Suffix-prefill block (attention-only stacks — prefix sharing
    rides on the paged KV pool).  Returns (x, (k_suf, v_suf))."""
    h = rms_norm(x, bp["ln1"])
    attn_out, kv = attn_prefill_suffix(bp["attn"], h, cfg, prefix_kv,
                                       prefix_len, rope_cs, lora=lora,
                                       adapter_idx=adapter_idx)
    x = x + attn_out
    if cfg.d_ff > 0:
        y, _ = _mlp_out(bp, rms_norm(x, bp["ln2"]), cfg, lora,
                        adapter_idx)
        x = x + y
    x = shard(x, "batch", "act_seq", "embed")
    return x, kv


def cross_attn(p, x, vision_kv, cfg: ModelConfig):
    """Cross-attention over precomputed vision K/V (no rope, no cache
    mutation — vision tokens are static per request)."""
    b, s = x.shape[0], x.shape[1]
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k, v = vision_kv
    o = attention_dense(q, k, v, causal=False)
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return o @ p["wo"]


def vision_kv(p, vis: jax.Array, cfg: ModelConfig):
    """Project vision embeddings to K/V once (cached for decode)."""
    b, t = vis.shape[0], vis.shape[1]
    k = (vis @ p["wk"]).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    v = (vis @ p["wv"]).reshape(b, t, cfg.n_kv_heads, cfg.head_dim)
    return k, v


# ----------------------------------------------------------------- blocks --
def _mlp_out(bp, h, cfg, lora, adapter_idx=None):
    if "moe" in bp:
        from repro.models.moe import MoEParams, moe_mlp
        y, aux = moe_mlp(MoEParams(**bp["moe"]), h, cfg)
        return y, aux
    sc = cfg.lora.scaling
    g = lora_lib.apply(h, h @ bp["mlp"]["wg"],
                       lora.get("gate") if lora else None, sc, adapter_idx)
    u = lora_lib.apply(h, h @ bp["mlp"]["wu"],
                       lora.get("up") if lora else None, sc, adapter_idx)
    hidden = jax.nn.silu(g) * u
    hidden = shard(hidden, "batch", "seq", "ff")
    y = lora_lib.apply(hidden, hidden @ bp["mlp"]["wd"],
                       lora.get("down") if lora else None, sc, adapter_idx)
    return y, jnp.zeros((), jnp.float32)


def block_full(bp, x, cfg: ModelConfig, rope_cs, lora=None,
               block_kv: int = 512, skip_masked_blocks: bool = False,
               adapter_idx=None):
    """Full-sequence block (training / prefill).  Returns
    (x, (kv, ssm_cache_final, aux_loss))."""
    h = rms_norm(x, bp["ln1"])
    kv = None
    ssm_final = None
    if cfg.family is Family.SSM:
        y, ssm_cache = mamba2.ssm_mixer(
            mamba2.SSMParams(**bp["ssm"]), h, cfg,
            cache=None, lora=lora)
        x = x + y
        return x, (kv, ssm_cache._asdict(), jnp.zeros((), jnp.float32))
    attn_out, kv = attn_full(bp["attn"], h, cfg, rope_cs, lora=lora,
                             block_kv=block_kv,
                             skip_masked_blocks=skip_masked_blocks,
                             adapter_idx=adapter_idx)
    if cfg.family is Family.HYBRID:
        ssm_out, ssm_cache = mamba2.ssm_mixer(
            mamba2.SSMParams(**bp["ssm"]), h, cfg, cache=None, lora=lora)
        ssm_final = ssm_cache._asdict()
        attn_out = 0.5 * (attn_out + ssm_out)
    x = x + attn_out
    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff > 0:
        y, aux = _mlp_out(bp, rms_norm(x, bp["ln2"]), cfg, lora,
                          adapter_idx)
        x = x + y
    # residual-stream constraint: under SP rules the remat-saved carry is
    # sequence-sharded over the model axis (act_seq), not replicated
    x = shard(x, "batch", "act_seq", "embed")
    return x, (kv, ssm_final, aux)


def block_decode(bp, x, cfg: ModelConfig, caches, pos, rope_cs, lora=None,
                 backend=None, adapter_idx=None):
    """One-token block.  caches: dict with optional 'kv' (k,v) and 'ssm'
    (SSMCache).  Returns (x, updated caches)."""
    h = rms_norm(x, bp["ln1"])
    new_caches = dict(caches)
    if cfg.family is Family.SSM:
        y, new_ssm = mamba2.ssm_mixer(
            mamba2.SSMParams(**bp["ssm"]), h, cfg,
            cache=mamba2.SSMCache(**caches["ssm"]), lora=lora)
        new_caches["ssm"] = new_ssm._asdict()
        return x + y, new_caches
    attn_out, new_kv = attn_decode(bp["attn"], h, cfg, caches["kv"], pos,
                                   rope_cs, lora=lora, backend=backend,
                                   adapter_idx=adapter_idx)
    new_caches["kv"] = new_kv
    if cfg.family is Family.HYBRID:
        ssm_out, new_ssm = mamba2.ssm_mixer(
            mamba2.SSMParams(**bp["ssm"]), h, cfg,
            cache=mamba2.SSMCache(**caches["ssm"]), lora=lora)
        new_caches["ssm"] = new_ssm._asdict()
        attn_out = 0.5 * (attn_out + ssm_out)
    x = x + attn_out
    if cfg.d_ff > 0:
        y, _ = _mlp_out(bp, rms_norm(x, bp["ln2"]), cfg, lora,
                        adapter_idx)
        x = x + y
    return x, new_caches


def block_decode_paged(bp, x, cfg: ModelConfig, pool_kv, rope_cs,
                       block_tables, write_block, write_off, kv_len,
                       lora=None, backend=None, adapter_idx=None):
    """One-token block against one layer's paged KV pool (attention-only
    stacks — SSM state is per-slot, not per-block).  Returns
    (x, updated pools)."""
    h = rms_norm(x, bp["ln1"])
    attn_out, new_kv = attn_decode_paged(
        bp["attn"], h, cfg, pool_kv, rope_cs, block_tables, write_block,
        write_off, kv_len, lora=lora, backend=backend,
        adapter_idx=adapter_idx)
    x = x + attn_out
    if cfg.d_ff > 0:
        y, _ = _mlp_out(bp, rms_norm(x, bp["ln2"]), cfg, lora,
                        adapter_idx)
        x = x + y
    return x, new_kv


def cross_block(cp, x, vkv, cfg: ModelConfig):
    h = rms_norm(x, cp["ln1"])
    ga = jnp.tanh(cp["gate_attn"]).astype(x.dtype)  # f32 gate; keep carry dtype
    x = x + ga * cross_attn(cp["attn"], h, vkv, cfg)
    h = rms_norm(x, cp["ln2"])
    y = swiglu(h, cp["mlp"]["wg"], cp["mlp"]["wu"], cp["mlp"]["wd"])
    gm = jnp.tanh(cp["gate_mlp"]).astype(x.dtype)
    return x + gm * y


# -------------------------------------------------------------- policies ---
def remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    else:  # "full" / "block"
        policy = None
    return jax.checkpoint(fn, policy=policy)
