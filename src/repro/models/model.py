"""Unified model: one ``Model`` object per ModelConfig, covering all five
assigned families with the same public surface:

  init(key) -> params                       init_lora(key) -> adapters
  forward_loss(params, lora, batch)         (training objective)
  prefill(params, lora, batch)              -> (logits_last, caches)
  decode_step(params, lora, caches, token, pos) -> (logits, caches)
  init_caches(batch, seq)                   (KV / SSM / cross-KV caches)
  input_specs(cell)                         ShapeDtypeStruct stand-ins

Layer stacks run through ``jax.lax.scan`` over stacked params so compile
time and HLO size are O(1) in depth (grok's 64 layers and the VLM's 100
layers compile like a 1-layer model).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import Family, ModelConfig, ShapeCell
from repro.models import lora as lora_lib
from repro.models import mamba2, transformer as tfm
from repro.models.layers import dense_init, rms_norm, rope_tables
from repro.models.sharding import shard


# ------------------------------------------------------------------ loss ---
def chunked_ce_loss(hidden: jax.Array, head: jax.Array, labels: jax.Array,
                    mask: jax.Array, chunk: int = 512
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Cross-entropy over a vocab head without materializing [B,S,V] f32:
    scans seq chunks, rematerializing logits in the backward pass."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    nc = s // chunk
    rem = s - nc * chunk

    def chunk_loss(h, y, m):
        logits = (h @ head).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - ll) * m), jnp.sum(m)

    chunk_loss = jax.checkpoint(chunk_loss)

    def body(carry, xs):
        tot, cnt = carry
        l, c = chunk_loss(*xs)
        return (tot + l, cnt + c), None

    hs = hidden[:, :nc * chunk].reshape(b, nc, chunk, d).swapaxes(0, 1)
    ys = labels[:, :nc * chunk].reshape(b, nc, chunk).swapaxes(0, 1)
    ms = mask[:, :nc * chunk].reshape(b, nc, chunk).swapaxes(0, 1)
    (tot, cnt), _ = lax.scan(body, (jnp.zeros((), jnp.float32),
                                    jnp.zeros((), jnp.float32)), (hs, ys, ms))
    if rem:
        l, c = chunk_loss(hidden[:, nc * chunk:], labels[:, nc * chunk:],
                          mask[:, nc * chunk:])
        tot, cnt = tot + l, cnt + c
    loss = tot / jnp.maximum(cnt, 1.0)
    return loss, {"loss_sum": tot, "token_count": cnt}


def _scan_or_loop(body, init, xs):
    """Unrolled drop-in for lax.scan over stacked-leading-dim xs trees.
    Used by cost-calibration compiles (scan_layers=False): XLA's
    HLOCostAnalysis counts a while-loop body once regardless of trip
    count, so the dry-run measures FLOPs on unrolled small-depth
    variants and extrapolates (see launch/dryrun.py)."""
    length = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    stacked = jax.tree.map(lambda *leaves: jnp.stack(leaves), *ys)
    return carry, stacked


# ----------------------------------------------------------------- model ---
@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # --------------------------------------------------------------- init --
    def init(self, key) -> Dict:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        k_embed, k_blocks, k_cross, k_head = jax.random.split(key, 4)
        params: Dict[str, Any] = {}
        params["embed"] = dense_init(k_embed, cfg.vocab_size, cfg.d_model,
                                     dtype, scale=1.0)
        if cfg.family is Family.VLM:
            units, per = self._vlm_shape()
            bkeys = jax.random.split(k_blocks, units * per).reshape(
                units, per)
            params["blocks"] = jax.vmap(jax.vmap(
                lambda k: tfm.init_block(k, cfg)))(bkeys)
            ckeys = jax.random.split(k_cross, units)
            params["cross"] = jax.vmap(
                lambda k: tfm.init_cross_block(k, cfg))(ckeys)
        else:
            bkeys = jax.random.split(k_blocks, cfg.n_layers)
            params["blocks"] = jax.vmap(
                lambda k: tfm.init_block(k, cfg))(bkeys)
        params["final_norm"] = jnp.ones((cfg.d_model,), dtype)
        params["lm_head"] = dense_init(k_head, cfg.d_model, cfg.vocab_size,
                                       dtype)
        return params

    def init_lora(self, key) -> Dict:
        cfg = self.cfg
        if cfg.family is Family.VLM:
            units, per = self._vlm_shape()
            tree = lora_lib.init_lora(key, cfg, units * per)
            return jax.tree.map(
                lambda x: x.reshape((units, per) + x.shape[1:]), tree)
        return lora_lib.init_lora(key, cfg, cfg.n_layers)

    def _vlm_shape(self) -> Tuple[int, int]:
        cfg = self.cfg
        units = cfg.n_layers // cfg.cross_attn_every
        per = cfg.cross_attn_every - 1
        return units, per

    # ------------------------------------------------------------ forward --
    def _embed(self, params, batch) -> jax.Array:
        cfg = self.cfg
        if cfg.encoder_only and "embeds" in batch:
            x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        else:
            x = jnp.take(params["embed"], batch["tokens"], axis=0)
        return shard(x, "batch", "act_seq", "embed")

    def hidden_states(self, params, lora, batch, *, collect_caches=False,
                      block_kv: int = 512, skip_masked_blocks: bool = False,
                      adapter_idx=None):
        """Full-sequence forward.  Returns (hidden, caches|None, aux).

        ``adapter_idx`` [B] int32 (optional) selects each row's adapter
        slot from a STACKED multi-adapter lora tree (leaves
        [L, A, din, r]); < 0 disables the bypass for that row."""
        cfg = self.cfg
        x = self._embed(params, batch)
        s = x.shape[1]
        rope_cs = rope_tables(jnp.arange(s), cfg.head_dim, cfg.rope_theta) \
            if cfg.has_attention else None

        def body_fn(xc, xs):
            bp, lslice = xs
            y, (kv, ssm_final, aux) = tfm.block_full(
                bp, xc, cfg, rope_cs, lora=lslice, block_kv=block_kv,
                skip_masked_blocks=skip_masked_blocks,
                adapter_idx=adapter_idx)
            outs = (kv, ssm_final, aux) if collect_caches else (None, None, aux)
            return y, outs

        body_fn = tfm.remat_wrap(body_fn, cfg)
        scan = _scan_or_loop if not cfg.scan_layers else lax.scan

        if cfg.family is Family.VLM:
            vis = batch["vision"].astype(x.dtype)
            units, per = self._vlm_shape()

            def unit_fn(xc, xs):
                ublocks, ulora, ucross = xs

                def inner(xc2, xs2):
                    return body_fn(xc2, xs2)

                xc, outs = scan(inner, xc, (ublocks, ulora))
                vkv = tfm.vision_kv(ucross["attn"], vis, cfg)
                xc = tfm.cross_block(ucross, xc, vkv, cfg)
                couts = (vkv if collect_caches else None)
                return xc, (outs, couts)

            x, (outs, cross_kv) = scan(
                unit_fn, x, (params["blocks"], lora, params["cross"]))
            kvs, ssm_finals, auxs = outs
            aux = jnp.sum(auxs)
            caches = None
            if collect_caches:
                caches = {"kv": kvs, "cross_kv": cross_kv}
        else:
            x, (kvs, ssm_finals, auxs) = scan(
                body_fn, x, (params["blocks"], lora))
            aux = jnp.sum(auxs)
            caches = None
            if collect_caches:
                caches = {}
                if cfg.has_attention:
                    caches["kv"] = kvs
                if cfg.has_ssm:
                    caches["ssm"] = ssm_finals  # stacked {"conv","state"}
        hidden = rms_norm(x, params["final_norm"])
        return hidden, caches, aux

    # --------------------------------------------------------------- loss --
    def forward_loss(self, params, lora, batch, *, ce_chunk: int = 512,
                     block_kv: int = 512, skip_masked_blocks: bool = False):
        hidden, _, aux = self.hidden_states(
            params, lora, batch, block_kv=block_kv,
            skip_masked_blocks=skip_masked_blocks)
        loss, metrics = chunked_ce_loss(
            hidden, params["lm_head"], batch["labels"],
            batch["mask"].astype(jnp.float32), chunk=ce_chunk)
        metrics["aux_loss"] = aux
        total = loss + 0.01 * aux
        metrics["ce_loss"] = loss
        return total, metrics

    def logits(self, params, lora, batch):
        """Full-vocab logits for the whole sequence (smoke-scale only)."""
        hidden, _, _ = self.hidden_states(params, lora, batch)
        return hidden @ params["lm_head"]

    # ------------------------------------------------------------- caches --
    def init_caches(self, batch: int, seq: int, dtype=None) -> Dict:
        cfg = self.cfg
        dtype = dtype or jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
        hd, hkv = cfg.head_dim, cfg.n_kv_heads
        caches: Dict[str, Any] = {}
        if cfg.family is Family.VLM:
            units, per = self._vlm_shape()
            caches["kv"] = (
                jnp.zeros((units, per, batch, seq, hkv, hd), dtype),
                jnp.zeros((units, per, batch, seq, hkv, hd), dtype))
            caches["cross_kv"] = (
                jnp.zeros((units, batch, cfg.vision_tokens, hkv, hd), dtype),
                jnp.zeros((units, batch, cfg.vision_tokens, hkv, hd), dtype))
            return caches
        if cfg.has_attention:
            # sliding-window archs keep a ring buffer of window size —
            # this is what makes the long_500k hymba cell fit (21 GB of
            # flat cache would not).
            kv_seq = seq if cfg.sliding_window == 0 \
                else min(seq, cfg.sliding_window)
            caches["kv"] = (
                jnp.zeros((cfg.n_layers, batch, kv_seq, hkv, hd), dtype),
                jnp.zeros((cfg.n_layers, batch, kv_seq, hkv, hd), dtype))
        if cfg.has_ssm:
            c = mamba2.init_ssm_cache(cfg, batch, dtype,
                                      stacked=cfg.n_layers)
            caches["ssm"] = c._asdict()
        return caches

    def init_paged_caches(self, n_blocks: int, block_size: int,
                          dtype=None) -> Dict:
        """Global paged KV pool: [L, n_blocks, block_size, Hkv, Dh] per
        K/V.  Sequences map logical positions to pool blocks through
        per-slot block tables (see ``decode_step_paged``), so cache
        memory scales with allocated blocks, not slots * max_seq.
        Attention-only stacks: SSM/conv state is per-slot and tiny —
        paging it buys nothing."""
        cfg = self.cfg
        assert cfg.has_attention and not cfg.has_ssm \
            and cfg.family is not Family.VLM, \
            f"{cfg.name}: paged KV caches need an attention-only stack"
        dtype = dtype or jnp.dtype(cfg.kv_cache_dtype or cfg.dtype)
        shape = (cfg.n_layers, n_blocks, block_size, cfg.n_kv_heads,
                 cfg.head_dim)
        return {"kv": (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))}

    # -------------------------------------------------------------- prefill -
    def prefill(self, params, lora, batch, *, block_kv: int = 512,
                skip_masked_blocks: bool = False):
        """Process the prompt; returns (last-token logits, caches)."""
        cfg = self.cfg
        hidden, caches, _ = self.hidden_states(
            params, lora, batch, collect_caches=True, block_kv=block_kv,
            skip_masked_blocks=skip_masked_blocks)
        logits = hidden[:, -1:] @ params["lm_head"]
        out_caches: Dict[str, Any] = {}
        if caches and caches.get("kv") is not None:
            out_caches["kv"] = caches["kv"]
        if caches and caches.get("cross_kv") is not None:
            out_caches["cross_kv"] = caches["cross_kv"]
        if cfg.has_ssm and caches and caches.get("ssm") is not None:
            out_caches["ssm"] = caches["ssm"]  # conv tail + final SSD state
        return logits, out_caches

    def prefill_ragged(self, params, lora, batch, prompt_lens, *,
                       block_kv: int = 512,
                       skip_masked_blocks: bool = False,
                       adapter_idx=None):
        """Prefill right-padded ragged prompts in one batch.

        ``prompt_lens`` [B] int32 gives each row's true prompt length;
        logits are gathered at each row's *last real token* rather than
        the (padded) final position.  Valid for attention-only stacks:
        causal masking keeps pad tokens out of every real position's KV,
        and cache rows past ``prompt_lens`` are dead weight masked by the
        per-slot kv_len at decode time.  SSM/hybrid recurrences thread
        state through pads, so those families must prefill exact-length
        (see runtime/serving_loop.py)."""
        cfg = self.cfg
        assert not cfg.has_ssm, \
            f"{cfg.name}: ragged (padded) prefill breaks SSM recurrence"
        hidden, caches, _ = self.hidden_states(
            params, lora, batch, collect_caches=True, block_kv=block_kv,
            skip_masked_blocks=skip_masked_blocks,
            adapter_idx=adapter_idx)
        idx = (prompt_lens - 1).astype(jnp.int32)[:, None, None]
        last = jnp.take_along_axis(
            hidden, jnp.broadcast_to(idx, (hidden.shape[0], 1,
                                           hidden.shape[2])), axis=1)
        logits = last @ params["lm_head"]
        out_caches: Dict[str, Any] = {}
        if caches and caches.get("kv") is not None:
            out_caches["kv"] = caches["kv"]
        if caches and caches.get("cross_kv") is not None:
            out_caches["cross_kv"] = caches["cross_kv"]
        return logits, out_caches

    # ---------------------------------------------------------- slot ops ---
    def write_prefill_slot(self, pool_caches, prefill_caches, slot,
                           src=0):
        """Copy sequence ``src`` of a prefill cache into decode slot
        ``slot`` of a pool cache (continuous batching admission).

        Every non-VLM cache leaf is laid out [L, B, ...]; row ``src`` of
        the prefill leaf has trailing dims <= the pool's (shorter prompt
        into a longer slot), so a single dynamic_update_slice at batch
        index ``slot`` covers KV rings, conv tails and SSD states alike.
        Cache rows beyond the prompt keep stale bytes — never attended,
        because the slot's kv_len masks them until decode overwrites
        them in order."""
        assert self.cfg.family is not Family.VLM, \
            "VLM cache slots (units-leading layout) are future work"

        def write(pool, pre):
            row = lax.dynamic_slice_in_dim(
                pre, jnp.asarray(src, jnp.int32), 1, axis=1)
            start = (jnp.int32(0), jnp.asarray(slot, jnp.int32)) \
                + (jnp.int32(0),) * (pool.ndim - 2)
            return lax.dynamic_update_slice(
                pool, row.astype(pool.dtype), start)

        return jax.tree.map(write, pool_caches, prefill_caches)

    def write_prefill_slots(self, pool_caches, prefill_caches, slots):
        """Batched admission: scatter a whole prefill wave into its
        decode slots in ONE program (vs one ``write_prefill_slot`` call
        per request).  ``slots`` [W] int32 gives row j's target slot;
        rows flagged with slot id >= n_slots are dropped (requests that
        finished at admission).  Attention ragged-wave path only, so
        every leaf is a KV cache [L, B, S, Hkv, Dh]; wave rows shorter
        than the pool's seq dim are zero-padded — those rows are masked
        by the slot's kv_len until decode overwrites them in order."""
        assert self.cfg.family is not Family.VLM, \
            "VLM cache slots (units-leading layout) are future work"
        slots = jnp.asarray(slots, jnp.int32)

        def write(pool, pre):
            p, s = pre.shape[2], pool.shape[2]
            if p < s:
                widths = [(0, 0)] * pre.ndim
                widths[2] = (0, s - p)
                pre = jnp.pad(pre, widths)
            return pool.at[:, slots].set(pre.astype(pool.dtype),
                                         mode="drop")

        return jax.tree.map(write, pool_caches, prefill_caches)

    def write_prefill_blocks(self, pool_caches, prefill_caches,
                             wave_tables):
        """Batched paged admission: scatter a whole prefill wave's KV
        into freshly allocated pool blocks in ONE program.

        ``wave_tables`` [W, NBP] int32 maps wave row j's logical blocks
        to pool blocks; unused entries (short prompts, requests finished
        at admission) hold ``n_blocks`` and are dropped by the scatter.
        The wave's right-padded prefill [L, W, P, Hkv, Dh] is reshaped
        to block granularity, so the whole wave lands as one scatter
        per K/V pool leaf."""
        wave_tables = jnp.asarray(wave_tables, jnp.int32)
        nbp = wave_tables.shape[1]
        ids = wave_tables.reshape(-1)

        def write(pool, pre):
            nl, w, p = pre.shape[0], pre.shape[1], pre.shape[2]
            bs = pool.shape[2]
            assert p <= nbp * bs, \
                f"prefill len {p} exceeds wave table coverage {nbp * bs}"
            if p < nbp * bs:
                widths = [(0, 0)] * pre.ndim
                widths[2] = (0, nbp * bs - p)
                pre = jnp.pad(pre, widths)
            vals = pre.reshape(nl, w * nbp, bs, *pre.shape[3:])
            return pool.at[:, ids].set(vals.astype(pool.dtype),
                                       mode="drop")

        return jax.tree.map(write, pool_caches, prefill_caches)

    def prefill_ragged_suffix(self, params, lora, batch, suffix_lens,
                              prefix_lens, caches, prefix_tables,
                              adapter_idx=None):
        """Prefill only the uncached suffix of each prompt (prefix
        sharing over the paged pool).

        ``batch["tokens"]`` [W, SufPad] holds each row's right-padded
        suffix tokens (absolute positions ``prefix_lens[w] + i``);
        ``prefix_tables`` [W, NBpre] int32 names the pool blocks holding
        each row's cached block-aligned prefix (scratch-padded past
        ``prefix_lens[w]`` rows — those lanes are masked).  The prefix
        K/V are gathered from ``caches`` in-program, so the suffix
        attends over cached prefix + its own causal K/V and reproduces
        the full-prefill logits bit-for-bit.  Returns (logits at each
        row's last real suffix token [W,1,V], {"kv": suffix K/V
        [L, W, SufPad, Hkv, Dh]}) for ``write_prefill_blocks`` into the
        suffix's freshly allocated blocks."""
        cfg = self.cfg
        assert cfg.has_attention and not cfg.has_ssm \
            and cfg.family is not Family.VLM, \
            f"{cfg.name}: suffix prefill needs an attention-only stack"
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0)
        x = shard(x, "batch", "act_seq", "embed")
        prefix_lens = jnp.asarray(prefix_lens, jnp.int32)
        suffix_lens = jnp.asarray(suffix_lens, jnp.int32)
        positions = prefix_lens[:, None] + jnp.arange(tokens.shape[1])
        rope_cs = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        tables = jnp.asarray(prefix_tables, jnp.int32)
        w, nbpre = tables.shape

        def gather(pool):
            out = jnp.take(pool, tables.reshape(-1), axis=1)
            return out.reshape(pool.shape[0], w,
                               nbpre * pool.shape[2], *pool.shape[3:])

        prefix_kv = (gather(caches["kv"][0]), gather(caches["kv"][1]))

        def body(xc, xs):
            bp, lsl, pre = xs
            y, kv = tfm.block_prefill_suffix(bp, xc, cfg, pre,
                                             prefix_lens, rope_cs,
                                             lora=lsl,
                                             adapter_idx=adapter_idx)
            return y, kv

        scan = _scan_or_loop if not cfg.scan_layers else lax.scan
        x, kvs = scan(body, x, (params["blocks"], lora, prefix_kv))
        hidden = rms_norm(x, params["final_norm"])
        idx = (suffix_lens - 1).astype(jnp.int32)[:, None, None]
        last = jnp.take_along_axis(
            hidden, jnp.broadcast_to(idx, (hidden.shape[0], 1,
                                           hidden.shape[2])), axis=1)
        logits = last @ params["lm_head"]
        return logits, {"kv": kvs}

    def prefill_ragged_continue(self, params, lora, batch, suffix_lens,
                                prefix_lens, caches, slot_ids,
                                adapter_idx=None):
        """Resumable chunked prefill over CONTIGUOUS slot caches: one
        fixed-budget chunk per call, attending over the K/V the slot's
        earlier chunks already wrote.

        ``batch["tokens"]`` [W, CPad] holds each row's right-padded
        chunk tokens (absolute positions ``prefix_lens[w] + i``);
        ``slot_ids`` [W] int32 names each row's decode slot, whose cache
        rows ``0 .. prefix_lens[w]-1`` hold the prefix written by chunks
        1..K-1 (rows past that are stale and masked).  Same dense-mirror
        softmax as ``prefill_ragged_suffix``, so chunked prefill
        reproduces the monolithic logits bit-for-bit.  Returns (logits
        at each row's last real chunk token [W,1,V], {"kv": chunk K/V
        [L, W, CPad, Hkv, Dh]}) for ``write_prefill_rows`` back into the
        slots at offset ``prefix_lens``."""
        cfg = self.cfg
        assert cfg.has_attention and not cfg.has_ssm \
            and cfg.family is not Family.VLM, \
            f"{cfg.name}: chunked prefill needs an attention-only stack"
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0)
        x = shard(x, "batch", "act_seq", "embed")
        prefix_lens = jnp.asarray(prefix_lens, jnp.int32)
        suffix_lens = jnp.asarray(suffix_lens, jnp.int32)
        positions = prefix_lens[:, None] + jnp.arange(tokens.shape[1])
        rope_cs = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
        slots = jnp.asarray(slot_ids, jnp.int32)

        def gather(cache):
            # [L, B, S, Hkv, Dh] -> this wave's slot rows [L, W, S, ...]
            return jnp.take(cache, slots, axis=1)

        prefix_kv = (gather(caches["kv"][0]), gather(caches["kv"][1]))

        def body(xc, xs):
            bp, lsl, pre = xs
            y, kv = tfm.block_prefill_suffix(bp, xc, cfg, pre,
                                             prefix_lens, rope_cs,
                                             lora=lsl,
                                             adapter_idx=adapter_idx)
            return y, kv

        scan = _scan_or_loop if not cfg.scan_layers else lax.scan
        x, kvs = scan(body, x, (params["blocks"], lora, prefix_kv))
        hidden = rms_norm(x, params["final_norm"])
        idx = (suffix_lens - 1).astype(jnp.int32)[:, None, None]
        last = jnp.take_along_axis(
            hidden, jnp.broadcast_to(idx, (hidden.shape[0], 1,
                                           hidden.shape[2])), axis=1)
        logits = last @ params["lm_head"]
        return logits, {"kv": kvs}

    def write_prefill_rows(self, pool_caches, prefill_caches, slots,
                           offsets, lens):
        """Scatter one chunked-prefill wave's K/V into contiguous slot
        caches at each row's resume offset, in ONE program.

        ``slots``/``offsets``/``lens`` [W] int32: row j's chunk K/V
        [L, W, CPad, Hkv, Dh] lands at cache rows
        ``offsets[j] .. offsets[j]+lens[j]-1`` of slot ``slots[j]``;
        pad positions past ``lens[j]`` are pushed out of range and
        dropped, as are rows flagged with slot id >= n_slots."""
        slots = jnp.asarray(slots, jnp.int32)
        offsets = jnp.asarray(offsets, jnp.int32)
        lens = jnp.asarray(lens, jnp.int32)

        def write(pool, pre):
            c, s = pre.shape[2], pool.shape[2]
            pos = offsets[:, None] + jnp.arange(c)            # [W, C]
            pos = jnp.where(jnp.arange(c)[None, :] < lens[:, None],
                            pos, s)                           # pads -> drop
            # slots[:,None] broadcasts with pos at adjacent axes 1,2, so
            # the result matches pre's [L, W, C, Hkv, Dh] layout
            return pool.at[:, slots[:, None], pos].set(
                pre.astype(pool.dtype), mode="drop")

        return jax.tree.map(write, pool_caches, prefill_caches)

    def copy_blocks(self, paged_caches, src_ids, dst_ids):
        """Copy-on-write: duplicate whole pool blocks ``dst := src`` in
        ONE gather+scatter per K/V leaf.  The runtime batches every COW
        of a tick (shared block about to take a decode write) into one
        call."""
        src = jnp.asarray(src_ids, jnp.int32)
        dst = jnp.asarray(dst_ids, jnp.int32)

        def cp(pool):
            return pool.at[:, dst].set(jnp.take(pool, src, axis=1))

        k, v = paged_caches["kv"]
        return {"kv": (cp(k), cp(v))}

    def gather_blocks(self, paged_caches, ids):
        """Preemption swap-out: pull whole pool blocks ``ids`` out of
        the pool in ONE gather per K/V leaf — the device half of a
        batched device->host copy (the caller ``device_get``s the
        result).  Pad entries may repeat a real id (e.g. 0/scratch);
        the host side slices the real rows off."""
        ids = jnp.asarray(ids, jnp.int32)

        def g(pool):
            return jnp.take(pool, ids, axis=1)

        k, v = paged_caches["kv"]
        return {"kv": (g(k), g(v))}

    def scatter_blocks(self, paged_caches, ids, host_kv):
        """Preemption swap-in: land host-side block contents back into
        freshly taken pool blocks ``ids`` in ONE scatter per K/V leaf.
        Pad entries hold ``n_blocks`` and are dropped, so one bucketed
        program shape serves every restore width."""
        ids = jnp.asarray(ids, jnp.int32)

        def s(pool, vals):
            return pool.at[:, ids].set(
                jnp.asarray(vals).astype(pool.dtype), mode="drop")

        k, v = paged_caches["kv"]
        hk, hv = host_kv
        return {"kv": (s(k, hk), s(v, hv))}

    # --------------------------------------------------------------- decode -
    def decode_step(self, params, lora, caches, token, pos, *,
                    attn_backend: Optional[str] = None,
                    adapter_idx=None):
        """One decode step.  token: [B,1] int32; pos: scalar int32 (next
        write position, shared) or [B] int32 (per-sequence positions —
        ragged decode slots for continuous batching).  ``attn_backend``
        (static) picks the decode-attention path — Pallas on TPU, jnp
        elsewhere.  ``adapter_idx`` [B] int32 (optional) selects each
        row's adapter slot from a STACKED multi-adapter ``lora`` tree
        (leaves [L, A, din, r]; < 0 = base only) — the multi-tenant
        decode wave.  Returns (logits [B,1,V], updated caches)."""
        cfg = self.cfg
        pos = jnp.asarray(pos)
        x = jnp.take(params["embed"], token, axis=0)
        x = shard(x, "batch", None, "embed")
        rope_cs = None
        if cfg.has_attention:
            # scalar pos -> [1, Dh/2] tables broadcast over the batch;
            # vector pos -> [B, 1, Dh/2] per-sequence tables
            rope_pos = pos[None] if pos.ndim == 0 else pos[:, None]
            rope_cs = rope_tables(rope_pos, cfg.head_dim, cfg.rope_theta)

        scan = _scan_or_loop if not cfg.scan_layers else lax.scan

        if cfg.family is Family.VLM:
            units, per = self._vlm_shape()

            def unit_fn(xc, xs):
                ublocks, ulora, ucross, ukv, uckv = xs

                def inner(xc2, xs2):
                    bp, lsl, kvl = xs2
                    y, nc = tfm.block_decode(bp, xc2, cfg, {"kv": kvl},
                                             pos, rope_cs, lora=lsl,
                                             backend=attn_backend)
                    return y, nc["kv"]

                xc, new_kv = scan(inner, xc, (ublocks, ulora, ukv))
                xc = tfm.cross_block(ucross, xc, uckv, cfg)
                return xc, new_kv

            x, new_kv = scan(
                unit_fn, x, (params["blocks"], lora, params["cross"],
                             caches["kv"], caches["cross_kv"]))
            new_caches = {"kv": new_kv, "cross_kv": caches["cross_kv"]}
        else:
            def body(xc, xs):
                bp, lsl, cache_l = xs
                y, nc = tfm.block_decode(bp, xc, cfg, cache_l, pos,
                                         rope_cs, lora=lsl,
                                         backend=attn_backend,
                                         adapter_idx=adapter_idx)
                return y, nc

            cache_tree = {}
            if cfg.has_attention:
                cache_tree["kv"] = caches["kv"]
            if cfg.has_ssm:
                cache_tree["ssm"] = caches["ssm"]
            x, new_caches = scan(body, x,
                                 (params["blocks"], lora, cache_tree))
        hidden = rms_norm(x, params["final_norm"])
        logits = hidden @ params["lm_head"]
        return logits, new_caches

    def decode_step_paged(self, params, lora, caches, token, pos,
                          block_tables, *, ring_len: int = 0,
                          attn_backend: Optional[str] = None,
                          adapter_idx=None):
        """One decode step over the paged KV pool.

        caches: ``init_paged_caches`` tree; token: [B,1] int32; pos: [B]
        int32 absolute positions (RoPE uses these); block_tables:
        [B, NB] int32 (entries past a sequence's live blocks must point
        at a valid pool block — the runtime keeps them at scratch block
        0).  ``ring_len`` (static) is the logical cache length for
        sliding-window archs: writes wrap at ``ring_len`` exactly like
        the contiguous ring buffer, so greedy outputs are identical; 0
        means no wrap (full attention, table covers the whole budget).
        Returns (logits [B,1,V], updated caches)."""
        cfg = self.cfg
        assert cfg.has_attention and not cfg.has_ssm \
            and cfg.family is not Family.VLM, \
            f"{cfg.name}: paged decode needs an attention-only stack"
        k_pool = caches["kv"][0]
        bs = k_pool.shape[2]
        block_tables = jnp.asarray(block_tables, jnp.int32)
        pos = jnp.asarray(pos)
        if pos.ndim == 0:
            pos = jnp.full((token.shape[0],), pos, jnp.int32)
        rl = ring_len if ring_len else block_tables.shape[1] * bs
        wpos = jnp.remainder(pos, rl)
        kv_len = jnp.minimum(pos + 1, rl)
        write_block = jnp.take_along_axis(
            block_tables, (wpos // bs)[:, None], axis=1)[:, 0]
        write_off = wpos % bs

        x = jnp.take(params["embed"], token, axis=0)
        x = shard(x, "batch", None, "embed")
        rope_cs = rope_tables(pos[:, None], cfg.head_dim, cfg.rope_theta)
        scan = _scan_or_loop if not cfg.scan_layers else lax.scan

        def body(xc, xs):
            bp, lsl, pool_l = xs
            y, new_pool = tfm.block_decode_paged(
                bp, xc, cfg, pool_l, rope_cs, block_tables, write_block,
                write_off, kv_len, lora=lsl, backend=attn_backend,
                adapter_idx=adapter_idx)
            return y, new_pool

        x, new_kv = scan(body, x, (params["blocks"], lora, caches["kv"]))
        hidden = rms_norm(x, params["final_norm"])
        logits = hidden @ params["lm_head"]
        return logits, {"kv": new_kv}

    # ---------------------------------------------------------- input specs -
    def input_specs(self, cell: ShapeCell) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a cell —
        weak-type-correct, shardable, no device allocation."""
        cfg = self.cfg
        b, s = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        act = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct
        if cell.kind == "train":
            batch = {}
            if cfg.encoder_only:
                batch["embeds"] = sds((b, s, cfg.d_model), act)
            else:
                batch["tokens"] = sds((b, s), i32)
            batch["labels"] = sds((b, s), i32)
            batch["mask"] = sds((b, s), jnp.float32)
            if cfg.family is Family.VLM:
                batch["vision"] = sds((b, cfg.vision_tokens, cfg.d_model), act)
            return {"batch": batch}
        if cell.kind == "prefill":
            batch = {}
            if cfg.encoder_only:
                batch["embeds"] = sds((b, s, cfg.d_model), act)
            else:
                batch["tokens"] = sds((b, s), i32)
            if cfg.family is Family.VLM:
                batch["vision"] = sds((b, cfg.vision_tokens, cfg.d_model), act)
            return {"batch": batch}
        # decode: one new token against caches of length seq
        caches = jax.eval_shape(lambda: self.init_caches(b, s))
        return {
            "caches": caches,
            "token": sds((b, 1), i32),
            "pos": sds((), i32),
        }

    def param_specs(self) -> Dict:
        return jax.eval_shape(lambda: self.init(jax.random.key(0)))

    def lora_specs(self) -> Dict:
        return jax.eval_shape(lambda: self.init_lora(jax.random.key(0)))


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
