"""Core neural layers in pure JAX: norms, RoPE, GQA attention (dense,
blockwise/flash-equivalent, decode), SwiGLU/GeLU MLPs.

All functions are parameter-dict based (no framework).  Weight matrices use
the ``[in, out]`` convention; stacked-layer params carry a leading ``L``
dim and are consumed through ``jax.lax.scan``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.sharding import shard, shard_map_compat


# ---------------------------------------------------------------- norms ----
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


# ----------------------------------------------------------------- RoPE ----
def rope_tables(positions: jax.Array, head_dim: int, theta: float):
    """cos/sin tables for given positions [..., S] -> [..., S, head_dim/2]."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B,S,H,D]; cos/sin: [S,D/2] or [B,S,D/2] (broadcast over heads)."""
    if cos.ndim == 2:
        cos, sin = cos[None], sin[None]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------ attention ----
def _gqa_repeat(k, n_heads: int):
    """[B,S,Hkv,D] -> [B,S,Hq,D] by repeating KV heads.

    The jnp attention paths use the repeated-KV formulation instead of
    grouped reshapes: a reshape like 48 -> (8, 6) of a 16-way-sharded
    head dim is not expressible in GSPMD and forces all-gathers, while
    the repeat output simply inherits the q head sharding (the source
    read stays Hkv-sized).  The Pallas kernels keep the grouped form —
    in VMEM the repeat would be real memory traffic.
    """
    g = n_heads // k.shape[2]
    if g == 1:
        return k
    return jnp.repeat(k, g, axis=2)


def attention_dense(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset=0, kv_len: Optional[jax.Array] = None,
                    scale: Optional[float] = None):
    """Reference GQA attention (materializes full score matrix).

    q: [B,Sq,Hq,D]; k,v: [B,Skv,Hkv,D].  ``q_offset`` is the absolute
    position of q[0] (decode).  ``kv_len`` masks positions >= kv_len.
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    k = _gqa_repeat(k, hq)
    v = _gqa_repeat(v, hq)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    if kv_len is not None:
        mask = mask & (kpos[None, :] < jnp.asarray(kv_len)[..., None, None]) \
            if jnp.ndim(kv_len) else mask & (kpos[None, :] < kv_len)
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def attention_blockwise(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset: int = 0, block_kv: int = 512,
                        scale: Optional[float] = None,
                        skip_masked_blocks: bool = False,
                        unroll: bool = False):
    """Flash-equivalent attention: lax.scan over KV blocks with online
    softmax.  Memory O(Sq * block_kv) instead of O(Sq * Skv).

    With ``skip_masked_blocks`` (beyond-paper optimization, see
    EXPERIMENTS.md §Perf) the scan runs only over the lower-triangular
    (q-block, kv-block) pairs, halving attention FLOPs for causal prefill.
    """
    b, sq, hq, d = q.shape
    skv = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    k = _gqa_repeat(k, hq)
    v = _gqa_repeat(v, hq)
    nkv = -(-skv // block_kv)
    pad = nkv * block_kv - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nkv, block_kv, hq, d)
    vb = v.reshape(b, nkv, block_kv, hq, d)
    qf = q                                           # [B,Sq,H,D]
    qpos = q_offset + jnp.arange(sq)

    if not skip_masked_blocks:
        def body(carry, xs):
            m, l, acc = carry
            kblk, vblk, jblk = xs
            kpos = jblk * block_kv + jnp.arange(block_kv)
            # bf16 operands, f32 accumulation (flash-kernel numerics):
            # no f32 copies of q/k/v stream through HBM
            s = jnp.einsum("bqhd,bkhd->bqhk", qf, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = kpos[None, :] < skv
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= (qpos[:, None] - kpos[None, :]) < window
            s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard rows where everything is masked so far
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
            corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bqhk,bkhd->bqhd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((b, sq, hq), -jnp.inf, jnp.float32),
                jnp.zeros((b, sq, hq), jnp.float32),
                jnp.zeros((b, sq, hq, d), jnp.float32))
        if unroll:
            # cost-calibration path: XLA's cost analysis counts scan
            # bodies once, so the dry-run unrolls the KV-block loop
            carry = init
            for j in range(nkv):
                carry, _ = body(carry, (kb[:, j], vb[:, j], jnp.int32(j)))
            m, l, acc = carry
        else:
            (m, l, acc), _ = lax.scan(
                body, init,
                (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nkv)))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    # --- triangular (block-skipping) variant: scan over valid (i,j) pairs ---
    assert causal and q_offset == 0 and sq == skv, \
        "block skipping is for causal self-attention prefill"
    bq = block_kv
    nq = -(-sq // bq)
    qpad = nq * bq - sq
    qb = qf if not qpad else jnp.pad(
        qf, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    qb = qb.reshape(b, nq, bq, hq, d)
    if window > 0:
        wblocks = -(-window // bq) + 1
        pairs = [(i, j) for i in range(nq) for j in range(nq)
                 if j <= i and i - j < wblocks]
    else:
        pairs = [(i, j) for i in range(nq) for j in range(i + 1)]
    ii = jnp.array([p[0] for p in pairs])
    jj = jnp.array([p[1] for p in pairs])

    def body(carry, xs):
        m, l, acc = carry                     # [B,nq,bq,H(,D)]
        i, j = xs
        qi = lax.dynamic_index_in_dim(qb, i, axis=1, keepdims=False)
        kj = lax.dynamic_index_in_dim(kb, j, axis=1, keepdims=False)
        vj = lax.dynamic_index_in_dim(vb, j, axis=1, keepdims=False)
        qpos_i = i * bq + jnp.arange(bq)
        kpos_j = j * bq + jnp.arange(block_kv)
        s = jnp.einsum("bqhd,bkhd->bqhk", qi, kj,
                       preferred_element_type=jnp.float32) * scale
        mask = (kpos_j[None, :] <= qpos_i[:, None]) & (kpos_j[None, :] < skv)
        if window > 0:
            mask &= (qpos_i[:, None] - kpos_j[None, :]) < window
        s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
        mi = lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        li = lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        acci = lax.dynamic_index_in_dim(acc, i, 1, keepdims=False)
        m_new = jnp.maximum(mi, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
        corr = jnp.where(jnp.isinf(mi), 0.0, jnp.exp(mi - m_safe))
        l_new = li * corr + jnp.sum(p, axis=-1)
        acc_new = acci * corr[..., None] + jnp.einsum(
            "bqhk,bkhd->bqhd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        m = lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        acc = lax.dynamic_update_index_in_dim(acc, acc_new, i, 1)
        return (m, l, acc), None

    init = (jnp.full((b, nq, bq, hq), -jnp.inf, jnp.float32),
            jnp.zeros((b, nq, bq, hq), jnp.float32),
            jnp.zeros((b, nq, bq, hq, d), jnp.float32))
    if unroll:
        carry = init
        for i, j in pairs:
            carry, _ = body(carry, (jnp.int32(i), jnp.int32(j)))
        m, l, acc = carry
    else:
        (m, l, acc), _ = lax.scan(body, init, (ii, jj))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.reshape(b, nq * bq, hq, d)[:, :sq]
    return out.astype(q.dtype)


def attention_prefix_suffix(q, k_pre, v_pre, k_suf, v_suf, prefix_len, *,
                            window: int = 0,
                            scale: Optional[float] = None):
    """Suffix-prefill attention: suffix queries attend over a cached
    (gathered) prefix's K/V plus the suffix's own causal K/V.

    q, k_suf, v_suf: [B, Sq, H*, D] — the uncached suffix, row ``i`` at
    absolute position ``prefix_len[b] + i``; k_pre, v_pre:
    [B, Pp, Hkv, D] — prefix K/V gathered from pool blocks, positions
    ``0 .. Pp-1``, valid where ``< prefix_len[b]`` (rows past a
    sequence's real prefix are other blocks' garbage and are masked).
    Mirrors ``attention_dense``'s score/softmax formulation exactly:
    masked lanes contribute exact zeros, so a request prefilled as
    (cached prefix + suffix) reproduces the full-prefill logits
    bit-for-bit."""
    b, sq, hq, d = q.shape
    pp = k_pre.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    k = jnp.concatenate([k_pre.astype(q.dtype), k_suf], axis=1)
    v = jnp.concatenate([v_pre.astype(q.dtype), v_suf], axis=1)
    k = _gqa_repeat(k, hq)
    v = _gqa_repeat(v, hq)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    plen = jnp.asarray(prefix_len, jnp.int32)
    qpos = plen[:, None] + jnp.arange(sq)                    # [B, Sq]
    kpos = jnp.concatenate(
        [jnp.broadcast_to(jnp.arange(pp), (b, pp)),
         plen[:, None] + jnp.arange(sq)], axis=1)            # [B, Pp+Sq]
    mask = kpos[:, None, :] <= qpos[:, :, None]              # causal
    mask &= jnp.concatenate(
        [jnp.arange(pp)[None, :] < plen[:, None],            # real prefix
         jnp.ones((b, sq), bool)], axis=1)[:, None, :]
    if window > 0:
        mask &= (qpos[:, :, None] - kpos[:, None, :]) < window
    scores = jnp.where(mask[:, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def resolve_decode_backend(backend: Optional[str]) -> str:
    """Resolve a decode-attention backend name.

    ``None``/"auto" picks the Pallas kernel on TPU and the jnp path
    everywhere else; "pallas" / "interpret" / "jnp" force a path (tests
    force "interpret" to exercise the kernel on CPU).  The choice is an
    explicit (static) argument through the decode stack rather than an
    env read at trace time, so jitted programs cache per backend.
    """
    if backend is None or backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in ("pallas", "interpret", "jnp"):
        raise ValueError(f"unknown decode backend {backend!r}")
    return backend


def attention_decode(q, k_cache, v_cache, kv_len, *, window: int = 0,
                     scale: Optional[float] = None,
                     backend: Optional[str] = None):
    """Single-token decode attention over a KV cache.

    q: [B,1,Hq,D]; caches: [B,S,Hkv,D]; kv_len: [B] or scalar — number of
    valid cache entries (the new token's KV must already be written).

    ``backend`` (see ``resolve_decode_backend``) dispatches to the Pallas
    kernel when the masking is expressible as a pure ``kv_len`` prefix
    (``window == 0`` here — ring-buffer callers already fold the window
    into ``kv_len``): the contiguous cache is viewed as a block pool with
    an identity block table, so one kernel serves both layouts.
    """
    b, _, hq, d = q.shape
    s = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    backend = resolve_decode_backend(backend)
    if backend in ("pallas", "interpret") and window == 0:
        from repro.kernels.decode_attention import paged_decode_attention
        hkv = k_cache.shape[2]
        bk = next(bk for bk in (256, 128, 64, 32, 16, 8, 4, 2, 1)
                  if s % bk == 0)
        nk = s // bk
        kp = k_cache.reshape(b * nk, bk, hkv, d)
        vp = v_cache.reshape(b * nk, bk, hkv, d)
        tables = (jnp.arange(b, dtype=jnp.int32)[:, None] * nk
                  + jnp.arange(nk, dtype=jnp.int32)[None, :])
        klen = jnp.asarray(kv_len)
        if klen.ndim == 0:
            klen = jnp.full((b,), klen)
        out = paged_decode_attention(q[:, 0], kp, vp, tables, klen,
                                     scale=scale,
                                     interpret=backend == "interpret")
        return out[:, None].astype(q.dtype)
    kr = _gqa_repeat(k_cache, hq)
    vr = _gqa_repeat(v_cache, hq)
    scores = jnp.einsum("bhd,bkhd->bhk", q[:, 0].astype(jnp.float32),
                        kr.astype(jnp.float32)) * scale
    kpos = jnp.arange(s)
    klen = jnp.asarray(kv_len)
    if klen.ndim == 0:
        klen = jnp.full((b,), klen)
    mask = kpos[None, :] < klen[:, None]                 # [B,S]
    if window > 0:
        mask &= kpos[None, :] >= (klen[:, None] - window)
    scores = jnp.where(mask[:, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhk,bkhd->bhd", probs, vr.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)


def attention_decode_paged(q, k_pool, v_pool, block_tables, kv_len, *,
                           scale: Optional[float] = None,
                           backend: Optional[str] = None):
    """Single-token decode attention over a paged KV cache.

    q: [B,1,Hq,D]; pools: [n_blocks, block_size, Hkv, D] (one layer's
    slice of the global block pool); block_tables: [B, NB] int32 mapping
    each sequence's logical blocks to pool blocks; kv_len: [B] valid
    logical length.  Pallas backends walk the table block-by-block; the
    jnp fallback gathers the logical [B, NB*bs] view and reuses the
    contiguous ``attention_decode`` math (identical masking, so paged
    and contiguous runtimes agree to numerical identity).
    """
    backend = resolve_decode_backend(backend)
    klen = jnp.asarray(kv_len)
    if klen.ndim == 0:
        klen = jnp.full((q.shape[0],), klen)
    if backend in ("pallas", "interpret"):
        from repro.kernels.decode_attention import paged_decode_attention
        out = paged_decode_attention(q[:, 0], k_pool, v_pool,
                                     block_tables, klen, scale=scale,
                                     interpret=backend == "interpret")
        return out[:, None].astype(q.dtype)
    b = q.shape[0]
    nb = block_tables.shape[1]
    bs = k_pool.shape[1]
    k = jnp.take(k_pool, block_tables, axis=0).reshape(
        b, nb * bs, *k_pool.shape[2:])
    v = jnp.take(v_pool, block_tables, axis=0).reshape(
        b, nb * bs, *v_pool.shape[2:])
    return attention_decode(q, k, v, klen, scale=scale, backend="jnp")


def attention_decode_seqsharded(q, k_new, v_new, k_cache, v_cache, pos, *,
                                scale: Optional[float] = None):
    """Sequence-sharded flash-decode via shard_map (beyond-paper
    optimization, EXPERIMENTS.md §Perf).

    Each shard of the mesh axis carrying ``kv_seq`` owns a contiguous
    slice of the cache: it writes the new token's K/V locally (no
    collective — the naive dynamic-update-slice on a sharded dim makes
    GSPMD reshard the whole cache) and computes grouped-GQA partial
    attention over its slice; the only cross-shard traffic is the
    online-softmax reduction — pmax of m [B,Hkv,G] and psum of
    (l, acc) [B,Hkv,G(,D)], a few MB instead of the cache size.

    q/k_new/v_new: [B,1,H*,D]; caches: [B,S,Hkv,D] (S sharded);
    pos: scalar int32.  Returns (out [B,1,Hq,D], new_k, new_v).
    """
    from repro.models.sharding import current_mesh, current_rules
    mesh = current_mesh()
    rules = current_rules()
    seq_ax = rules.kv_seq
    b, s, hkv, d = k_cache.shape
    hq = q.shape[2]
    g = hq // hkv
    scale_ = scale if scale is not None else 1.0 / math.sqrt(d)
    from jax.sharding import PartitionSpec as P
    # keep only mesh-present axes (single-pod mesh has no "pod")
    raw = rules.kv_batch
    raw = raw if isinstance(raw, tuple) else (raw,)
    batch_ax = tuple(a for a in raw if a in mesh.shape) or None
    n_seq = mesh.shape[seq_ax]

    def body(q_, kn, vn, kc, vc, pos_):
        idx = lax.axis_index(seq_ax)
        s_loc = kc.shape[1]
        start = idx * s_loc
        loc = pos_ - start
        in_range = (loc >= 0) & (loc < s_loc)
        loc_c = jnp.clip(loc, 0, s_loc - 1)
        # slot-masked write: out-of-range shards rewrite the old slot
        # value — the DUS stays in-place (one slot of traffic), no
        # full-slice `where` copy
        old_k = lax.dynamic_slice_in_dim(kc, loc_c, 1, axis=1)
        old_v = lax.dynamic_slice_in_dim(vc, loc_c, 1, axis=1)
        kn_eff = jnp.where(in_range, kn.astype(kc.dtype), old_k)
        vn_eff = jnp.where(in_range, vn.astype(vc.dtype), old_v)
        kc = lax.dynamic_update_slice_in_dim(kc, kn_eff, loc_c, axis=1)
        vc = lax.dynamic_update_slice_in_dim(vc, vn_eff, loc_c, axis=1)

        bl = q_.shape[0]
        qg = q_[:, 0].reshape(bl, hkv, g, d)
        # bf16 operands, f32 accumulation — no f32 cache copies.
        # f8 caches (kv_cache_dtype) upcast to the q dtype at the slice.
        kc_m = kc if kc.dtype == qg.dtype else kc.astype(qg.dtype)
        sc = jnp.einsum("bhgd,bshd->bhgs", qg, kc_m,
                        preferred_element_type=jnp.float32) * scale_
        kpos = start + jnp.arange(s_loc)
        mask = kpos <= pos_
        sc = jnp.where(mask[None, None, None, :], sc, -jnp.inf)
        m_loc = jnp.max(sc, axis=-1)
        m_glob = lax.pmax(m_loc, seq_ax)
        m_safe = jnp.where(jnp.isinf(m_glob), 0.0, m_glob)
        p = jnp.where(mask[None, None, None, :],
                      jnp.exp(sc - m_safe[..., None]), 0.0)
        l_loc = jnp.sum(p, axis=-1)
        vc_m = vc if vc.dtype == qg.dtype else vc.astype(qg.dtype)
        acc_loc = jnp.einsum("bhgs,bshd->bhgd",
                             p.astype(qg.dtype), vc_m,
                             preferred_element_type=jnp.float32)
        l = lax.psum(l_loc, seq_ax)
        acc = lax.psum(acc_loc, seq_ax)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return (out.reshape(bl, 1, hq, d).astype(q_.dtype), kc, vc)

    pq = P(batch_ax, None, None, None)
    pc = P(batch_ax, seq_ax, None, None)
    out, new_k, new_v = shard_map_compat(
        body, mesh=mesh,
        in_specs=(pq, pq, pq, pc, pc, P()),
        out_specs=(pq, pc, pc),
    )(q, k_new, v_new, k_cache, v_cache, pos)
    return out, new_k, new_v


def attention_auto(q, k, v, **kw):
    """Pick dense vs blockwise by sequence length."""
    if q.shape[1] * k.shape[1] <= 1024 * 1024:
        kw.pop("block_kv", None)
        kw.pop("skip_masked_blocks", None)
        return attention_dense(q, k, v, **kw)
    return attention_blockwise(q, k, v, **kw)


# ----------------------------------------------------------------- MLPs ----
def swiglu(x, wg, wu, wd):
    h = jax.nn.silu(x @ wg) * (x @ wu)
    h = shard(h, "batch", "seq", "ff")
    return h @ wd


def gelu_mlp(x, wi, bi, wo, bo):
    h = jax.nn.gelu(x @ wi + bi)
    h = shard(h, "batch", "seq", "ff")
    return h @ wo + bo


# ----------------------------------------------------------------- init ----
def dense_init(key, d_in, d_out, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)
