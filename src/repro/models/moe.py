"""Mixture-of-Experts MLP with GShard/Switch-style capacity dispatch.

Routing is computed per *group* (a contiguous block of tokens) so the
dispatch/combine one-hot tensors stay O(group² · cf) instead of O(T²);
groups are sharded over the data axis and experts over the model axis
(EP, ``moe_shard="ep"``) or the per-expert ff dim over the model axis
(TP, ``moe_shard="tp"`` — grok's 8 experts don't divide a 16-way axis).

The GSPMD partitioner turns the dispatch einsum into the expected
all-to-all traffic; the dry-run's collective-bytes parse confirms it.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init
from repro.models.sharding import shard, shard_map_compat


class MoEParams(NamedTuple):
    router: jax.Array  # [D, E]
    wg: jax.Array      # [E, D, F]
    wu: jax.Array      # [E, D, F]
    wd: jax.Array      # [E, F, D]


def init_moe(key, cfg: ModelConfig) -> MoEParams:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    init = lambda k, di, do: (
        jax.random.normal(k, (e, di, do), jnp.float32)
        / math.sqrt(di)).astype(dtype)
    return MoEParams(
        router=dense_init(ks[0], d, e, jnp.float32),
        wg=init(ks[1], d, f), wu=init(ks[2], d, f), wd=init(ks[3], f, d))


def _routing(logits: jax.Array, top_k: int, capacity: int
             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """GShard top-k routing with per-expert capacity.

    logits: [G, T, E].  Returns (dispatch [G,T,E,C] bool-ish,
    combine [G,T,E,C], aux_loss scalar).
    """
    g, t, e = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)      # [G,T,K]
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=1)                           # [G,E]
    top1 = jax.nn.one_hot(gate_idx[..., 0], e)
    ce = jnp.mean(top1, axis=1)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * e

    # slot ordering: token-major, slot-minor priority
    oh = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)      # [G,T,K,E]
    oh_flat = oh.transpose(0, 2, 1, 3).reshape(g, top_k * t, e)
    # priority: slot-0 of every token first (GShard), then slot-1, ...
    pos = jnp.cumsum(oh_flat, axis=1) - oh_flat            # [G,K*T,E]
    pos = jnp.sum(pos * oh_flat, axis=-1)                  # [G,K*T]
    keep = pos < capacity
    pos_k = pos.reshape(g, top_k, t).transpose(0, 2, 1)    # [G,T,K]
    keep_k = keep.reshape(g, top_k, t).transpose(0, 2, 1)

    disp_oh = jax.nn.one_hot(pos_k, capacity, dtype=jnp.float32)  # [G,T,K,C]
    gate_keep = gate_vals * keep_k
    # combine[G,T,E,C] = sum_k gate * onehot(expert) * onehot(pos)
    combine = jnp.einsum("gtke,gtkc->gtec",
                         oh.astype(jnp.float32) *
                         gate_keep[..., None], disp_oh)
    dispatch = jnp.einsum("gtke,gtkc->gtec",
                          oh.astype(jnp.float32) * keep_k[..., None],
                          disp_oh)
    return dispatch, combine, aux


def moe_decode_shardmap(params: MoEParams, x: jax.Array, cfg: ModelConfig
                        ) -> Tuple[jax.Array, jax.Array]:
    """Explicit-SPMD MoE for small-token (decode) steps.

    With ≤ a few hundred tokens, token activations are tiny (~MBs) while
    expert weights are GBs/device-slice; GSPMD's einsum partitioning
    gathers weights over the data axis (§Perf iteration 3, refuted).
    This shard_map keeps every weight slice resident: tokens are
    replicated, each device contracts its (D-slice × F-slice) block, and
    only capacity-sized f32 partials cross the mesh (psum over data for
    the up-projections, psum over model for the down-projection).
    Works for both expert layouts: EP (experts over model) and TP
    (per-expert ff over model).
    """
    from jax.sharding import PartitionSpec as P
    from repro.models.sharding import current_mesh, current_rules
    mesh = current_mesh()
    rules = current_rules()
    bt, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = bt * s
    xt = x.reshape(t, d)
    capacity = max(k, int(math.ceil(t * k * cfg.capacity_factor / e)))

    def _ax(a):
        return a if isinstance(a, str) and a in mesh.shape else None

    d_ax = _ax(rules.w_embed)
    e_ax = _ax(rules.experts)
    f_ax = _ax(rules.expert_ff)
    d_n = mesh.shape.get(d_ax, 1)
    e_n = mesh.shape.get(e_ax, 1)
    f_n = mesh.shape.get(f_ax, 1)

    def body(xt_, router, wg, wu, wd):
        logits = xt_.astype(jnp.float32) @ router          # [T, E]
        dispatch, combine, aux = _routing(logits[None], k, capacity)
        dispatch, combine = dispatch[0], combine[0]        # [T, E, C]
        ein = jnp.einsum("tec,td->ecd", dispatch.astype(xt_.dtype), xt_)
        # slice tokens to this device's resident blocks
        if e_ax is not None:
            ei = lax.axis_index(e_ax) * (e // e_n)
            ein = lax.dynamic_slice_in_dim(ein, ei, e // e_n, axis=0)
        if d_ax is not None:
            di = lax.axis_index(d_ax) * (d // d_n)
            ein = lax.dynamic_slice_in_dim(ein, di, d // d_n, axis=2)
        h_g = jnp.einsum("ecd,edf->ecf", ein, wg,
                         preferred_element_type=jnp.float32)
        h_u = jnp.einsum("ecd,edf->ecf", ein, wu,
                         preferred_element_type=jnp.float32)
        if d_ax is not None:                               # contraction partial
            h_g = lax.psum(h_g, d_ax)
            h_u = lax.psum(h_u, d_ax)
        h = (jax.nn.silu(h_g) * h_u).astype(xt_.dtype)     # [E_l, C, F_l]
        eout = jnp.einsum("ecf,efd->ecd", h, wd,
                          preferred_element_type=jnp.float32)
        if f_ax is not None:                               # contraction partial
            eout = lax.psum(eout, f_ax)
        # combine back to tokens; un-slice experts via psum over e_ax
        comb = combine
        if e_ax is not None:
            ci = lax.axis_index(e_ax) * (e // e_n)
            comb = lax.dynamic_slice_in_dim(comb, ci, e // e_n, axis=1)
        y_part = jnp.einsum("tec,ecd->td", comb.astype(jnp.float32), eout)
        if e_ax is not None:
            y_part = lax.psum(y_part, e_ax)
        if d_ax is not None:                               # d was sliced
            y = lax.all_gather(y_part, d_ax, axis=1, tiled=True)
        else:
            y = y_part
        return y.astype(xt_.dtype), aux

    pw_g = P(e_ax, d_ax, f_ax)
    pw_d = P(e_ax, f_ax, d_ax)
    y, aux = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(), P(), pw_g, pw_g, pw_d),
        out_specs=(P(), P()),
    )(xt, params.router, params.wg, params.wu, params.wd)
    return y.reshape(bt, s, d), aux


def _shardmap_eligible(cfg: ModelConfig) -> bool:
    from repro.models.sharding import current_mesh, current_rules
    mesh = current_mesh()
    if mesh is None:
        return False
    rules = current_rules()
    for dim, ax in ((cfg.d_model, rules.w_embed),
                    (cfg.n_experts, rules.experts),
                    (cfg.d_ff, rules.expert_ff)):
        if isinstance(ax, str) and ax in mesh.shape \
                and dim % mesh.shape[ax] != 0:
            return False
    return True


def moe_mlp(params: MoEParams, x: jax.Array, cfg: ModelConfig,
            group_size: int = 512) -> Tuple[jax.Array, jax.Array]:
    """x: [Bt, S, D] -> ([Bt, S, D], aux_loss)."""
    from repro.models.sharding import current_mesh
    bt, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    tokens = bt * s
    if tokens <= 1024 and _shardmap_eligible(cfg):
        return moe_decode_shardmap(params, x, cfg)
    gsz = min(group_size, tokens)
    g = tokens // gsz
    assert g * gsz == tokens, f"tokens {tokens} % group {gsz} != 0"
    xg = x.reshape(g, gsz, d)
    xg = shard(xg, "batch", None, "embed")

    capacity = max(k, int(math.ceil(gsz * k * cfg.capacity_factor / e)))
    logits = xg.astype(jnp.float32) @ params.router        # [G,T,E]
    dispatch, combine, aux = _routing(logits, k, capacity)
    dispatch = dispatch.astype(x.dtype)
    dispatch = shard(dispatch, "batch", None, "experts", None)
    combine = shard(combine.astype(jnp.float32),
                    "batch", None, "experts", None)

    ein = jnp.einsum("gtec,gtd->gecd", dispatch, xg)       # expert inputs
    ein = shard(ein, "batch", "experts", "capacity", "embed")
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", ein, params.wg)) \
        * jnp.einsum("gecd,edf->gecf", ein, params.wu)
    h = shard(h, "batch", "experts", "capacity", "expert_ff")
    eout = jnp.einsum("gecf,efd->gecd", h, params.wd)
    eout = shard(eout, "batch", "experts", "capacity", "embed")

    yg = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), eout)
    y = yg.reshape(bt, s, d)
    return shard(y, "batch", "seq", "embed"), aux
