"""Mamba2 SSD (state-space duality) mixer in pure JAX.

Implements the chunked SSD algorithm (Dao & Gu, arXiv:2405.21060): the
sequence is split into chunks; within a chunk the recurrence is computed as
a masked (decay-weighted) attention-like quadratic form, and chunk-final
states are propagated with a ``lax.scan`` — O(S·Q) instead of O(S²).

Shapes follow the minimal SSD formulation with a single B/C group:
  x:  [Bt, S, H, P]     (P = head dim)
  dt: [Bt, S, H]        (softplus-ed timestep, >0)
  A:  [H]               (negative decay rate, from -exp(A_log))
  B:  [Bt, S, N]        (input  projection of state, N = d_state)
  C:  [Bt, S, N]        (output projection of state)

Decode maintains state [Bt, H, P, N] with O(1) per-token updates — the
reason mamba2/hymba run the long_500k cell.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rms_norm
from repro.models.sharding import shard


class SSMParams(NamedTuple):
    in_proj: jax.Array    # [D, 2*d_inner + 2*N + H]
    out_proj: jax.Array   # [d_inner, D]
    conv_w: jax.Array     # [W, d_inner + 2*N]
    conv_b: jax.Array     # [d_inner + 2*N]
    A_log: jax.Array      # [H]
    D_skip: jax.Array     # [H]
    dt_bias: jax.Array    # [H]
    norm: jax.Array       # [d_inner] gated RMSNorm scale


def init_ssm(key, cfg: ModelConfig) -> SSMParams:
    d, di, n, h = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    return SSMParams(
        in_proj=dense_init(ks[0], d, 2 * di + 2 * n + h, dtype),
        out_proj=dense_init(ks[1], di, d, dtype),
        conv_w=(jax.random.normal(ks[2], (cfg.ssm_conv_width, di + 2 * n),
                                  jnp.float32) * 0.1).astype(dtype),
        conv_b=jnp.zeros((di + 2 * n,), dtype),
        A_log=jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        D_skip=jnp.ones((h,), jnp.float32),
        dt_bias=jnp.log(jnp.expm1(jnp.full((h,), 0.01))).astype(jnp.float32),
        norm=jnp.ones((di,), dtype),
    )


def _split_in_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    return z, xbc, dt  # gate [.., di], conv-in [.., di+2N], dt [.., H]


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv1d.  xbc: [Bt,S,C]; w: [W,C].

    Returns (out [Bt,S,C], new_state [Bt,W-1,C]).
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[-1]), xbc.dtype)
    xext = jnp.concatenate([state, xbc], axis=1)
    out = sum(xext[:, i:i + xbc.shape[1]] * w[i] for i in range(width))
    new_state = xext[:, xext.shape[1] - (width - 1):]
    return jax.nn.silu(out + b), new_state


def ssd_chunked(x, dt, A, B, C, chunk: int,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD scan.  Returns (y [Bt,S,H,P], final_state [Bt,H,P,N])."""
    bt, s, h, p = x.shape
    n = B.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    q = chunk
    xc = x.reshape(bt, nc, q, h, p).astype(jnp.float32)
    dtc = dt.reshape(bt, nc, q, h).astype(jnp.float32)
    Bc = B.reshape(bt, nc, q, n).astype(jnp.float32)
    Cc = C.reshape(bt, nc, q, n).astype(jnp.float32)

    da = dtc * A[None, None, None, :]                  # [Bt,nc,q,H] (<0)
    cum = jnp.cumsum(da, axis=2)                       # within-chunk cumsum
    seg_total = cum[:, :, -1, :]                       # [Bt,nc,H]

    # ---- intra-chunk (quadratic attention-like) term ----------------------
    # L[i,j] = exp(cum[i]-cum[j]) for i>=j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [Bt,nc,q,q,H]
    ii = jnp.arange(q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    Lmat = jnp.where(causal, jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)             # [Bt,nc,q,q]
    scores = cb[..., None] * Lmat * dtc[:, :, None, :, :]  # [Bt,nc,i,j,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xc)

    # ---- chunk-final states -------------------------------------------------
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - cum)  # [Bt,nc,q,H]
    # state_c = sum_j decay_to_end[j] * dt[j] * B[j] (x) x[j]
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                        decay_to_end * dtc, Bc, xc)         # [Bt,nc,H,P,N]

    # ---- inter-chunk scan ---------------------------------------------------
    if init_state is None:
        init_state = jnp.zeros((bt, h, p, n), jnp.float32)

    def body(prev, xs):
        st, seg = xs                                       # [Bt,H,P,N],[Bt,H]
        new = st + prev * jnp.exp(seg)[:, :, None, None]
        return new, prev                                   # emit state *before* chunk

    final, prev_states = lax.scan(
        body, init_state.astype(jnp.float32),
        (states.swapaxes(0, 1), seg_total.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)               # [Bt,nc,H,P,N]

    # ---- inter-chunk contribution ------------------------------------------
    y_inter = jnp.einsum("bcin,bchpn->bcihp",
                         Cc, prev_states) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(bt, nc * q, h, p)[:, :s]
    return y.astype(x.dtype), final


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """One-token SSD recurrence.  state: [Bt,H,P,N]; x_t: [Bt,H,P];
    dt_t: [Bt,H]; B_t/C_t: [Bt,N].  Returns (y_t [Bt,H,P], new_state)."""
    da = dt_t * A[None, :]                                  # [Bt,H]
    decay = jnp.exp(da)[:, :, None, None]
    inject = jnp.einsum("bh,bn,bhp->bhpn", dt_t, B_t, x_t)
    new_state = state * decay + inject
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t)
    return y, new_state


class SSMCache(NamedTuple):
    conv: jax.Array   # [Bt, W-1, d_inner+2N]
    state: jax.Array  # [Bt, H, P, N]


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32,
                   stacked: int = 0) -> SSMCache:
    di, n, h, p = (cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads,
                   cfg.ssm_head_dim)
    lead = (stacked,) if stacked else ()
    return SSMCache(
        conv=jnp.zeros(lead + (batch, cfg.ssm_conv_width - 1, di + 2 * n),
                       dtype),
        state=jnp.zeros(lead + (batch, h, p, n), jnp.float32),
    )


def ssm_mixer(params: SSMParams, x: jax.Array, cfg: ModelConfig,
              cache: Optional[SSMCache] = None, lora=None
              ) -> Tuple[jax.Array, Optional[SSMCache]]:
    """Full Mamba2 mixer: in_proj -> conv -> SSD -> gated norm -> out_proj.

    x: [Bt,S,D].  With ``cache`` and S==1 runs the O(1) decode path.
    ``lora``: optional dict with "ssm_in"/"ssm_out" LoRA pairs (a, b).
    """
    di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_n_heads
    p = cfg.ssm_head_dim
    A = -jnp.exp(params.A_log.astype(jnp.float32))

    zxbcdt = x @ params.in_proj
    if lora is not None and "ssm_in" in lora:
        a = lora["ssm_in"]["a"].astype(x.dtype)
        b = lora["ssm_in"]["b"].astype(x.dtype)
        zxbcdt = zxbcdt + ((x @ a) @ b) * cfg.lora.scaling
    z, xbc, dt_raw = _split_in_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params.dt_bias.astype(jnp.float32))

    decode = cache is not None and x.shape[1] == 1
    xbc_conv, new_conv = _causal_conv(
        xbc, params.conv_w, params.conv_b,
        cache.conv if cache is not None else None)
    xs, B, C = jnp.split(xbc_conv, [di, di + n], axis=-1)
    xs = shard(xs, "batch", "seq", "ssm_inner")
    bt, s = xs.shape[0], xs.shape[1]
    xh = xs.reshape(bt, s, h, p)

    if decode:
        y, new_state = ssd_decode_step(
            cache.state, xh[:, 0].astype(jnp.float32), dt[:, 0], A,
            B[:, 0].astype(jnp.float32), C[:, 0].astype(jnp.float32))
        y = y[:, None]
        new_cache = SSMCache(conv=new_conv, state=new_state)
    else:
        y, final = ssd_chunked(xh, dt, A, B.astype(jnp.float32),
                               C.astype(jnp.float32), cfg.ssm_chunk,
                               init_state=cache.state if cache else None)
        # always return the cache: prefill needs the final state + conv tail
        new_cache = SSMCache(conv=new_conv, state=final)

    y = y + xh.astype(jnp.float32) * params.D_skip[None, None, :, None]
    y = y.reshape(bt, s if not decode else 1, di).astype(x.dtype)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params.norm)
    out = y @ params.out_proj
    if lora is not None and "ssm_out" in lora:
        a = lora["ssm_out"]["a"].astype(y.dtype)
        b = lora["ssm_out"]["b"].astype(y.dtype)
        out = out + ((y @ a) @ b) * cfg.lora.scaling
    return out, new_cache
