"""LoRA adapter parameter trees — the paper's unified PEFT interface.

The same adapter tree is consumed by the training step (gradients flow
only into it), the inference step (fused low-rank bypass), and the FL
aggregation (Eq. 5 FedAvg over the (A, B) matrices).  Base weights are
frozen and shared — this is CoLLM's model-sharing mechanism made literal.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def target_dims(cfg: ModelConfig) -> Dict[str, Tuple[int, int]]:
    d, h = cfg.d_model, cfg.head_dim
    dims = {
        "q": (d, cfg.n_heads * h),
        "k": (d, cfg.n_kv_heads * h),
        "v": (d, cfg.n_kv_heads * h),
        "o": (cfg.n_heads * h, d),
    }
    if cfg.d_ff > 0:
        dims.update({"gate": (d, cfg.d_ff), "up": (d, cfg.d_ff),
                     "down": (cfg.d_ff, d)})
    if cfg.has_ssm:
        dims.update({
            "ssm_in": (d, 2 * cfg.ssm_d_inner + 2 * cfg.ssm_state
                       + cfg.ssm_n_heads),
            "ssm_out": (cfg.ssm_d_inner, d),
        })
    return dims


def init_lora(key, cfg: ModelConfig, stacked: int) -> Dict:
    """One (a, b) pair per target, stacked over ``stacked`` layers.
    a ~ N(0, 1/din), b = 0 (standard LoRA init -> adapter starts as no-op).
    """
    dims = target_dims(cfg)
    r = cfg.lora.rank
    dtype = jnp.float32  # adapters train in f32 (tiny)
    out = {}
    keys = jax.random.split(key, len(cfg.lora.targets))
    for tk, t in zip(keys, cfg.lora.targets):
        if t not in dims:
            continue
        din, dout = dims[t]
        a = (jax.random.normal(tk, (stacked, din, r), jnp.float32)
             / math.sqrt(din)).astype(dtype)
        b = jnp.zeros((stacked, r, dout), dtype)
        out[t] = {"a": a, "b": b}
    return out


def apply(x: jax.Array, base_out: jax.Array, pair: Optional[Dict],
          scaling: float, adapter_idx: Optional[jax.Array] = None
          ) -> jax.Array:
    """base_out + scaling * (x @ A) @ B — the low-rank bypass.

    With ``adapter_idx`` set, ``pair`` holds STACKED per-adapter slices
    (``a: [A, din, r]``, ``b: [A, r, dout]``) and each batch row applies
    its own adapter (see ``apply_segmented``)."""
    if pair is None:
        return base_out
    if adapter_idx is not None:
        return apply_segmented(x, base_out, pair, adapter_idx, scaling)
    a = pair["a"].astype(x.dtype)
    b = pair["b"].astype(x.dtype)
    return base_out + ((x @ a) @ b) * scaling


def apply_segmented(x: jax.Array, base_out: jax.Array, pair: Dict,
                    adapter_idx: jax.Array, scaling: float) -> jax.Array:
    """Per-row adapter selection over a stacked pair.

    x: [B, S, din]; pair: {"a": [A, din, r], "b": [A, r, dout]} (one
    layer's slot stack); adapter_idx: [B] int32, row's slot (< 0 =
    adapter disabled, base output returned bitwise — the select happens
    AFTER the einsum so stale device slots never leak into those rows).
    """
    a = pair["a"].astype(x.dtype)
    b = pair["b"].astype(x.dtype)
    n_adapters = a.shape[0]
    valid = adapter_idx >= 0
    idx = jnp.clip(adapter_idx, 0, n_adapters - 1)
    xa = jnp.einsum("bsk,bkr->bsr", x, jnp.take(a, idx, axis=0))
    low = jnp.einsum("bsr,brn->bsn", xa, jnp.take(b, idx, axis=0))
    y = base_out + low * scaling
    return jnp.where(valid[:, None, None], y, base_out)


def stack_adapters(trees: "list[Dict]") -> Dict:
    """Stack ``k`` same-structure adapter trees into one multi-slot tree:
    leaves go from ``[L, din, r]`` to ``[L, k, din, r]`` (slot axis 1 so
    the layer scan still slices axis 0)."""
    return jax.tree.map(lambda *leaves: jnp.stack(leaves, axis=1), *trees)


def merge_into(base_w: jax.Array, pair: Dict, scaling: float) -> jax.Array:
    """W' = W + scaling * A @ B (offline merge; used by the 'Separate'
    baseline that redeploys merged weights after training)."""
    return (base_w.astype(jnp.float32)
            + scaling * pair["a"].astype(jnp.float32)
            @ pair["b"].astype(jnp.float32)).astype(base_w.dtype)


def num_params(lora_tree: Dict) -> int:
    return sum(x.size for x in jax.tree.leaves(lora_tree))
