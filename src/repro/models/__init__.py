from repro.models.model import Model, build  # noqa: F401
from repro.models.sharding import (  # noqa: F401
    RULES_FSDP_HEAVY, RULES_TP_FSDP, RULES_TP_ONLY, ShardingRules,
    sharding_context, shard,
)
