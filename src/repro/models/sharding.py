"""Logical-axis sharding: MaxText-style rules mapping logical tensor axes
to physical mesh axes.

Model code annotates tensors with *logical* axis names via ``shard(x,
"batch", "seq", "embed")``.  A ``ShardingRules`` table maps each logical
name to a mesh axis (or None).  Outside a sharding context every
annotation is the identity, so the same model code runs on a single CPU
device (smoke tests) and on the 512-chip production mesh (dry-run).

Hillclimbing swaps rule tables without touching model code.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions: newer releases expose it as
    ``jax.shard_map`` (replication check kwarg ``check_vma``), older
    ones as ``jax.experimental.shard_map.shard_map`` (``check_rep``)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of axes, or None)."""
    batch: Axis = ("pod", "data")     # activation batch
    seq: Axis = None                  # sequence (generic)
    act_seq: Axis = None              # residual-stream seq (Megatron-SP)
    q_seq: Axis = None                # attention query seq (head fallback)
    embed: Axis = None                # activation d_model
    heads: Axis = "model"             # attention heads (TP)
    kv_heads: Axis = "model"
    head_dim: Axis = None
    ff: Axis = "model"                # MLP hidden (TP)
    vocab: Axis = "model"             # embedding/logits vocab (TP)
    experts: Axis = "model"           # MoE expert axis (EP)
    expert_ff: Axis = None            # MoE per-expert ff (TP for grok)
    capacity: Axis = None
    layers: Axis = None               # stacked-layer leading axis
    # weight FSDP axes (sharding of the non-TP dim of weights):
    w_embed: Axis = "data"            # d_model dim of weight matrices
    w_ff_in: Axis = "data"            # input dim of down-proj etc.
    conv: Axis = None
    ssm_inner: Axis = "model"         # d_inner of SSD mixer
    ssm_state: Axis = None
    ssm_heads: Axis = "model"
    lora_rank: Axis = None
    kv_batch: Axis = ("pod", "data")  # KV-cache batch
    kv_seq: Axis = None

    def resolve(self, *names: Optional[str]) -> P:
        parts = []
        for n in names:
            if n is None:
                parts.append(None)
            else:
                parts.append(getattr(self, n))
        return P(*parts)


# Presets -------------------------------------------------------------------
RULES_TP_FSDP = ShardingRules()                       # default: TP + FSDP
RULES_TP_ONLY = dataclasses.replace(
    RULES_TP_FSDP, w_embed=None, w_ff_in=None)        # pure TP (replicated DP)
RULES_FSDP_HEAVY = dataclasses.replace(               # FSDP on both weight dims
    RULES_TP_FSDP, w_embed=("pod", "data"), w_ff_in=("pod", "data"))


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: ShardingRules = RULES_TP_FSDP


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_context(mesh: Optional[Mesh], rules: Optional[ShardingRules] = None):
    """Activate a mesh + rule table for ``shard()`` annotations."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    if rules is not None:
        _CTX.rules = rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def current_rules() -> ShardingRules:
    return _CTX.rules


def _filter_spec(spec: P, mesh: Mesh, shape) -> P:
    """Drop mesh axes whose size does not divide the tensor dim (keeps the
    dry-run robust for dims like 25 heads or 8 experts on a 16-way axis)."""
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        kept = []
        for a in axes:
            if a in mesh.shape and dim % (size * mesh.shape[a]) == 0:
                kept.append(a)
                size *= mesh.shape[a]
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def logical_spec(shape, *names: Optional[str]) -> P:
    """Resolve logical names to a PartitionSpec under the current context."""
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None:
        return P()
    return _filter_spec(rules.resolve(*names), mesh, shape)


def shard(x, *names: Optional[str]):
    """with_sharding_constraint by logical axis names (no-op without mesh)."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = logical_spec(x.shape, *names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(shape, *names: Optional[str]) -> Optional[NamedSharding]:
    mesh = _CTX.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_spec(shape, *names))
