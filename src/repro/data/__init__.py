from repro.data.synthetic import SyntheticDataset, replica_datasets  # noqa: F401
from repro.data.traces import TraceConfig, conv_trace, code_trace, merged_trace  # noqa: F401
