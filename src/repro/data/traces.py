"""Azure-like LLM inference trace generation + replay (paper §2.1, §8.1).

The paper replays Azure-Code and Azure-Conv over a 10 h window; those
files aren't available offline, so we synthesize traces with the same
reported morphology (Fig. 1): diurnal swing with troughs at <0.7% of the
peak rate, sudden surges up to ~440% of the local baseline, and heavy
sub-second burstiness (doubly-stochastic Poisson / Markov-modulated
surges).  Deterministic under a seed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.interfaces import Request


@dataclasses.dataclass
class TraceConfig:
    name: str = "azure-conv-like"
    duration: float = 3600.0        # seconds
    peak_rate: float = 40.0         # req/s at diurnal peak
    trough_frac: float = 0.007      # Fig. 1: <0.7% of peak at the trough
    diurnal_period: float = 1800.0  # compressed "day" for the sim window
    surge_rate_mult: float = 4.4    # 440% surge (Fig. 1)
    surge_prob_per_s: float = 0.004
    surge_duration: float = 25.0
    burst_cv: float = 1.8           # sub-second burstiness (CV > 1)
    mean_tokens: int = 180          # output tokens per request (conv)
    token_cv: float = 0.6
    slo: float = 0.5
    stream_id: str = "llama3-8b"
    seed: int = 0


def rate_at(cfg: TraceConfig, t: float, surge: bool) -> float:
    lo = cfg.peak_rate * cfg.trough_frac
    phase = 0.5 * (1 - math.cos(2 * math.pi * t / cfg.diurnal_period))
    base = lo + (cfg.peak_rate - lo) * phase ** 2.2   # sharpen the peak
    return base * (cfg.surge_rate_mult if surge else 1.0)


def generate(cfg: TraceConfig, start_id: int = 0) -> List[Request]:
    """Markov-modulated Poisson process with gamma-distributed gaps for
    sub-second burstiness (CV = cfg.burst_cv)."""
    rng = np.random.default_rng(cfg.seed)
    out: List[Request] = []
    t = 0.0
    surge_until = -1.0
    rid = start_id
    # gamma with shape k has CV = 1/sqrt(k)
    k = 1.0 / (cfg.burst_cv ** 2)
    while t < cfg.duration:
        if t > surge_until and rng.random() < cfg.surge_prob_per_s * 0.1:
            surge_until = t + cfg.surge_duration * rng.lognormal(0, 0.3)
        lam = rate_at(cfg, t, t <= surge_until)
        mean_gap = 1.0 / max(lam, 1e-6)
        gap = float(rng.gamma(k, mean_gap / k))
        t += gap
        if t >= cfg.duration:
            break
        tokens = max(8, int(rng.lognormal(
            math.log(cfg.mean_tokens), cfg.token_cv)))
        out.append(Request(
            request_id=rid, stream_id=cfg.stream_id, arrival=t,
            deadline=t + cfg.slo, tokens=tokens))
        rid += 1
    return out


def code_trace(duration: float = 3600.0, seed: int = 1,
               stream_id: str = "llama3-8b", scale: float = 1.0
               ) -> List[Request]:
    """Azure-Code-like: lower rate, longer responses, spikier."""
    return generate(TraceConfig(
        name="azure-code-like", duration=duration, peak_rate=12.0 * scale,
        mean_tokens=420, token_cv=0.8, surge_prob_per_s=0.006,
        burst_cv=2.2, stream_id=stream_id, seed=seed))


def conv_trace(duration: float = 3600.0, seed: int = 2,
               stream_id: str = "llama3-8b", scale: float = 1.0
               ) -> List[Request]:
    """Azure-Conv-like: higher rate, shorter responses."""
    return generate(TraceConfig(
        name="azure-conv-like", duration=duration, peak_rate=40.0 * scale,
        mean_tokens=180, token_cv=0.6, stream_id=stream_id, seed=seed))


def merged_trace(duration: float = 3600.0, scale: float = 1.0,
                 stream_id: str = "llama3-8b", seed: int = 0
                 ) -> List[Request]:
    """§8.1: the two traces merged into one multi-tenant pattern."""
    a = code_trace(duration, seed=seed * 2 + 1, stream_id=stream_id,
                   scale=scale)
    b = conv_trace(duration, seed=seed * 2 + 2, stream_id=stream_id,
                   scale=scale)
    for i, r in enumerate(a + b):
        r.request_id = i
    merged = sorted(a + b, key=lambda r: r.arrival)
    return merged


def replay(requests: Sequence[Request], simulator, submit) -> None:
    """Schedule every request's arrival on the simulator."""
    for req in requests:
        simulator.schedule(req.arrival,
                           lambda now, r=req: submit(r), tag="arrival")


def stats(requests: Sequence[Request], bucket: float = 10.0) -> dict:
    """Fig. 1-style summary: rate percentiles, surge/trough ratio, CV."""
    if not requests:
        return {}
    arr = np.asarray([r.arrival for r in requests])
    dur = float(arr.max()) + 1e-9
    counts, _ = np.histogram(arr, bins=max(int(dur / bucket), 1))
    rates = counts / bucket
    nz = rates[rates > 0]
    secly, _ = np.histogram(arr, bins=max(int(dur), 1))
    return {
        "requests": len(requests),
        "mean_rate": float(len(requests) / dur),
        "peak_rate": float(rates.max()),
        "trough_over_peak": float(
            (nz.min() if len(nz) else 0.0) / max(rates.max(), 1e-9)),
        "surge_over_median": float(
            rates.max() / max(np.median(nz) if len(nz) else 1.0, 1e-9)),
        "per_second_cv": float(np.std(secly) / max(np.mean(secly), 1e-9)),
    }
