"""Synthetic instruction-tuning data (stand-in for Table 1's datasets,
which aren't shipped offline).

Generates deterministic token sequences with learnable structure: each
"domain" (code / conversation / manim / ...) has a distinct Markov
transition matrix over the vocabulary, so LoRA fine-tuning on a domain
measurably reduces CE loss on that domain — which is what the paper's
quality metric (1/CE) needs to show continuous adaptation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

DOMAINS = ("manim", "code_alpaca", "code_instruct",     # code generation
           "alpaca", "gpteacher", "open_instruct", "instruct3m")  # conv


@dataclasses.dataclass
class SyntheticDataset:
    domain: str
    vocab_size: int = 512
    seq_len: int = 64
    seed: int = 0
    branching: int = 7   # candidate next-tokens per token (lower=easier)

    def __post_init__(self):
        rng = np.random.default_rng(
            abs(hash((self.domain, self.seed))) % (2 ** 31))
        v, k = self.vocab_size, self.branching
        self.next_tokens = rng.integers(0, v, size=(v, k))
        self.next_probs = rng.dirichlet(np.ones(k) * 0.6, size=v)
        self._rng = np.random.default_rng(self.seed + 17)

    def sample_tokens(self, batch: int, rng: Optional[np.random.Generator]
                      = None) -> np.ndarray:
        rng = rng or self._rng
        out = np.zeros((batch, self.seq_len + 1), dtype=np.int32)
        out[:, 0] = rng.integers(0, self.vocab_size, size=batch)
        for t in range(self.seq_len):
            cur = out[:, t]
            choice = np.array([
                rng.choice(self.next_tokens[c], p=self.next_probs[c])
                for c in cur])
            out[:, t + 1] = choice
        return out

    def batch(self, batch_size: int,
              rng: Optional[np.random.Generator] = None) -> Dict:
        """Training batch: tokens, next-token labels, mask."""
        toks = self.sample_tokens(batch_size, rng)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
            "mask": np.ones((batch_size, self.seq_len), np.float32),
        }


def replica_datasets(n_replicas: int, vocab_size: int = 512,
                     seq_len: int = 64, seed: int = 0
                     ) -> Dict[str, SyntheticDataset]:
    """§8.1: each replica preloaded with a distinct dataset (simulated
    heterogeneous tenant data distribution)."""
    out = {}
    for i in range(n_replicas):
        domain = DOMAINS[i % len(DOMAINS)]
        out[f"r{i:02d}"] = SyntheticDataset(
            domain, vocab_size=vocab_size, seq_len=seq_len,
            seed=seed * 100 + i)
    return out
