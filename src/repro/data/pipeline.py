"""Sharded training-data pipeline: host-side batching + device layout.

For the multi-pod training path: every host generates its slice of the
global batch (by process index), device_put's it under the batch
sharding, and a one-deep prefetch overlaps host batch prep with device
compute.
"""
from __future__ import annotations

import collections
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.models.sharding import named_sharding


class DataPipeline:
    def __init__(self, sample_fn: Callable[[int], Dict[str, np.ndarray]],
                 global_batch: int, prefetch: int = 1):
        self.sample_fn = sample_fn
        self.global_batch = global_batch
        self.prefetch = prefetch
        self._buf: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None

    def _make(self) -> Dict[str, Any]:
        host = self.sample_fn(self.global_batch)
        out = {}
        for k, v in host.items():
            shd = named_sharding(v.shape, "batch",
                                 *([None] * (v.ndim - 1)))
            out[k] = jax.device_put(v, shd) if shd is not None \
                else jax.numpy.asarray(v)
        return out

    def _fill(self) -> None:
        while True:
            with self._lock:
                if len(self._buf) >= self.prefetch:
                    return
            batch = self._make()
            with self._lock:
                self._buf.append(batch)

    def __iter__(self) -> Iterator[Dict[str, Any]]:
        return self

    def __next__(self) -> Dict[str, Any]:
        with self._lock:
            if self._buf:
                nxt = self._buf.popleft()
            else:
                nxt = None
        if nxt is None:
            nxt = self._make()
        # kick off background refill
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._fill, daemon=True)
            self._thread.start()
        return nxt
