"""AdamW in pure JAX (no optax in this container) with the optax-style
(init, update) interface, plus gradient clipping and schedules.

State is a pytree mirroring the params (m, v in f32) so it shards with
the same rules as the parameters (FSDP-friendly).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, grads, state: AdamWState, params
               ) -> Tuple[Any, AdamWState, dict]:
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9)) \
            if self.clip_norm > 0 else jnp.float32(1.0)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
        m = jax.tree.map(lambda mm, g: self.b1 * mm + (1 - self.b1) * g,
                         state.m, grads)
        v = jax.tree.map(lambda vv, g: self.b2 * vv + (1 - self.b2) * g * g,
                         state.v, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, mm, vv):
            mhat = mm / bc1
            vhat = vv / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay > 0:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (-lr * delta).astype(p.dtype)

        updates = jax.tree.map(upd, params, m, v)
        new_params = jax.tree.map(lambda p, u: p + u, params, updates)
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, AdamWState(step=step, m=m, v=v), metrics


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    if not leaves:
        return jnp.float32(0.0)
    return jnp.sqrt(sum(leaves))


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, base_lr * cos)
    return lr
