"""Gradient noise scale (McCandlish et al., arXiv:1812.06162).

CoLLM's Coordinator uses the noise scale ``p_t`` inside the EFFICIENCY
term (Eq. 8) to penalize over-large training batches.  The simple (B_small,
B_big) estimator: with per-microbatch gradients g_i and their mean g,

  S = (B_big*|g_big|² - B_small*|g_small|²) / (B_big - B_small)   (signal)
  Σ = (|g_small|² - |g_big|²) / (1/B_small - 1/B_big)             (noise)
  B_noise = Σ / S
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import global_norm


def noise_scale_from_microbatches(micro_grads_sqnorm: jax.Array,
                                  mean_grad_sqnorm: jax.Array,
                                  micro_batch: int, n_micro: int
                                  ) -> jax.Array:
    """micro_grads_sqnorm: mean over microbatches of |g_i|²;
    mean_grad_sqnorm: |mean_i g_i|².  Returns estimated noise scale."""
    b_small = jnp.float32(micro_batch)
    b_big = jnp.float32(micro_batch * n_micro)
    g2_small = micro_grads_sqnorm
    g2_big = mean_grad_sqnorm
    signal = (b_big * g2_big - b_small * g2_small) / jnp.maximum(
        b_big - b_small, 1.0)
    noise = (g2_small - g2_big) / jnp.maximum(
        1.0 / b_small - 1.0 / b_big, 1e-9)
    return jnp.maximum(noise, 0.0) / jnp.maximum(signal, 1e-9)


class NoiseScaleEMA:
    """Host-side EMA of the noise-scale estimate (Coordinator telemetry)."""

    def __init__(self, decay: float = 0.9):
        self.decay = decay
        self.value: float = 0.0
        self._initialized = False

    def update(self, estimate: float) -> float:
        if not self._initialized:
            self.value = float(estimate)
            self._initialized = True
        else:
            self.value = self.decay * self.value \
                + (1 - self.decay) * float(estimate)
        return self.value

    @property
    def initialized(self) -> bool:
        """True once at least one measurement has landed — consumers
        fall back to a prior until then."""
        return self._initialized
