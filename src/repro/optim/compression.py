"""Gradient compression for cross-pod DP synchronization.

Two schemes, both with the distributed-optimization error-feedback trick
so compression error accumulates locally instead of being lost:

  * top-k sparsification (keep the k largest-|g| entries per tensor)
  * int8 stochastic quantization (per-tensor scale)

Used by launch/train.py for the gradient all-reduce over the ``pod``
axis, where DCN bandwidth (not ICI) is the bottleneck.  LoRA gradients
are tiny, so compression is mostly relevant for the optional full-FT
path and for FL rounds aggregating many adapters.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class ErrorFeedback(NamedTuple):
    residual: Any  # pytree matching grads


def init_error_feedback(grads) -> ErrorFeedback:
    return ErrorFeedback(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def topk_compress(g: jax.Array, frac: float) -> Tuple[jax.Array, jax.Array]:
    """Keep the top-``frac`` fraction of entries; returns (values, mask)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = (jnp.abs(flat) >= thresh).astype(jnp.float32)
    return (flat * mask).reshape(g.shape), mask.reshape(g.shape)


def compress_tree_topk(grads, ef: ErrorFeedback, frac: float = 0.05
                       ) -> Tuple[Any, ErrorFeedback]:
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        kept, mask = topk_compress(acc, frac)
        return kept, acc * (1.0 - mask)
    pairs = jax.tree.map(one, grads, ef.residual)
    kept = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda p: p[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return kept, ErrorFeedback(resid)


def quantize_int8(g: jax.Array, key=None) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization (optionally stochastic)."""
    scale = jnp.maximum(jnp.max(jnp.abs(g.astype(jnp.float32))), 1e-12) / 127.0
    scaled = g.astype(jnp.float32) / scale
    if key is not None:
        scaled = scaled + jax.random.uniform(key, g.shape, minval=-0.5,
                                             maxval=0.5)
    q = jnp.clip(jnp.round(scaled), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree_int8(grads, ef: ErrorFeedback
                       ) -> Tuple[Any, Any, ErrorFeedback]:
    """Returns (q_tree, scale_tree, new_ef).  Decode with dequantize."""
    def one(g, r):
        acc = g.astype(jnp.float32) + r
        q, s = quantize_int8(acc)
        deq = dequantize_int8(q, s)
        return q, s, acc - deq
    triples = jax.tree.map(one, grads, ef.residual)
    is_t = lambda x: isinstance(x, tuple) and len(x) == 3
    qt = jax.tree.map(lambda t: t[0], triples, is_leaf=is_t)
    st = jax.tree.map(lambda t: t[1], triples, is_leaf=is_t)
    rt = jax.tree.map(lambda t: t[2], triples, is_leaf=is_t)
    return qt, st, ErrorFeedback(rt)
