from repro.optim.adamw import AdamW, AdamWState, cosine_schedule, global_norm  # noqa: F401
from repro.optim.grad_noise import (  # noqa: F401
    NoiseScaleEMA, noise_scale_from_microbatches,
)
