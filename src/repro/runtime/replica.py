"""Replica implementations of the ReplicaHandle protocol.

``SimReplica``  — discrete-event replica with analytic interference
                  surfaces (ground truth the control plane must learn).
``LiveReplica`` — real JAX execution: serve/train/combined steps on a
                  (reduced) model, wall-clock latencies.  Used by the
                  integration tests and examples/.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time as _time
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.interfaces import (
    BatchResult, ReplicaHandle, ReplicaPressure, Request, TrainRoundStats,
)


# =========================================================================
# Simulated replica
# =========================================================================
@dataclasses.dataclass
class InterferenceSurface:
    """Ground-truth latency surfaces (bivariate + noise, §2.2).

    Defaults are calibrated to an 8B-class model on a 2-accelerator
    replica: exclusive inference latency 0.02·b + 0.05 s (b=16 ⇒ 0.37 s,
    inside the 0.5 s SLO), training step 0.03·B + 0.10 s, with cross
    terms producing the Fig. 4b interference regime.
    """
    infer_alpha: float = 0.020    # s per inference-batch element
    infer_beta: float = 0.008     # interference from co-running train batch
    infer_gamma: float = 0.050    # fixed cost
    train_alpha: float = 0.030
    train_beta: float = 0.010
    train_gamma: float = 0.100
    noise_frac: float = 0.04      # lognormal-ish multiplicative noise

    def t_infer(self, b: int, train_b: int, rng: np.random.Generator
                ) -> float:
        base = self.infer_alpha * b + self.infer_beta * train_b \
            + self.infer_gamma
        return float(base * rng.lognormal(0.0, self.noise_frac))

    def t_train(self, train_b: int, b: int, rng: np.random.Generator
                ) -> float:
        base = self.train_alpha * train_b + self.train_beta * b \
            + self.train_gamma
        return float(base * rng.lognormal(0.0, self.noise_frac))


@dataclasses.dataclass
class LossCurve:
    """Per-replica fine-tuning dynamics: exponential-decay loss toward a
    data-dependent floor, driven by samples seen; FedAvg pulls members
    toward the cohort mean (heterogeneous data, §4.2)."""
    init_loss: float = 2.4
    floor: float = 0.8
    rate: float = 1.0 / 6000.0    # per training sample
    # effective samples: statistical-efficiency scaling accumulates
    # fractional ``samples * eff`` increments, so this is a float
    seen: float = 0.0

    def loss(self) -> float:
        return self.floor + (self.init_loss - self.floor) \
            * math.exp(-self.rate * self.seen)

    def advance(self, samples: int, batch_size: int = 0
                ) -> Tuple[float, float]:
        """Advance by ``samples``; with a batch size given, apply
        Pollux-style statistical efficiency (McCandlish): per-sample
        progress decays once the batch exceeds the gradient-noise scale
        — the ground truth the Coordinator's Eq. 8 has to learn."""
        before = self.loss()
        eff = 1.0
        if batch_size > 0:
            noise = self.noise_scale()
            eff = (noise + 1.0) / (noise + float(batch_size))
        self.seen += samples * eff
        return before, self.loss()

    def noise_scale(self) -> float:
        """Gradient noise scale grows as loss approaches the floor
        (empirically: later training tolerates larger batches)."""
        prog = 1.0 - (self.loss() - self.floor) \
            / max(self.init_loss - self.floor, 1e-9)
        return 4.0 + 60.0 * prog


class SimReplica:
    """Discrete-event replica.  One batch executes at a time (Eq. 13d);
    a COMBINED-mode training round occupies a parallel 'stream' whose
    only coupling to serving is the interference surface — the simulator
    analogue of the fused XLA program."""

    def __init__(self, replica_id: str, model_id: str, simulator,
                 on_result: Callable[[BatchResult, str], None],
                 surface: Optional[InterferenceSurface] = None,
                 loss_curve: Optional[LossCurve] = None,
                 seed: int = 0, slow_factor: float = 1.0):
        self.replica_id = replica_id
        self.model_id = model_id
        self.sim = simulator
        self.on_result = on_result
        self.surface = surface or InterferenceSurface()
        self.loss_curve = loss_curve or LossCurve()
        self.rng = np.random.default_rng(seed)
        self.slow_factor = slow_factor          # straggler injection
        self.failed = False

        self.busy_until: float = 0.0
        self.pending: Deque[Tuple[float, List[Request]]] = collections.deque()
        # scheduled-but-unfinished work: (finish_time, n_requests)
        self.outstanding: Deque[Tuple[float, int]] = collections.deque()
        self.train_batch: int = 0               # active co-running B
        self.training_until: float = 0.0
        self.adapter: Any = {"version": 0}
        self.adapter_version: int = 0
        # busy-interval bookkeeping for utilization()
        self.busy_intervals: Deque[Tuple[float, float]] = collections.deque(
            maxlen=4096)
        self.served_requests: int = 0
        self.served_tokens: int = 0
        self.total_infer_time: float = 0.0
        self.total_train_time: float = 0.0

    # ------------------------------------------------------------- serving -
    def submit_batch(self, requests: Sequence[Request], now: float) -> None:
        if self.failed or not requests:
            return
        self.pending.append((now, list(requests)))
        self._drain(now)

    def _drain(self, now: float) -> None:
        while self.pending:
            submit_t, batch = self.pending.popleft()
            start = max(now, self.busy_until)
            train_b = self.train_batch if start < self.training_until else 0
            lat = self.surface.t_infer(len(batch), train_b, self.rng) \
                * self.slow_factor
            finish = start + lat
            self.busy_until = finish
            self.busy_intervals.append((start, finish))
            self.outstanding.append((finish, len(batch)))
            q = self.quality_score(now)
            self.sim.schedule(
                finish,
                lambda t, b=batch, s=submit_t, st=start, l=lat,
                tb=train_b, qq=q: self._complete(t, b, s, st, l, tb, qq),
                tag=f"batch:{self.replica_id}")

    def _complete(self, now: float, batch: List[Request], submit_t: float,
                  start: float, lat: float, train_b: int, q: float) -> None:
        tokens = 0
        queue_waits = []
        for r in batch:
            r.completed_at = now
            r.quality = q
            tokens += r.tokens
            # T_queue per the paper §6.2: everything before processing
            # starts — dispatcher pacing wait included ("the cost of
            # controllability"), not just replica-side queueing.
            queue_waits.append(start - r.arrival)
        self.served_requests += len(batch)
        self.served_tokens += tokens
        self.total_infer_time += lat
        stream = batch[0].stream_id
        self.on_result(BatchResult(
            replica_id=self.replica_id, batch_size=len(batch),
            infer_latency=lat, total_latency=now - submit_t,
            queue_latency=float(np.mean(queue_waits)), finished_at=now,
            quality=q, tokens=tokens, train_batch=train_b), stream)

    # ------------------------------------------------------------ telemetry
    def _prune_outstanding(self, now: float) -> None:
        while self.outstanding and self.outstanding[0][0] <= now:
            self.outstanding.popleft()

    def queue_length(self, now: float) -> int:
        """Requests accepted but not yet finished."""
        self._prune_outstanding(now)
        return sum(n for _, n in self.outstanding) \
            + sum(len(b) for _, b in self.pending)

    def outstanding_batches(self, now: float) -> int:
        self._prune_outstanding(now)
        return len(self.outstanding) + len(self.pending)

    def utilization(self, now: float, window: float = 10.0) -> float:
        lo = now - window
        busy = 0.0
        for s, e in self.busy_intervals:
            if e <= lo or s >= now:   # outside window / scheduled ahead
                continue
            busy += max(min(e, now) - max(s, lo), 0.0)
        util = busy / window
        if now < self.training_until and self.train_batch > 0:
            util += 0.75  # co-running fine-tuning soaks spare compute
        return float(min(util, 1.0))

    # ------------------------------------------------- placement signals ---
    def pressure(self, now: float) -> ReplicaPressure:
        """Analytic stand-in for the live runtime's pressure export: one
        execution unit, queue depth as the load signal, no block pool."""
        self._prune_outstanding(now)
        return ReplicaPressure(
            queue_len=self.queue_length(now),
            pending=sum(len(b) for _, b in self.pending),
            active_slots=1 if self.busy_until > now else 0,
            total_slots=1)

    def prefix_affinity(self, prompt: Any) -> int:
        return 0    # analytic latencies never look at prompt content

    def reclaim_queued(self, max_n: int, now: float) -> List[Request]:
        # ``_drain`` schedules every submitted batch synchronously, so
        # there is never unstarted work to hand back
        return []

    def drain_pending(self, now: float) -> List[Request]:
        # nothing to hand back: ``_drain`` schedules every submitted
        # batch synchronously, and scheduled sim events run to
        # completion (like a batch already on the accelerator)
        return []

    # ------------------------------------------------------------ training -
    def set_adapter(self, adapter: Any, version: int) -> None:
        self.adapter = adapter
        self.adapter_version = version

    def get_adapter(self) -> Any:
        return self.adapter

    def train_round(self, train_batch: int, infer_batch: int, steps: int,
                    now: float) -> TrainRoundStats:
        step_time = self.surface.t_train(train_batch, infer_batch,
                                         self.rng) * self.slow_factor
        samples = train_batch * steps
        before, after = self.loss_curve.advance(samples, train_batch)
        self.train_batch = train_batch
        self.training_until = max(self.training_until,
                                  now + steps * step_time)
        self.total_train_time += steps * step_time
        return TrainRoundStats(
            replica_id=self.replica_id, steps=steps,
            train_batch=train_batch, infer_batch=infer_batch,
            avg_step_time=step_time, loss_before=before, loss_after=after,
            noise_scale=self.loss_curve.noise_scale(), samples=samples)

    def quality_score(self, now: float) -> float:
        """§8.1: response quality = 1 / CE-loss of the current model."""
        return 1.0 / max(self.loss_curve.loss(), 1e-6)

    # --------------------------------------------------------------- faults
    def fail(self, now: float) -> None:
        self.failed = True
        self.pending.clear()

    def recover(self, now: float) -> None:
        self.failed = False
        self.busy_until = now


# =========================================================================
# Live replica (real JAX execution)
# =========================================================================
class LiveReplica:
    """Runs actual JAX serving + training (reduced models) and measures
    wall-clock — the end-to-end integration path.

    Serving goes through the slot-based ``ContinuousBatcher``
    (``runtime.serving_loop``): submitted requests become real
    prefill-then-decode generation over shared caches, and a COMBINED
    train round executes the fused ``combined_step`` per decode tick
    whenever serving work is in flight (training + decode in one XLA
    program over shared base weights)."""

    def __init__(self, replica_id: str, model_id: str, engine,
                 params, lora, opt_state,
                 on_result: Callable[[BatchResult, str], None],
                 data_fn: Callable[[int], Dict[str, Any]],
                 eval_fn: Optional[Callable[[Any], float]] = None,
                 serve_slots: int = 4, serve_prompt_len: int = 16,
                 max_gen_tokens: int = 8, serve_paged: bool = False,
                 serve_block_size: int = 16,
                 serve_n_blocks: Optional[int] = None,
                 serve_prefix_cache: bool = False):
        from repro.runtime.serving_loop import ContinuousBatcher
        self.replica_id = replica_id
        self.model_id = model_id
        self.engine = engine
        self.params = params
        self.on_result = on_result
        self.data_fn = data_fn          # batch_size -> training batch dict
        self.eval_fn = eval_fn          # lora -> eval CE loss
        self.adapter_version = 0
        self.train_batch = 0
        self.serve_prompt_len = serve_prompt_len
        self.max_gen_tokens = max_gen_tokens
        # (submit_t on the caller's clock, submit wall stamp, [Request])
        self._queue: Deque[Tuple[float, float, List[Request]]] = \
            collections.deque()
        # submitted-but-unfinished groups: (submit_t, submit_wall,
        # [Request], {gen_id: GenRequest}, ingest wall stamp)
        self._inflight: List[Tuple[float, float, List[Request],
                                   Dict[int, Any], float]] = []
        self._gen_counter = 0
        self._busy_frac = 0.0
        self._last_loss = float("nan")
        self.batcher = ContinuousBatcher(
            engine, params, lora, n_slots=serve_slots,
            max_seq=serve_prompt_len + max_gen_tokens,
            prompt_pad=serve_prompt_len, opt_state=opt_state,
            paged=serve_paged, block_size=serve_block_size,
            n_blocks=serve_n_blocks, prefix_cache=serve_prefix_cache)
        from repro.runtime.serving_loop import _engine_jits
        self._jit_loss = _engine_jits(engine)["loss"]

    # adapter + optimizer state live in the batcher so the fused path
    # can donate/update them in place
    @property
    def lora(self):
        return self.batcher.lora

    @lora.setter
    def lora(self, value):
        self.batcher.lora = value
        # new adapter -> any cached CE probe is stale
        self._last_loss = float("nan")

    @property
    def opt_state(self):
        return self.batcher.opt_state

    @opt_state.setter
    def opt_state(self, value):
        self.batcher.opt_state = value

    # ------------------------------------------------------------- serving -
    def submit_batch(self, requests: Sequence[Request], now: float) -> None:
        self._queue.append((now, _time.perf_counter(), list(requests)))

    def _ingest(self, now: float) -> None:
        """Move admissible groups from the replica's admission queue to
        the continuous batcher.  Ingestion is HEADROOM-GATED: groups
        stay in the admission queue while the batcher already holds a
        full slot wave of queued work, so the micro-cycle can still
        reclaim them for rebalancing (work inside the batcher queue is
        committed to this replica).  Prompts come from the control-plane
        Request when it carries one (multi-replica routing needs
        identical prompts on every replica), the replica's data
        distribution otherwise."""
        from repro.runtime.serving_loop import GenRequest
        while self._queue \
                and len(self.batcher.queue) < self.batcher.n_slots:
            submit_t, submit_wall, batch = self._queue.popleft()
            drawn = None
            if any(r.prompt is None for r in batch):
                drawn = np.asarray(self.data_fn(
                    len(batch))["tokens"])[:, :self.serve_prompt_len]
            group: Dict[int, Any] = {}
            for j, r in enumerate(batch):
                prompt = np.asarray(
                    r.prompt, np.int32)[:self.serve_prompt_len] \
                    if r.prompt is not None else drawn[j]
                g = GenRequest(
                    request_id=self._gen_counter, prompt=prompt,
                    max_new_tokens=min(r.tokens, self.max_gen_tokens),
                    arrival=now, temperature=r.temperature,
                    top_k=r.top_k, top_p=r.top_p,
                    # seed from the CONTROL-plane id, never the
                    # per-replica gen counter: sampled streams must not
                    # depend on placement or failover re-queues
                    seed=r.seed if r.seed is not None else r.request_id)
                self._gen_counter += 1
                self.batcher.submit(g)
                group[g.request_id] = g
            self._inflight.append((submit_t, submit_wall, batch, group,
                                   _time.perf_counter()))

    def _emit_finished(self, now: float) -> None:
        still = []
        q = None
        for submit_t, submit_wall, batch, group, t0 in self._inflight:
            if not all(g.done for g in group.values()):
                still.append((submit_t, submit_wall, batch, group, t0))
                continue
            if q is None:
                q = self.quality_score(now)
            # every latency is a WALL-CLOCK duration measured on one
            # clock: queue wait = submit -> ingest, serving = ingest ->
            # the LAST request's finish stamp (not whenever the control
            # plane got around to emitting), total = their sum.
            lat = max(g.finished_wall for g in group.values()) - t0
            queue_wait = max(t0 - submit_wall, 0.0)
            tokens = sum(len(g.tokens) for g in group.values())
            # timestamps stay on the CALLER's clock (``now`` may be
            # simulated time): completion is observed at ``now``.  The
            # old ``now + lat`` stamped a timestamp off BOTH clocks —
            # SLO attainment then compared a hybrid against sim
            # deadlines.
            for r, g in zip(batch, group.values()):
                r.completed_at = now
                r.quality = q
                r.output_tokens = list(g.tokens)
            self.on_result(BatchResult(
                replica_id=self.replica_id, batch_size=len(batch),
                infer_latency=lat, total_latency=queue_wait + lat,
                queue_latency=queue_wait,
                finished_at=now, quality=q, tokens=tokens,
                train_batch=self.train_batch), batch[0].stream_id)
        self._inflight = still

    def pump(self, now: float) -> None:
        """Synchronously drain queued serving work through the
        continuous batcher (examples drive this)."""
        self._ingest(now)
        while not self.batcher.idle():
            self.batcher.step(now=now)
            self._emit_finished(now)
            self._ingest(now)

    def pump_once(self, now: float) -> bool:
        """ONE runtime tick: ingest admissible groups, advance every
        active slot one token, emit finished groups.  The multi-replica
        fabric round-robins this so replicas interleave instead of one
        ``pump`` monopolizing the device.  Returns True while the
        replica still holds unfinished work."""
        self._ingest(now)
        if not self.batcher.idle():
            t0 = _time.perf_counter()
            self.batcher.step(now=now)
            # per-replica busy time: this replica's share of the device
            # (per-replica throughput = its tokens / its stepping time)
            self.batcher.stats.wall_time += _time.perf_counter() - t0
            self._emit_finished(now)
        self._busy_frac = len(self.batcher.active_slots()) \
            / self.batcher.n_slots
        return bool(self._queue or self._inflight
                    or not self.batcher.idle())

    def queue_length(self, now: float) -> int:
        return sum(len(b) for _, _w, b in self._queue) \
            + sum(len(b) for _, _w, b, g, _t in self._inflight
                  if not all(x.done for x in g.values()))

    def outstanding_batches(self, now: float) -> int:
        """Submitted-but-unfinished groups — the dispatcher's in-flight
        backpressure unit."""
        return len(self._queue) \
            + sum(1 for _, _w, b, g, _t in self._inflight
                  if not all(x.done for x in g.values()))

    def utilization(self, now: float) -> float:
        return self._busy_frac

    # ------------------------------------------------- placement signals ---
    def pressure(self, now: float) -> ReplicaPressure:
        """Real runtime pressure off the batcher + block allocator:
        free/reserved pool blocks, active slots, admission-queue depth,
        prefix-cache occupancy — the dispatcher's routing inputs."""
        b = self.batcher
        # pending = RECLAIMABLE work only (admission queue, not yet
        # ingested); requests already in the batcher queue are committed
        # to this replica and show up in queue_len alone
        pending = sum(len(g) for _, _w, g in self._queue)
        committed = pending + len(b.queue)
        active = len(b.active_slots())
        p = ReplicaPressure(
            queue_len=self.queue_length(now),
            pending=pending,
            active_slots=active,
            total_slots=b.n_slots,
            # one wave decoding + one wave queued behind it
            admit_capacity=max(2 * b.n_slots - active - committed, 0))
        if b.paged:
            p.free_blocks = max(b.allocator.available(), 0)
            p.reserved_blocks = b.allocator.reserved
            p.pool_blocks = b.allocator.capacity
            if b.prefix_cache is not None:
                p.cached_blocks = len(b.prefix_cache)
        return p

    def prefix_affinity(self, prompt: Any) -> int:
        """Prompt tokens this replica's prefix cache would serve without
        prefill — the dispatcher routes matching requests here."""
        pc = self.batcher.prefix_cache
        if pc is None or prompt is None or len(pc) == 0:
            # empty-cache early-out: the dispatcher probes affinity per
            # scanned queue entry on every fire — skip the hashing
            # until something is actually registered
            return 0
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        return len(pc.match(prompt[:self.serve_prompt_len])) \
            * self.batcher.block_size

    # ------------------------------------------------ elastic / failover ---
    def reclaim_queued(self, max_n: int, now: float) -> List[Request]:
        """Hand back up to ``max_n`` requests from the admission queue
        (newest groups first — they have waited the least here), whole
        groups only; work already inside the batcher is committed."""
        groups: List[List[Request]] = []
        taken = 0
        while self._queue and taken + len(self._queue[-1][2]) <= max_n:
            _, _w, batch = self._queue.pop()
            groups.append(batch)
            taken += len(batch)
        return [r for g in reversed(groups) for r in g]

    def drain_pending(self, now: float) -> List[Request]:
        """Failover teardown: emit every ALREADY-FINISHED generation
        (including finished members of partially-done groups — those
        results were produced; re-serving them would double-count), then
        stop serving and hand back every unfinished request (admission
        queue + in-flight groups) for re-placement on a survivor.
        Partial generations are discarded; the batcher frees all pool
        blocks."""
        self._emit_finished(now)
        out: List[Request] = []
        q = None
        for submit_t, submit_wall, batch, group, t0 in self._inflight:
            gens = list(group.values())
            done = [(r, g) for r, g in zip(batch, gens) if g.done]
            out.extend(r for r, g in zip(batch, gens) if not g.done)
            if not done:
                continue
            if q is None:
                q = self.quality_score(now)
            lat = max(g.finished_wall for _, g in done) - t0
            queue_wait = max(t0 - submit_wall, 0.0)
            tokens = 0
            for r, g in done:
                r.completed_at = now
                r.quality = q
                r.output_tokens = list(g.tokens)
                tokens += len(g.tokens)
            self.on_result(BatchResult(
                replica_id=self.replica_id, batch_size=len(done),
                infer_latency=lat, total_latency=queue_wait + lat,
                queue_latency=queue_wait, finished_at=now, quality=q,
                tokens=tokens, train_batch=self.train_batch),
                batch[0].stream_id)
        self._inflight.clear()
        for _s, _w, batch in self._queue:
            out.extend(batch)
        self._queue.clear()
        self.batcher.drain_all()
        self._busy_frac = 0.0
        for r in out:
            r.completed_at = None
        return out

    # ------------------------------------------------------------ training -
    def set_adapter(self, adapter: Any, version: int) -> None:
        self.lora = adapter
        self.adapter_version = version

    def get_adapter(self) -> Any:
        return self.lora

    def train_round(self, train_batch: int, infer_batch: int, steps: int,
                    now: float) -> TrainRoundStats:
        """One local round through the batcher: each tick is the fused
        combined_step while serving work is in flight, a plain LoRA step
        otherwise."""
        self.train_batch = train_batch
        self._ingest(now)
        t0 = _time.perf_counter()
        n_before = len(self.batcher.train_losses)
        for _ in range(steps):
            self.batcher.step(train_batch=self.data_fn(train_batch),
                              now=now)
            # emit groups the moment they complete so their latency
            # reflects serving time, not the rest of the round; keep
            # feeding the batcher from the admission queue as slots free
            self._emit_finished(now)
            self._ingest(now)
        elapsed = _time.perf_counter() - t0
        # the fused round generates serving tokens too — accrue its busy
        # time so throughput (= tokens / wall_time) stays honest for
        # COMBINED replicas driven outside pump_once
        self.batcher.stats.wall_time += elapsed
        dt = elapsed / max(steps, 1)
        self._busy_frac = 0.9
        losses = self.batcher.train_losses[n_before:]
        before = losses[0] if losses else float("nan")
        after = losses[-1] if losses else float("nan")
        self._last_loss = after
        return TrainRoundStats(
            replica_id=self.replica_id, steps=steps,
            train_batch=train_batch, infer_batch=infer_batch,
            avg_step_time=dt, loss_before=before, loss_after=after,
            noise_scale=8.0, samples=train_batch * steps)

    def quality_score(self, now: float) -> float:
        if self.eval_fn is not None:
            return 1.0 / max(self.eval_fn(self.lora), 1e-6)
        if math.isnan(self._last_loss):
            # serving-only replica with no training signal yet: probe
            # the current adapter's CE on a held-out-style batch so
            # BatchResult.quality tracks the real model, not a constant
            self._last_loss = float(self._jit_loss(
                self.params, self.lora, self.data_fn(4)))
        return 1.0 / max(self._last_loss, 1e-6)
