"""Replica implementations of the ReplicaHandle protocol.

``SimReplica``  — discrete-event replica with analytic interference
                  surfaces (ground truth the control plane must learn).
``LiveReplica`` — real JAX execution: serve/train/combined steps on a
                  (reduced) model, wall-clock latencies.  Used by the
                  integration tests and examples/.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time as _time
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.interfaces import (
    BatchResult, ReplicaHandle, Request, TrainRoundStats,
)


# =========================================================================
# Simulated replica
# =========================================================================
@dataclasses.dataclass
class InterferenceSurface:
    """Ground-truth latency surfaces (bivariate + noise, §2.2).

    Defaults are calibrated to an 8B-class model on a 2-accelerator
    replica: exclusive inference latency 0.02·b + 0.05 s (b=16 ⇒ 0.37 s,
    inside the 0.5 s SLO), training step 0.03·B + 0.10 s, with cross
    terms producing the Fig. 4b interference regime.
    """
    infer_alpha: float = 0.020    # s per inference-batch element
    infer_beta: float = 0.008     # interference from co-running train batch
    infer_gamma: float = 0.050    # fixed cost
    train_alpha: float = 0.030
    train_beta: float = 0.010
    train_gamma: float = 0.100
    noise_frac: float = 0.04      # lognormal-ish multiplicative noise

    def t_infer(self, b: int, train_b: int, rng: np.random.Generator
                ) -> float:
        base = self.infer_alpha * b + self.infer_beta * train_b \
            + self.infer_gamma
        return float(base * rng.lognormal(0.0, self.noise_frac))

    def t_train(self, train_b: int, b: int, rng: np.random.Generator
                ) -> float:
        base = self.train_alpha * train_b + self.train_beta * b \
            + self.train_gamma
        return float(base * rng.lognormal(0.0, self.noise_frac))


@dataclasses.dataclass
class LossCurve:
    """Per-replica fine-tuning dynamics: exponential-decay loss toward a
    data-dependent floor, driven by samples seen; FedAvg pulls members
    toward the cohort mean (heterogeneous data, §4.2)."""
    init_loss: float = 2.4
    floor: float = 0.8
    rate: float = 1.0 / 6000.0    # per training sample
    # effective samples: statistical-efficiency scaling accumulates
    # fractional ``samples * eff`` increments, so this is a float
    seen: float = 0.0

    def loss(self) -> float:
        return self.floor + (self.init_loss - self.floor) \
            * math.exp(-self.rate * self.seen)

    def advance(self, samples: int, batch_size: int = 0
                ) -> Tuple[float, float]:
        """Advance by ``samples``; with a batch size given, apply
        Pollux-style statistical efficiency (McCandlish): per-sample
        progress decays once the batch exceeds the gradient-noise scale
        — the ground truth the Coordinator's Eq. 8 has to learn."""
        before = self.loss()
        eff = 1.0
        if batch_size > 0:
            noise = self.noise_scale()
            eff = (noise + 1.0) / (noise + float(batch_size))
        self.seen += samples * eff
        return before, self.loss()

    def noise_scale(self) -> float:
        """Gradient noise scale grows as loss approaches the floor
        (empirically: later training tolerates larger batches)."""
        prog = 1.0 - (self.loss() - self.floor) \
            / max(self.init_loss - self.floor, 1e-9)
        return 4.0 + 60.0 * prog


class SimReplica:
    """Discrete-event replica.  One batch executes at a time (Eq. 13d);
    a COMBINED-mode training round occupies a parallel 'stream' whose
    only coupling to serving is the interference surface — the simulator
    analogue of the fused XLA program."""

    def __init__(self, replica_id: str, model_id: str, simulator,
                 on_result: Callable[[BatchResult, str], None],
                 surface: Optional[InterferenceSurface] = None,
                 loss_curve: Optional[LossCurve] = None,
                 seed: int = 0, slow_factor: float = 1.0):
        self.replica_id = replica_id
        self.model_id = model_id
        self.sim = simulator
        self.on_result = on_result
        self.surface = surface or InterferenceSurface()
        self.loss_curve = loss_curve or LossCurve()
        self.rng = np.random.default_rng(seed)
        self.slow_factor = slow_factor          # straggler injection
        self.failed = False

        self.busy_until: float = 0.0
        self.pending: Deque[Tuple[float, List[Request]]] = collections.deque()
        # scheduled-but-unfinished work: (finish_time, n_requests)
        self.outstanding: Deque[Tuple[float, int]] = collections.deque()
        self.train_batch: int = 0               # active co-running B
        self.training_until: float = 0.0
        self.adapter: Any = {"version": 0}
        self.adapter_version: int = 0
        # busy-interval bookkeeping for utilization()
        self.busy_intervals: Deque[Tuple[float, float]] = collections.deque(
            maxlen=4096)
        self.served_requests: int = 0
        self.served_tokens: int = 0
        self.total_infer_time: float = 0.0
        self.total_train_time: float = 0.0

    # ------------------------------------------------------------- serving -
    def submit_batch(self, requests: Sequence[Request], now: float) -> None:
        if self.failed or not requests:
            return
        self.pending.append((now, list(requests)))
        self._drain(now)

    def _drain(self, now: float) -> None:
        while self.pending:
            submit_t, batch = self.pending.popleft()
            start = max(now, self.busy_until)
            train_b = self.train_batch if start < self.training_until else 0
            lat = self.surface.t_infer(len(batch), train_b, self.rng) \
                * self.slow_factor
            finish = start + lat
            self.busy_until = finish
            self.busy_intervals.append((start, finish))
            self.outstanding.append((finish, len(batch)))
            q = self.quality_score(now)
            self.sim.schedule(
                finish,
                lambda t, b=batch, s=submit_t, st=start, l=lat,
                tb=train_b, qq=q: self._complete(t, b, s, st, l, tb, qq),
                tag=f"batch:{self.replica_id}")

    def _complete(self, now: float, batch: List[Request], submit_t: float,
                  start: float, lat: float, train_b: int, q: float) -> None:
        tokens = 0
        queue_waits = []
        for r in batch:
            r.completed_at = now
            r.quality = q
            tokens += r.tokens
            # T_queue per the paper §6.2: everything before processing
            # starts — dispatcher pacing wait included ("the cost of
            # controllability"), not just replica-side queueing.
            queue_waits.append(start - r.arrival)
        self.served_requests += len(batch)
        self.served_tokens += tokens
        self.total_infer_time += lat
        stream = batch[0].stream_id
        self.on_result(BatchResult(
            replica_id=self.replica_id, batch_size=len(batch),
            infer_latency=lat, total_latency=now - submit_t,
            queue_latency=float(np.mean(queue_waits)), finished_at=now,
            quality=q, tokens=tokens, train_batch=train_b), stream)

    # ------------------------------------------------------------ telemetry
    def _prune_outstanding(self, now: float) -> None:
        while self.outstanding and self.outstanding[0][0] <= now:
            self.outstanding.popleft()

    def queue_length(self, now: float) -> int:
        """Requests accepted but not yet finished."""
        self._prune_outstanding(now)
        return sum(n for _, n in self.outstanding) \
            + sum(len(b) for _, b in self.pending)

    def outstanding_batches(self, now: float) -> int:
        self._prune_outstanding(now)
        return len(self.outstanding) + len(self.pending)

    def utilization(self, now: float, window: float = 10.0) -> float:
        lo = now - window
        busy = 0.0
        for s, e in self.busy_intervals:
            if e <= lo or s >= now:   # outside window / scheduled ahead
                continue
            busy += max(min(e, now) - max(s, lo), 0.0)
        util = busy / window
        if now < self.training_until and self.train_batch > 0:
            util += 0.75  # co-running fine-tuning soaks spare compute
        return float(min(util, 1.0))

    # ------------------------------------------------------------ training -
    def set_adapter(self, adapter: Any, version: int) -> None:
        self.adapter = adapter
        self.adapter_version = version

    def get_adapter(self) -> Any:
        return self.adapter

    def train_round(self, train_batch: int, infer_batch: int, steps: int,
                    now: float) -> TrainRoundStats:
        step_time = self.surface.t_train(train_batch, infer_batch,
                                         self.rng) * self.slow_factor
        samples = train_batch * steps
        before, after = self.loss_curve.advance(samples, train_batch)
        self.train_batch = train_batch
        self.training_until = max(self.training_until,
                                  now + steps * step_time)
        self.total_train_time += steps * step_time
        return TrainRoundStats(
            replica_id=self.replica_id, steps=steps,
            train_batch=train_batch, infer_batch=infer_batch,
            avg_step_time=step_time, loss_before=before, loss_after=after,
            noise_scale=self.loss_curve.noise_scale(), samples=samples)

    def quality_score(self, now: float) -> float:
        """§8.1: response quality = 1 / CE-loss of the current model."""
        return 1.0 / max(self.loss_curve.loss(), 1e-6)

    # --------------------------------------------------------------- faults
    def fail(self, now: float) -> None:
        self.failed = True
        self.pending.clear()

    def recover(self, now: float) -> None:
        self.failed = False
        self.busy_until = now


# =========================================================================
# Live replica (real JAX execution)
# =========================================================================
class LiveReplica:
    """Runs actual JAX serving + training (reduced models) and measures
    wall-clock — the end-to-end integration path.

    Serving goes through the slot-based ``ContinuousBatcher``
    (``runtime.serving_loop``): submitted requests become real
    prefill-then-decode generation over shared caches, and a COMBINED
    train round executes the fused ``combined_step`` per decode tick
    whenever serving work is in flight (training + decode in one XLA
    program over shared base weights)."""

    def __init__(self, replica_id: str, model_id: str, engine,
                 params, lora, opt_state,
                 on_result: Callable[[BatchResult, str], None],
                 data_fn: Callable[[int], Dict[str, Any]],
                 eval_fn: Optional[Callable[[Any], float]] = None,
                 serve_slots: int = 4, serve_prompt_len: int = 16,
                 max_gen_tokens: int = 8, serve_paged: bool = False,
                 serve_block_size: int = 16,
                 serve_n_blocks: Optional[int] = None,
                 serve_prefix_cache: bool = False):
        from repro.runtime.serving_loop import ContinuousBatcher
        self.replica_id = replica_id
        self.model_id = model_id
        self.engine = engine
        self.params = params
        self.on_result = on_result
        self.data_fn = data_fn          # batch_size -> training batch dict
        self.eval_fn = eval_fn          # lora -> eval CE loss
        self.adapter_version = 0
        self.train_batch = 0
        self.serve_prompt_len = serve_prompt_len
        self.max_gen_tokens = max_gen_tokens
        # (submit_t on the caller's clock, submit wall stamp, [Request])
        self._queue: Deque[Tuple[float, float, List[Request]]] = \
            collections.deque()
        # submitted-but-unfinished groups: (submit_t, submit_wall,
        # [Request], {gen_id: GenRequest}, ingest wall stamp)
        self._inflight: List[Tuple[float, float, List[Request],
                                   Dict[int, Any], float]] = []
        self._gen_counter = 0
        self._busy_frac = 0.0
        self._last_loss = float("nan")
        self.batcher = ContinuousBatcher(
            engine, params, lora, n_slots=serve_slots,
            max_seq=serve_prompt_len + max_gen_tokens,
            prompt_pad=serve_prompt_len, opt_state=opt_state,
            paged=serve_paged, block_size=serve_block_size,
            n_blocks=serve_n_blocks, prefix_cache=serve_prefix_cache)
        from repro.runtime.serving_loop import _engine_jits
        self._jit_loss = _engine_jits(engine)["loss"]

    # adapter + optimizer state live in the batcher so the fused path
    # can donate/update them in place
    @property
    def lora(self):
        return self.batcher.lora

    @lora.setter
    def lora(self, value):
        self.batcher.lora = value
        # new adapter -> any cached CE probe is stale
        self._last_loss = float("nan")

    @property
    def opt_state(self):
        return self.batcher.opt_state

    @opt_state.setter
    def opt_state(self, value):
        self.batcher.opt_state = value

    # ------------------------------------------------------------- serving -
    def submit_batch(self, requests: Sequence[Request], now: float) -> None:
        self._queue.append((now, _time.perf_counter(), list(requests)))

    def _ingest(self, now: float) -> None:
        """Turn queued control-plane Requests into generation requests on
        the continuous batcher (prompts drawn from the replica's data
        distribution; requested output length capped to the smoke
        budget)."""
        from repro.runtime.serving_loop import GenRequest
        while self._queue:
            submit_t, submit_wall, batch = self._queue.popleft()
            prompts = np.asarray(
                self.data_fn(len(batch))["tokens"])[:, :self.serve_prompt_len]
            group: Dict[int, Any] = {}
            for r, prompt in zip(batch, prompts):
                g = GenRequest(
                    request_id=self._gen_counter, prompt=prompt,
                    max_new_tokens=min(r.tokens, self.max_gen_tokens),
                    arrival=now)
                self._gen_counter += 1
                self.batcher.submit(g)
                group[g.request_id] = g
            self._inflight.append((submit_t, submit_wall, batch, group,
                                   _time.perf_counter()))

    def _emit_finished(self, now: float) -> None:
        still = []
        q = None
        for submit_t, submit_wall, batch, group, t0 in self._inflight:
            if not all(g.done for g in group.values()):
                still.append((submit_t, submit_wall, batch, group, t0))
                continue
            if q is None:
                q = self.quality_score(now)
            # every latency is a WALL-CLOCK duration measured on one
            # clock: queue wait = submit -> ingest, serving = ingest ->
            # the LAST request's finish stamp (not whenever the control
            # plane got around to emitting), total = their sum.
            lat = max(g.finished_wall for g in group.values()) - t0
            queue_wait = max(t0 - submit_wall, 0.0)
            tokens = sum(len(g.tokens) for g in group.values())
            # timestamps stay on the CALLER's clock (``now`` may be
            # simulated time): completion is observed at ``now``.  The
            # old ``now + lat`` stamped a timestamp off BOTH clocks —
            # SLO attainment then compared a hybrid against sim
            # deadlines.
            for r in batch:
                r.completed_at = now
                r.quality = q
            self.on_result(BatchResult(
                replica_id=self.replica_id, batch_size=len(batch),
                infer_latency=lat, total_latency=queue_wait + lat,
                queue_latency=queue_wait,
                finished_at=now, quality=q, tokens=tokens,
                train_batch=self.train_batch), batch[0].stream_id)
        self._inflight = still

    def pump(self, now: float) -> None:
        """Synchronously drain queued serving work through the
        continuous batcher (examples drive this)."""
        self._ingest(now)
        while not self.batcher.idle():
            self.batcher.step(now=now)
            self._emit_finished(now)

    def queue_length(self, now: float) -> int:
        return sum(len(b) for _, _w, b in self._queue) \
            + sum(len(b) for _, _w, b, g, _t in self._inflight
                  if not all(x.done for x in g.values()))

    def utilization(self, now: float) -> float:
        return self._busy_frac

    # ------------------------------------------------------------ training -
    def set_adapter(self, adapter: Any, version: int) -> None:
        self.lora = adapter
        self.adapter_version = version

    def get_adapter(self) -> Any:
        return self.lora

    def train_round(self, train_batch: int, infer_batch: int, steps: int,
                    now: float) -> TrainRoundStats:
        """One local round through the batcher: each tick is the fused
        combined_step while serving work is in flight, a plain LoRA step
        otherwise."""
        self.train_batch = train_batch
        self._ingest(now)
        t0 = _time.perf_counter()
        n_before = len(self.batcher.train_losses)
        for _ in range(steps):
            self.batcher.step(train_batch=self.data_fn(train_batch),
                              now=now)
            # emit groups the moment they complete so their latency
            # reflects serving time, not the rest of the round
            self._emit_finished(now)
        dt = (_time.perf_counter() - t0) / max(steps, 1)
        self._busy_frac = 0.9
        losses = self.batcher.train_losses[n_before:]
        before = losses[0] if losses else float("nan")
        after = losses[-1] if losses else float("nan")
        self._last_loss = after
        return TrainRoundStats(
            replica_id=self.replica_id, steps=steps,
            train_batch=train_batch, infer_batch=infer_batch,
            avg_step_time=dt, loss_before=before, loss_after=after,
            noise_scale=8.0, samples=train_batch * steps)

    def quality_score(self, now: float) -> float:
        if self.eval_fn is not None:
            return 1.0 / max(self.eval_fn(self.lora), 1e-6)
        if math.isnan(self._last_loss):
            # serving-only replica with no training signal yet: probe
            # the current adapter's CE on a held-out-style batch so
            # BatchResult.quality tracks the real model, not a constant
            self._last_loss = float(self._jit_loss(
                self.params, self.lora, self.data_fn(4)))
        return 1.0 / max(self._last_loss, 1e-6)
