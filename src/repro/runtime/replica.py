"""Replica implementations of the ReplicaHandle protocol.

``SimReplica``  — discrete-event replica with analytic interference
                  surfaces (ground truth the control plane must learn).
``LiveReplica`` — real JAX execution: serve/train/combined steps on a
                  (reduced) model, wall-clock latencies.  Used by the
                  integration tests and examples/.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time as _time
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.interfaces import (
    BatchResult, ReplicaHandle, ReplicaPressure, Request, TrainRoundStats,
)
from repro.optim.grad_noise import NoiseScaleEMA, noise_scale_from_microbatches


def tree_finite(tree: Any) -> bool:
    """True iff every leaf of a (possibly nested) array tree is fully
    finite — the publish-gate predicate: a NaN/Inf-poisoned shadow must
    never be swapped into serving."""
    import jax
    import jax.numpy as jnp
    if tree is None:
        return True
    return all(bool(jnp.isfinite(leaf).all())
               for leaf in jax.tree_util.tree_leaves(tree))


# =========================================================================
# Simulated replica
# =========================================================================
@dataclasses.dataclass
class InterferenceSurface:
    """Ground-truth latency surfaces (bivariate + noise, §2.2).

    Defaults are calibrated to an 8B-class model on a 2-accelerator
    replica: exclusive inference latency 0.02·b + 0.05 s (b=16 ⇒ 0.37 s,
    inside the 0.5 s SLO), training step 0.03·B + 0.10 s, with cross
    terms producing the Fig. 4b interference regime.
    """
    infer_alpha: float = 0.020    # s per inference-batch element
    infer_beta: float = 0.008     # interference from co-running train batch
    infer_gamma: float = 0.050    # fixed cost
    train_alpha: float = 0.030
    train_beta: float = 0.010
    train_gamma: float = 0.100
    noise_frac: float = 0.04      # lognormal-ish multiplicative noise

    def t_infer(self, b: int, train_b: int, rng: np.random.Generator
                ) -> float:
        base = self.infer_alpha * b + self.infer_beta * train_b \
            + self.infer_gamma
        return float(base * rng.lognormal(0.0, self.noise_frac))

    def t_train(self, train_b: int, b: int, rng: np.random.Generator
                ) -> float:
        base = self.train_alpha * train_b + self.train_beta * b \
            + self.train_gamma
        return float(base * rng.lognormal(0.0, self.noise_frac))


@dataclasses.dataclass
class LossCurve:
    """Per-replica fine-tuning dynamics: exponential-decay loss toward a
    data-dependent floor, driven by samples seen; FedAvg pulls members
    toward the cohort mean (heterogeneous data, §4.2)."""
    init_loss: float = 2.4
    floor: float = 0.8
    rate: float = 1.0 / 6000.0    # per training sample
    # effective samples: statistical-efficiency scaling accumulates
    # fractional ``samples * eff`` increments, so this is a float
    seen: float = 0.0

    def loss(self) -> float:
        return self.floor + (self.init_loss - self.floor) \
            * math.exp(-self.rate * self.seen)

    def advance(self, samples: int, batch_size: int = 0
                ) -> Tuple[float, float]:
        """Advance by ``samples``; with a batch size given, apply
        Pollux-style statistical efficiency (McCandlish): per-sample
        progress decays once the batch exceeds the gradient-noise scale
        — the ground truth the Coordinator's Eq. 8 has to learn."""
        before = self.loss()
        eff = 1.0
        if batch_size > 0:
            noise = self.noise_scale()
            eff = (noise + 1.0) / (noise + float(batch_size))
        self.seen += samples * eff
        return before, self.loss()

    def noise_scale(self) -> float:
        """Gradient noise scale grows as loss approaches the floor
        (empirically: later training tolerates larger batches)."""
        prog = 1.0 - (self.loss() - self.floor) \
            / max(self.init_loss - self.floor, 1e-9)
        return 4.0 + 60.0 * prog


class SimReplica:
    """Discrete-event replica.  One batch executes at a time (Eq. 13d);
    a COMBINED-mode training round occupies a parallel 'stream' whose
    only coupling to serving is the interference surface — the simulator
    analogue of the fused XLA program."""

    def __init__(self, replica_id: str, model_id: str, simulator,
                 on_result: Callable[[BatchResult, str], None],
                 surface: Optional[InterferenceSurface] = None,
                 loss_curve: Optional[LossCurve] = None,
                 seed: int = 0, slow_factor: float = 1.0):
        self.replica_id = replica_id
        self.model_id = model_id
        self.sim = simulator
        self.on_result = on_result
        self.surface = surface or InterferenceSurface()
        self.loss_curve = loss_curve or LossCurve()
        self.rng = np.random.default_rng(seed)
        self.slow_factor = slow_factor          # straggler injection
        self.failed = False

        self.busy_until: float = 0.0
        self.pending: Deque[Tuple[float, List[Request]]] = collections.deque()
        # scheduled-but-unfinished work: (finish_time, n_requests)
        self.outstanding: Deque[Tuple[float, int]] = collections.deque()
        self.train_batch: int = 0               # active co-running B
        self.training_until: float = 0.0
        self.adapter: Any = {"version": 0}
        self.adapter_version: int = 0
        # active incremental round:
        # ((train_batch, infer_batch, steps, step_time), started, done)
        self._round: Optional[Tuple[Tuple[int, int, int, float],
                                    float, float]] = None
        # busy-interval bookkeeping for utilization()
        self.busy_intervals: Deque[Tuple[float, float]] = collections.deque(
            maxlen=4096)
        self.served_requests: int = 0
        self.served_tokens: int = 0
        self.total_infer_time: float = 0.0
        self.total_train_time: float = 0.0

    # ------------------------------------------------------------- serving -
    def submit_batch(self, requests: Sequence[Request], now: float) -> None:
        if self.failed or not requests:
            return
        self.pending.append((now, list(requests)))
        self._drain(now)

    def _drain(self, now: float) -> None:
        while self.pending:
            submit_t, batch = self.pending.popleft()
            start = max(now, self.busy_until)
            train_b = self.train_batch if start < self.training_until else 0
            lat = self.surface.t_infer(len(batch), train_b, self.rng) \
                * self.slow_factor
            finish = start + lat
            self.busy_until = finish
            self.busy_intervals.append((start, finish))
            self.outstanding.append((finish, len(batch)))
            q = self.quality_score(now)
            self.sim.schedule(
                finish,
                lambda t, b=batch, s=submit_t, st=start, l=lat,
                tb=train_b, qq=q: self._complete(t, b, s, st, l, tb, qq),
                tag=f"batch:{self.replica_id}")

    def _complete(self, now: float, batch: List[Request], submit_t: float,
                  start: float, lat: float, train_b: int, q: float) -> None:
        tokens = 0
        queue_waits = []
        for r in batch:
            r.completed_at = now
            r.quality = q
            tokens += r.tokens
            # T_queue per the paper §6.2: everything before processing
            # starts — dispatcher pacing wait included ("the cost of
            # controllability"), not just replica-side queueing.
            queue_waits.append(start - r.arrival)
        self.served_requests += len(batch)
        self.served_tokens += tokens
        self.total_infer_time += lat
        stream = batch[0].stream_id
        self.on_result(BatchResult(
            replica_id=self.replica_id, batch_size=len(batch),
            infer_latency=lat, total_latency=now - submit_t,
            queue_latency=float(np.mean(queue_waits)), finished_at=now,
            quality=q, tokens=tokens, train_batch=train_b), stream)

    # ------------------------------------------------------------ telemetry
    def _prune_outstanding(self, now: float) -> None:
        while self.outstanding and self.outstanding[0][0] <= now:
            self.outstanding.popleft()

    def queue_length(self, now: float) -> int:
        """Requests accepted but not yet finished."""
        self._prune_outstanding(now)
        return sum(n for _, n in self.outstanding) \
            + sum(len(b) for _, b in self.pending)

    def outstanding_batches(self, now: float) -> int:
        self._prune_outstanding(now)
        return len(self.outstanding) + len(self.pending)

    def utilization(self, now: float, window: float = 10.0) -> float:
        lo = now - window
        busy = 0.0
        for s, e in self.busy_intervals:
            if e <= lo or s >= now:   # outside window / scheduled ahead
                continue
            busy += max(min(e, now) - max(s, lo), 0.0)
        util = busy / window
        if now < self.training_until and self.train_batch > 0:
            util += 0.75  # co-running fine-tuning soaks spare compute
        return float(min(util, 1.0))

    # ------------------------------------------------- placement signals ---
    def pressure(self, now: float) -> ReplicaPressure:
        """Analytic stand-in for the live runtime's pressure export: one
        execution unit, queue depth as the load signal, no block pool."""
        self._prune_outstanding(now)
        return ReplicaPressure(
            queue_len=self.queue_length(now),
            pending=sum(len(b) for _, b in self.pending),
            active_slots=1 if self.busy_until > now else 0,
            total_slots=1)

    def prefix_affinity(self, prompt: Any,
                        adapter_id: Optional[str] = None) -> int:
        return 0    # analytic latencies never look at prompt content

    def reclaim_queued(self, max_n: int, now: float) -> List[Request]:
        # ``_drain`` schedules every submitted batch synchronously, so
        # there is never unstarted work to hand back
        return []

    def drain_pending(self, now: float) -> List[Request]:
        # nothing to hand back: ``_drain`` schedules every submitted
        # batch synchronously, and scheduled sim events run to
        # completion (like a batch already on the accelerator)
        return []

    # ------------------------------------------------------------ training -
    def set_adapter(self, adapter: Any, version: int) -> None:
        self.adapter = adapter
        self.adapter_version = version

    def get_adapter(self) -> Any:
        return self.adapter

    def train_round(self, train_batch: int, infer_batch: int, steps: int,
                    now: float) -> TrainRoundStats:
        step_time = self.surface.t_train(train_batch, infer_batch,
                                         self.rng) * self.slow_factor
        samples = train_batch * steps
        before, after = self.loss_curve.advance(samples, train_batch)
        self.train_batch = train_batch
        self.training_until = max(self.training_until,
                                  now + steps * step_time)
        self.total_train_time += steps * step_time
        return TrainRoundStats(
            replica_id=self.replica_id, steps=steps,
            train_batch=train_batch, infer_batch=infer_batch,
            avg_step_time=step_time, loss_before=before, loss_after=after,
            noise_scale=self.loss_curve.noise_scale(), samples=samples)

    # ------------------------------------------- incremental sessions ------
    def begin_round(self, train_batch: int, infer_batch: int, steps: int,
                    now: float) -> None:
        """Non-blocking round: the training WINDOW is billed up front
        (the interference surface sees the co-running batch for its
        duration), but the round's EFFECTS — loss-curve advance, train
        time — land only at ``finish_round``, so an aborted round
        leaves quality at the last published state exactly like the
        live path's discarded shadow."""
        if self._round is not None:
            raise RuntimeError(
                f"{self.replica_id}: train round already active")
        step_time = self.surface.t_train(train_batch, infer_batch,
                                         self.rng) * self.slow_factor
        self.train_batch = train_batch
        self.training_until = max(self.training_until,
                                  now + steps * step_time)
        self._round = ((train_batch, infer_batch, steps, step_time),
                       now, now + steps * step_time)

    def round_progress(self, now: float) -> float:
        if self._round is None:
            return 1.0
        _, t0, t1 = self._round
        if t1 <= t0:
            return 1.0
        return float(min(max((now - t0) / (t1 - t0), 0.0), 1.0))

    def finish_round(self, now: float) -> TrainRoundStats:
        if self._round is None:
            raise RuntimeError(f"{self.replica_id}: no active round")
        (train_batch, infer_batch, steps, step_time), _, _ = self._round
        self._round = None
        self.train_batch = 0
        samples = train_batch * steps
        before, after = self.loss_curve.advance(samples, train_batch)
        self.total_train_time += steps * step_time
        return TrainRoundStats(
            replica_id=self.replica_id, steps=steps,
            train_batch=train_batch, infer_batch=infer_batch,
            avg_step_time=step_time, loss_before=before,
            loss_after=after,
            noise_scale=self.loss_curve.noise_scale(), samples=samples)

    def publish_adapter(self) -> int:
        # the analytic replica has no shadow tree — ``finish_round``
        # already advanced the loss curve the adapter stands for
        return self.adapter_version

    def abort_round(self, now: float) -> None:
        """§8.2 suspension: drop the pending round WITHOUT its effects
        (no loss advance, no train-time billing) and stop the
        co-running interference at ``now``."""
        self._round = None
        self.train_batch = 0
        self.training_until = min(self.training_until, now)

    def quality_score(self, now: float) -> float:
        """§8.1: response quality = 1 / CE-loss of the current model."""
        return 1.0 / max(self.loss_curve.loss(), 1e-6)

    # --------------------------------------------------------------- faults
    def fail(self, now: float) -> None:
        self.failed = True
        self.pending.clear()

    def recover(self, now: float) -> None:
        self.failed = False
        self.busy_until = now


# =========================================================================
# Live replica (real JAX execution)
# =========================================================================
@dataclasses.dataclass
class TrainSession:
    """One incremental COMBINED train round, advanced ONE fused
    ``combined_step`` tick at a time inside ``pump_once`` — the fabric
    loop interleaves it with every other replica's serving instead of a
    blocking whole-round call monopolizing the device.

    The optimizer donates into the replica's SHADOW adapter for the
    whole session; prefill/decode keep reading the published snapshot,
    so greedy serving output is bit-identical to serve-only until
    ``publish_adapter`` swaps the trees at the round boundary."""
    train_batch: int
    infer_batch: int
    steps: int
    started_at: float               # caller's clock
    grad_accum: int = 1             # microbatch split for the p_t probe
    steps_done: int = 0
    busy_time: float = 0.0          # wall seconds inside session ticks
    samples_done: int = 0           # train rows actually stepped (budget
    #                                 scheduler may shrink a tick's batch)
    losses: List[float] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return self.steps_done >= self.steps

    @property
    def progress(self) -> float:
        if self.steps <= 0:
            return 1.0      # a zero-step round is born complete
        return min(self.steps_done / self.steps, 1.0)


class LiveReplica:
    """Runs actual JAX serving + training (reduced models) and measures
    wall-clock — the end-to-end integration path.

    Serving goes through the slot-based ``ContinuousBatcher``
    (``runtime.serving_loop``): submitted requests become real
    prefill-then-decode generation over shared caches, and a COMBINED
    train round executes the fused ``combined_step`` per decode tick
    whenever serving work is in flight (training + decode in one XLA
    program over shared base weights)."""

    def __init__(self, replica_id: str, model_id: str, engine,
                 params, lora, opt_state,
                 on_result: Callable[[BatchResult, str], None],
                 data_fn: Callable[[int], Dict[str, Any]],
                 eval_fn: Optional[Callable[[Any], float]] = None,
                 serve_slots: int = 4, serve_prompt_len: int = 16,
                 max_gen_tokens: int = 8, serve_paged: bool = False,
                 serve_block_size: int = 16,
                 serve_n_blocks: Optional[int] = None,
                 serve_prefix_cache: bool = False,
                 adapters: Any = None,
                 train_tenant: Optional[str] = None,
                 injector: Any = None,
                 serve_prefill_chunk: int = 0,
                 serve_tpot_target: float = 0.0,
                 serve_oversubscribe: float = 0.0,
                 serve_swap: bool = True):
        from repro.runtime.serving_loop import ContinuousBatcher
        self.replica_id = replica_id
        self.model_id = model_id
        self.engine = engine
        self.params = params
        self.on_result = on_result
        self.data_fn = data_fn          # batch_size -> training batch dict
        self.eval_fn = eval_fn          # lora -> eval CE loss
        self.adapter_version = 0
        self.train_batch = 0
        self.serve_prompt_len = serve_prompt_len
        self.max_gen_tokens = max_gen_tokens
        # (submit_t on the caller's clock, submit wall stamp, [Request])
        self._queue: Deque[Tuple[float, float, List[Request]]] = \
            collections.deque()
        # submitted-but-unfinished groups: (submit_t, submit_wall,
        # [Request], {gen_id: GenRequest}, ingest wall stamp)
        self._inflight: List[Tuple[float, float, List[Request],
                                   Dict[int, Any], float]] = []
        self._gen_counter = 0
        self._busy_frac = 0.0
        self._last_loss = float("nan")
        # incremental COMBINED round state
        self._session: Optional[TrainSession] = None
        self._pending_tb: Optional[Dict[str, Any]] = None
        self._noise_ema = NoiseScaleEMA()
        # per-tick busy-time accounting: (wall stamp at tick end, tick
        # seconds) over a trailing window — the replica's REAL busy
        # fraction, train and serve ticks alike
        self._busy_log: Deque[Tuple[float, float]] = collections.deque(
            maxlen=1024)
        self._busy_window = 2.0
        # multi-tenant serving: the AdapterRegistry routing decode rows
        # per tenant, and which tenant mirrors the co-training adapter
        # (publish_adapter/set_adapter write through to its registry
        # entry so its requests see each published round)
        self.adapters = adapters
        self.train_tenant = train_tenant
        # chaos hooks (runtime.fault.FaultInjector or None): consulted
        # at pump top (crash/stall), admission (oom), and after train
        # ticks (nan_grads) — injected crashes/OOMs RAISE out of
        # pump_once; the fabric tick contains them as detected failures
        self.injector = injector
        self.batcher = ContinuousBatcher(
            engine, params, lora, n_slots=serve_slots,
            max_seq=serve_prompt_len + max_gen_tokens,
            prompt_pad=serve_prompt_len, opt_state=opt_state,
            paged=serve_paged, block_size=serve_block_size,
            n_blocks=serve_n_blocks, prefix_cache=serve_prefix_cache,
            adapters=adapters, prefill_chunk=serve_prefill_chunk,
            tpot_target=serve_tpot_target,
            oversubscribe=serve_oversubscribe, swap=serve_swap)
        from repro.runtime.serving_loop import _engine_jits
        self._jit_loss = _engine_jits(engine)["loss"]

    # adapter + optimizer state live in the batcher so the fused path
    # can donate/update them in place
    @property
    def lora(self):
        return self.batcher.lora

    @lora.setter
    def lora(self, value):
        self.batcher.lora = value
        # new adapter -> any cached CE probe is stale
        self._last_loss = float("nan")

    @property
    def opt_state(self):
        return self.batcher.opt_state

    @opt_state.setter
    def opt_state(self, value):
        self.batcher.opt_state = value

    # ------------------------------------------------------------- serving -
    def submit_batch(self, requests: Sequence[Request], now: float) -> None:
        self._queue.append((now, _time.perf_counter(), list(requests)))

    def _ingest(self, now: float) -> None:
        """Move admissible groups from the replica's admission queue to
        the continuous batcher.  Ingestion is HEADROOM-GATED: groups
        stay in the admission queue while the batcher already holds a
        full slot wave of queued work, so the micro-cycle can still
        reclaim them for rebalancing (work inside the batcher queue is
        committed to this replica).  Prompts come from the control-plane
        Request when it carries one (multi-replica routing needs
        identical prompts on every replica), the replica's data
        distribution otherwise."""
        from repro.runtime.serving_loop import GenRequest
        while self._queue \
                and len(self.batcher.queue) < self.batcher.n_slots:
            if self.injector is not None:
                self.injector.at_admission(self.replica_id, now)
            submit_t, submit_wall, batch = self._queue.popleft()
            drawn = None
            if any(r.prompt is None for r in batch):
                drawn = np.asarray(self.data_fn(
                    len(batch))["tokens"])[:, :self.serve_prompt_len]
            group: Dict[int, Any] = {}
            for j, r in enumerate(batch):
                prompt = np.asarray(
                    r.prompt, np.int32)[:self.serve_prompt_len] \
                    if r.prompt is not None else drawn[j]
                g = GenRequest(
                    request_id=self._gen_counter, prompt=prompt,
                    max_new_tokens=min(r.tokens, self.max_gen_tokens),
                    arrival=now, adapter_id=r.adapter_id,
                    deadline=r.deadline,
                    temperature=r.temperature,
                    top_k=r.top_k, top_p=r.top_p,
                    # seed from the CONTROL-plane id, never the
                    # per-replica gen counter: sampled streams must not
                    # depend on placement or failover re-queues
                    seed=r.seed if r.seed is not None else r.request_id)
                self._gen_counter += 1
                self.batcher.submit(g)
                group[g.request_id] = g
            self._inflight.append((submit_t, submit_wall, batch, group,
                                   _time.perf_counter()))

    def _emit_finished(self, now: float) -> None:
        still = []
        q = None
        for submit_t, submit_wall, batch, group, t0 in self._inflight:
            if not all(g.done for g in group.values()):
                still.append((submit_t, submit_wall, batch, group, t0))
                continue
            if q is None:
                q = self.quality_score(now)
            # every latency is a WALL-CLOCK duration measured on one
            # clock: queue wait = submit -> ingest, serving = ingest ->
            # the LAST request's finish stamp (not whenever the control
            # plane got around to emitting), total = their sum.
            lat = max(g.finished_wall for g in group.values()) - t0
            queue_wait = max(t0 - submit_wall, 0.0)
            tokens = sum(len(g.tokens) for g in group.values())
            # timestamps stay on the CALLER's clock (``now`` may be
            # simulated time): completion is observed at ``now``.  The
            # old ``now + lat`` stamped a timestamp off BOTH clocks —
            # SLO attainment then compared a hybrid against sim
            # deadlines.
            for r, g in zip(batch, group.values()):
                r.completed_at = now
                r.quality = q
                r.output_tokens = list(g.tokens)
            self.on_result(BatchResult(
                replica_id=self.replica_id, batch_size=len(batch),
                infer_latency=lat, total_latency=queue_wait + lat,
                queue_latency=queue_wait,
                finished_at=now, quality=q, tokens=tokens,
                train_batch=self.train_batch), batch[0].stream_id)
        self._inflight = still

    def pump(self, now: float) -> None:
        """Synchronously drain queued serving work through the
        continuous batcher (examples drive this)."""
        self._ingest(now)
        while not self.batcher.idle():
            self.batcher.step(now=now)
            self._emit_finished(now)
            self._ingest(now)

    def pump_once(self, now: float) -> bool:
        """ONE runtime tick: ingest admissible groups, advance every
        active slot one token, emit finished groups.  The multi-replica
        fabric round-robins this so replicas interleave instead of one
        ``pump`` monopolizing the device.  With a train session active,
        the same tick runs the fused ``combined_step``: the shadow
        adapter takes one optimizer step while the decode wave reads the
        published snapshot — and a tick with no serving work still
        advances the session through a plain shadow train step.  Returns
        True while the replica holds unfinished SERVING work (training
        progress is the Launcher's to poll, not a reason to spin the
        trace loop)."""
        if self.injector is not None:
            # chaos hooks: an injected crash raises out of this pump
            # (the fabric tick converts it into a detected failure); a
            # stall sleeps here, inflating this tick's latency into the
            # straggler watch
            self.injector.before_pump(self.replica_id, now)
        self._ingest(now)
        sess = self._session
        train_due = sess is not None and not sess.done
        serving = not self.batcher.idle()
        if serving or train_due:
            # sticky train batch: a budget-skipped tick re-offers the
            # SAME drawn batch next tick, so the trained sequence walks
            # the finite pool in deterministic epoch order no matter
            # which wall-clock ticks had slack
            tb = None
            if train_due:
                tb = self._pending_tb
                if tb is None:
                    tb = self.data_fn(sess.train_batch)
            t0 = _time.perf_counter()
            self.batcher.step(train_batch=tb, now=now)
            dt = _time.perf_counter() - t0
            if serving:
                # per-replica busy time: this replica's share of the
                # device (per-replica throughput = its tokens / its
                # stepping time); train-only ticks generate no tokens
                # and must not dilute serving throughput
                self.batcher.stats.wall_time += dt
                self._emit_finished(now)
            self._account_busy(dt)
            if train_due:
                self._pending_tb = None \
                    if self.batcher.last_tick_trained else tb
            if train_due and self.batcher.last_tick_trained:
                # budget-gated co-scheduling: the batcher may SKIP the
                # train leg on a tick whose SLO slack is spent (tt is
                # None) — a skipped tick advances neither steps_done nor
                # the loss log, so rounds report only real steps
                sess.steps_done += 1
                sess.busy_time += dt
                sess.samples_done += self.batcher.last_tick_train_rows
                m = self.batcher.last_train_metrics
                sess.losses.append(m["ce_loss"])
                if self.batcher.last_tick_train_rows >= sess.train_batch:
                    # shrunk microbatches fold grad_accum to 1 — their
                    # |g|² is not the probe's microbatch statistic
                    self._observe_noise(m, sess)
            if train_due and self.injector is not None and self.injector \
                    .poison_grads(self.replica_id, now):
                self._poison_shadow()
        self._busy_frac = self._measured_busy_frac()
        return bool(self._queue or self._inflight
                    or not self.batcher.idle())

    def queue_length(self, now: float) -> int:
        return sum(len(b) for _, _w, b in self._queue) \
            + sum(len(b) for _, _w, b, g, _t in self._inflight
                  if not all(x.done for x in g.values()))

    def outstanding_batches(self, now: float) -> int:
        """Submitted-but-unfinished groups — the dispatcher's in-flight
        backpressure unit."""
        return len(self._queue) \
            + sum(1 for _, _w, b, g, _t in self._inflight
                  if not all(x.done for x in g.values()))

    def utilization(self, now: float) -> float:
        return self._busy_frac

    # --------------------------------------------- busy-time accounting ----
    def _account_busy(self, dt: float) -> None:
        self._busy_log.append((_time.perf_counter(), dt))

    def _measured_busy_frac(self) -> float:
        """Busy fraction over the trailing window of per-tick busy-time
        accounting: wall seconds spent stepping (serve + train ticks)
        divided by the window actually covered.  Decays to 0 once the
        replica stops ticking — the SERVING→IDLE signal the state
        manager's Eq. 1 consumes."""
        if not self._busy_log:
            return 0.0
        t_now = _time.perf_counter()
        lo = t_now - self._busy_window
        first_end, first_dt = self._busy_log[0]
        span = max(min(self._busy_window,
                       t_now - (first_end - first_dt)), 1e-6)
        busy = sum(d for t, d in self._busy_log if t >= lo)
        return float(min(busy / span, 1.0))

    # ------------------------------------------------- placement signals ---
    def pressure(self, now: float) -> ReplicaPressure:
        """Real runtime pressure off the batcher + block allocator:
        free/reserved pool blocks, active slots, admission-queue depth,
        prefix-cache occupancy — the dispatcher's routing inputs."""
        b = self.batcher
        # pending = RECLAIMABLE work only (admission queue, not yet
        # ingested); requests already in the batcher queue are committed
        # to this replica and show up in queue_len alone
        pending = sum(len(g) for _, _w, g in self._queue)
        # parked (preempted) requests are committed work too: each one
        # re-takes a slot and pool capacity on restore
        committed = pending + len(b.queue) + b.n_preempted
        active = len(b.active_slots())
        p = ReplicaPressure(
            queue_len=self.queue_length(now),
            pending=pending,
            active_slots=active,
            total_slots=b.n_slots,
            # one wave decoding + one wave queued behind it
            admit_capacity=max(2 * b.n_slots - active - committed, 0))
        if b.adapters is not None:
            p.resident_adapters = b.adapters.resident_ids()
        if b.paged:
            p.free_blocks = max(b.allocator.available(), 0)
            p.reserved_blocks = b.allocator.reserved
            p.pool_blocks = b.allocator.capacity
            if b.prefix_cache is not None:
                p.cached_blocks = len(b.prefix_cache)
            # oversubscribed pool: advertise the thrash signal so the
            # dispatcher discounts this replica while requests sit
            # parked off-device waiting for capacity
            p.oversubscribe = b.oversubscribe
            p.preempted = b.n_preempted
        return p

    def prefix_affinity(self, prompt: Any,
                        adapter_id: Optional[str] = None) -> int:
        """Prompt tokens this replica's prefix cache would serve without
        prefill — the dispatcher routes matching requests here.  The
        lookup is scoped to ``adapter_id``'s namespace (cached KV is
        adapter-specific, so another tenant's blocks never count)."""
        pc = self.batcher.prefix_cache
        if pc is None or prompt is None or len(pc) == 0:
            # empty-cache early-out: the dispatcher probes affinity per
            # scanned queue entry on every fire — skip the hashing
            # until something is actually registered
            return 0
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        return len(pc.match(prompt[:self.serve_prompt_len],
                            namespace=adapter_id)) \
            * self.batcher.block_size

    # ------------------------------------------------ elastic / failover ---
    def reclaim_queued(self, max_n: int, now: float) -> List[Request]:
        """Hand back up to ``max_n`` requests from the admission queue
        (newest groups first — they have waited the least here), whole
        groups only; work already inside the batcher is committed."""
        groups: List[List[Request]] = []
        taken = 0
        while self._queue and taken + len(self._queue[-1][2]) <= max_n:
            _, _w, batch = self._queue.pop()
            groups.append(batch)
            taken += len(batch)
        return [r for g in reversed(groups) for r in g]

    def drain_pending(self, now: float) -> List[Request]:
        """Failover teardown: emit every ALREADY-FINISHED generation
        (including finished members of partially-done groups — those
        results were produced; re-serving them would double-count), then
        stop serving and hand back every unfinished request (admission
        queue + in-flight groups) for re-placement on a survivor.
        Partial generations are discarded; the batcher frees all pool
        blocks."""
        self._emit_finished(now)
        out: List[Request] = []
        q = None
        for submit_t, submit_wall, batch, group, t0 in self._inflight:
            gens = list(group.values())
            done = [(r, g) for r, g in zip(batch, gens) if g.done]
            out.extend(r for r, g in zip(batch, gens) if not g.done)
            if not done:
                continue
            if q is None:
                q = self.quality_score(now)
            lat = max(g.finished_wall for _, g in done) - t0
            queue_wait = max(t0 - submit_wall, 0.0)
            tokens = 0
            for r, g in done:
                r.completed_at = now
                r.quality = q
                r.output_tokens = list(g.tokens)
                tokens += len(g.tokens)
            self.on_result(BatchResult(
                replica_id=self.replica_id, batch_size=len(done),
                infer_latency=lat, total_latency=queue_wait + lat,
                queue_latency=queue_wait, finished_at=now, quality=q,
                tokens=tokens, train_batch=self.train_batch),
                batch[0].stream_id)
        self._inflight.clear()
        for _s, _w, batch in self._queue:
            out.extend(batch)
        self._queue.clear()
        self.batcher.drain_all()
        self._busy_frac = 0.0
        for r in out:
            r.completed_at = None
        return out

    # ------------------------------------------------------------ training -
    def set_adapter(self, adapter: Any, version: int) -> None:
        """Publish ``adapter`` as the served snapshot (round boundaries /
        deployment).  A new global landing mid-session ABORTS the
        session outright — shadow and progress discarded — rather than
        silently retargeting the remaining ticks at the served tree
        (which would break the within-round snapshot isolation).

        Publish gate: a non-finite incoming tree (e.g. a FedAvg merge
        over a poisoned member that slipped past the member gates) is
        REJECTED — the served adapter stays at its current finite
        version and the rejection is counted."""
        if not tree_finite(adapter):
            self.batcher.stats.nan_publishes_blocked += 1
            return
        if self._session is not None:
            self.abort_round(0.0)
        self.lora = adapter
        self.adapter_version = version
        self.batcher.train_lora = None
        self.batcher.stats.adapter_version = version
        self._mirror_train_tenant()

    def get_adapter(self) -> Any:
        return self.lora

    # ------------------------------------------- incremental sessions ------
    def begin_round(self, train_batch: int, infer_batch: int, steps: int,
                    now: float) -> None:
        """Open an incremental train session: stage the shadow tree (a
        reference to the published snapshot — JAX arrays are immutable,
        so the first optimizer step forks it) and let ``pump_once``
        advance one fused step per fabric tick."""
        if self._session is not None:
            raise RuntimeError(
                f"{self.replica_id}: train session already active")
        # microbatch split for the gradient-noise probe (Eq. 8's p_t):
        # an even batch trains as 2 microbatches inside the same fused
        # step; odd/unit batches keep the EMA from previous rounds
        accum = 2 if train_batch >= 2 and train_batch % 2 == 0 else 1
        self.batcher.train_lora = self.lora
        self.batcher.train_grad_accum = accum
        self.train_batch = train_batch
        self._pending_tb = None     # batch size may change per round
        self._session = TrainSession(
            train_batch=train_batch, infer_batch=infer_batch,
            steps=steps, started_at=now, grad_accum=accum)

    def round_progress(self, now: float) -> float:
        return 1.0 if self._session is None else self._session.progress

    def finish_round(self, now: float) -> TrainRoundStats:
        """Close the session and report MEASURED round stats: wall time
        per fused step and the gradient-noise scale estimated from the
        session's microbatch gradients (EMA across ticks/rounds) — not
        a hardcoded prior."""
        sess = self._session
        if sess is None:
            raise RuntimeError(f"{self.replica_id}: no active round")
        self._session = None
        # publish gate, round edition: a NaN/Inf shadow (poisoned
        # gradients) aborts the round HERE — the shadow is dropped so
        # the subsequent publish_adapter is a no-op and serving stays
        # at the last finite published version
        if self.batcher.train_lora is not None \
                and not tree_finite(self.batcher.train_lora):
            self.batcher.train_lora = None
            self.batcher.stats.nan_publishes_blocked += 1
        self.batcher.train_grad_accum = 1
        # no training co-runs past this point: results emitted before
        # the next begin_round must not carry a stale interference
        # label (the dispatcher's Eq. 14 fit skips train_batch > 0 rows)
        self.train_batch = 0
        self._busy_frac = self._measured_busy_frac()
        dt = sess.busy_time / max(sess.steps_done, 1)
        noise = self._noise_ema.value if self._noise_ema.initialized \
            else 8.0    # prior until the first even-batch round measures
        # poisoned ticks log NaN CE — report only the finite losses so
        # the Coordinator's Eq. 8 fits never ingest NaN
        fin = [l for l in sess.losses if math.isfinite(l)]
        return TrainRoundStats(
            replica_id=self.replica_id, steps=sess.steps_done,
            train_batch=sess.train_batch, infer_batch=sess.infer_batch,
            avg_step_time=dt,
            loss_before=fin[0] if fin else float("nan"),
            loss_after=fin[-1] if fin else float("nan"),
            noise_scale=noise,
            samples=sess.samples_done if sess.samples_done
            else sess.train_batch * sess.steps_done)

    def publish_adapter(self) -> int:
        """Round boundary: atomically swap the trained shadow into the
        published slot.  Host-side pointer swap — in-flight decodes read
        whichever tree the next tick's program is handed, never a
        half-updated one.

        Publish gate: a non-finite shadow is REJECTED — dropped without
        the swap, so the served adapter (and its registry mirror) stays
        bit-identical at the last published finite version."""
        shadow = self.batcher.train_lora
        if shadow is not None and not tree_finite(shadow):
            self.batcher.train_lora = None
            self.batcher.stats.nan_publishes_blocked += 1
            return self.adapter_version
        if shadow is not None:
            self.lora = shadow          # resets the cached CE probe
            self.batcher.train_lora = None
            if self.batcher.train_losses:
                # the shadow's final train CE is the published model's
                # best available quality estimate (refreshed lazily by
                # the eval probe on the next cold quality_score)
                self._last_loss = self.batcher.train_losses[-1]
            self.adapter_version += 1
            self.batcher.stats.adapter_version = self.adapter_version
            self._mirror_train_tenant()
        return self.adapter_version

    def _mirror_train_tenant(self) -> None:
        """Write the freshly published co-training adapter through to
        its registry tenant: resident slot rewritten in place, so every
        in-flight row of that tenant reads the new version on its next
        tick while other tenants' tokens stay bit-identical."""
        if self.adapters is not None and self.train_tenant is not None:
            self.adapters.update(self.train_tenant, self.lora,
                                 version=self.adapter_version)

    def abort_round(self, now: float) -> None:
        """§8.2 load-surge suspension: drop the session and the shadow
        tree outright — the served adapter stays at the last PUBLISHED
        version, so suspending fine-tuning never perturbs serving."""
        self._session = None
        self.batcher.train_lora = None
        self.batcher.train_grad_accum = 1
        self.train_batch = 0

    def _poison_shadow(self) -> None:
        """Chaos: NaN-fill the session's shadow tree (an injected
        gradient blow-up).  Serving is untouched — the published
        snapshot is a different tree — and the publish gates must
        refuse to ever swap this one in."""
        import jax
        import jax.numpy as jnp
        if self.batcher.train_lora is not None:
            self.batcher.train_lora = jax.tree.map(
                lambda x: jnp.full_like(x, jnp.nan),
                self.batcher.train_lora)

    def _observe_noise(self, metrics: Dict[str, float],
                       sess: TrainSession) -> None:
        """Per-tick gradient-noise-scale measurement (McCandlish
        small/big estimator over the fused step's microbatches)."""
        if sess.grad_accum <= 1:
            return
        est = float(noise_scale_from_microbatches(
            metrics["micro_grad_sqnorm"], metrics["grad_sqnorm"],
            micro_batch=sess.train_batch // sess.grad_accum,
            n_micro=sess.grad_accum))
        if math.isfinite(est):
            # the small/big estimator is ill-conditioned when the signal
            # term ~vanishes (near-random gradients on tiny smoke
            # models): one such tick would dominate the EMA forever, so
            # clip to a band that still spans every plausible B* regime
            self._noise_ema.update(min(max(est, 0.0), 1e4))

    def train_round(self, train_batch: int, infer_batch: int, steps: int,
                    now: float) -> TrainRoundStats:
        """Blocking convenience over the session surface: begin a round,
        drive it to completion through ``pump_once`` ticks (serving
        interleaves exactly as it would under the fabric loop), then
        finish and publish the trained shadow."""
        self.begin_round(train_batch, infer_batch, steps, now)
        while self._session is not None and not self._session.done:
            self.pump_once(now)
        stats = self.finish_round(now)
        self.publish_adapter()
        return stats

    def quality_score(self, now: float) -> float:
        if self.eval_fn is not None:
            return 1.0 / max(self.eval_fn(self.lora), 1e-6)
        if math.isnan(self._last_loss):
            # serving-only replica with no training signal yet: probe
            # the current adapter's CE on a held-out-style batch so
            # BatchResult.quality tracks the real model, not a constant
            self._last_loss = float(self._jit_loss(  # lint: host-sync-ok cold quality probe, cached in _last_loss — not per-token
                self.params, self.lora, self.data_fn(4)))
        return 1.0 / max(self._last_loss, 1e-6)
