"""Baseline serving systems (paper §8.1): dLoRA-like, Shepherd-like,
vanilla PEFT, and round-robin — all running against the same SimReplica
fleet so the comparison isolates the scheduling policy.

None of the baselines fine-tune: they serve static models (constant
response quality), exactly as in the paper's evaluation.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Deque, Dict, List, Optional, Sequence

from repro.core.interfaces import BatchResult, Request
from repro.core.latency_model import LinearLatencyModel


class BaseDispatcher:
    name = "base"

    def __init__(self, replicas: Dict[str, object], slo: float = 0.5):
        self.replicas = replicas
        self.slo = slo
        self.queue: Deque[Request] = collections.deque()
        self.dispatched = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free(self, rid: str, now: float) -> bool:
        r = self.replicas[rid]
        return r.busy_until <= now and not r.pending

    def _take(self, n: int) -> List[Request]:
        out = []
        while self.queue and len(out) < n:
            out.append(self.queue.popleft())
        return out

    def on_tick(self, now: float) -> None:
        raise NotImplementedError


class PEFTDispatcher(BaseDispatcher):
    """Vanilla HF-PEFT-style serving: fixed batch size, FIFO, no SLO
    awareness, no pacing."""
    name = "peft"

    def __init__(self, replicas, slo=0.5, batch_size: int = 8):
        super().__init__(replicas, slo)
        self.batch_size = batch_size

    def on_tick(self, now: float) -> None:
        for rid in self.replicas:
            if not self.queue:
                return
            if self._free(rid, now):
                batch = self._take(self.batch_size)
                if batch:
                    self.replicas[rid].submit_batch(batch, now)
                    self.dispatched += len(batch)


class RoundRobinDispatcher(BaseDispatcher):
    """Fig. 5/13 baseline: requests round-robin'd to per-replica queues
    with the same optimal batch size b* CoLLM would use — the comparison
    that isolates the value of subflow pacing."""
    name = "rr"

    def __init__(self, replicas, slo=0.5, batch_size: int = 16):
        super().__init__(replicas, slo)
        self.batch_size = batch_size
        self._rr = itertools.cycle(list(replicas))
        self.local: Dict[str, Deque[Request]] = {
            rid: collections.deque() for rid in replicas}

    def submit(self, req: Request) -> None:
        self.local[next(self._rr)].append(req)

    def on_tick(self, now: float) -> None:
        for rid, q in self.local.items():
            if q and self._free(rid, now):
                batch = []
                while q and len(batch) < self.batch_size:
                    batch.append(q.popleft())
                self.replicas[rid].submit_batch(batch, now)
                self.dispatched += len(batch)


class ShepherdDispatcher(BaseDispatcher):
    """Shepherd-like: SLO-aware, aggressively prefers large batches — a
    free replica waits (up to a slack) for the queue to fill its
    latency-feasible maximum batch before serving."""
    name = "shepherd"

    def __init__(self, replicas, slo=0.5, wait_slack_frac: float = 0.3):
        super().__init__(replicas, slo)
        self.wait_slack = slo * wait_slack_frac
        self.models: Dict[str, LinearLatencyModel] = {
            rid: LinearLatencyModel() for rid in replicas}

    def observe(self, result: BatchResult) -> None:
        m = self.models.get(result.replica_id)
        if m is not None:
            m.observe(result.batch_size, result.infer_latency)
            m.fit()

    def on_tick(self, now: float) -> None:
        # drop requests that can no longer meet their deadline
        while self.queue and self.queue[0].deadline < now:
            self.queue.popleft()
        for rid in self.replicas:
            if not self.queue:
                return
            if not self._free(rid, now):
                continue
            m = self.models[rid]
            oldest = self.queue[0]
            budget = oldest.deadline - now
            bmax = m.max_batch(budget, floor=1, cap=256) if m.fitted else 32
            if len(self.queue) >= bmax or \
                    (now - oldest.arrival) >= self.wait_slack:
                batch = self._take(bmax)
                if batch:
                    self.replicas[rid].submit_batch(batch, now)
                    self.dispatched += len(batch)


class DLoRADispatcher(BaseDispatcher):
    """dLoRA-like: per-replica queues, dynamic batch sizing under the
    SLO, periodic migration of queued requests from overloaded to
    underloaded replicas (the paper's 'adaptive request migration')."""
    name = "dlora"

    def __init__(self, replicas, slo=0.5, migrate_every: float = 1.0):
        super().__init__(replicas, slo)
        self.local: Dict[str, Deque[Request]] = {
            rid: collections.deque() for rid in replicas}
        self.models: Dict[str, LinearLatencyModel] = {
            rid: LinearLatencyModel() for rid in replicas}
        self.migrate_every = migrate_every
        self._next_migrate = 0.0
        self.migrations = 0

    def submit(self, req: Request) -> None:
        # join the shortest queue
        rid = min(self.local, key=lambda r: len(self.local[r]))
        self.local[rid].append(req)

    def observe(self, result: BatchResult) -> None:
        m = self.models.get(result.replica_id)
        if m is not None:
            m.observe(result.batch_size, result.infer_latency)
            m.fit()

    def on_tick(self, now: float) -> None:
        if now >= self._next_migrate:
            self._migrate(now)
            self._next_migrate = now + self.migrate_every
        for rid, q in self.local.items():
            while q and q[0].deadline < now:
                q.popleft()
            if q and self._free(rid, now):
                m = self.models[rid]
                budget = q[0].deadline - now
                bmax = m.max_batch(budget, floor=1, cap=256) \
                    if m.fitted else 16
                batch = []
                while q and len(batch) < bmax:
                    batch.append(q.popleft())
                self.replicas[rid].submit_batch(batch, now)
                self.dispatched += len(batch)

    def _migrate(self, now: float) -> None:
        sizes = {rid: len(q) for rid, q in self.local.items()}
        if not sizes:
            return
        mean = sum(sizes.values()) / len(sizes)
        donors = [r for r, s in sizes.items() if s > 2 * mean + 4]
        takers = [r for r, s in sizes.items() if s < mean]
        for d in donors:
            while takers and len(self.local[d]) > mean:
                t = min(takers, key=lambda r: len(self.local[r]))
                self.local[t].append(self.local[d].pop())
                self.migrations += 1
                if len(self.local[t]) >= mean:
                    takers.remove(t)
