"""End-to-end experiment harness: one call = one (policy × trace ×
cluster) simulation, returning the paper's metrics.  Every benchmark in
benchmarks/ goes through here.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.cluster import ClusterConfig, ClusterController
from repro.core.interfaces import BatchResult, Request
from repro.core.states import ReplicaState
from repro.data import traces as traces_lib
from repro.runtime.baselines import (
    BaseDispatcher, DLoRADispatcher, PEFTDispatcher, RoundRobinDispatcher,
    ShepherdDispatcher,
)
from repro.runtime.metrics import MetricsCollector
from repro.runtime.replica import InterferenceSurface, LossCurve, SimReplica
from repro.runtime.simulator import Simulator

POLICIES = ("collm", "dlora", "shepherd", "peft", "rr")


@dataclasses.dataclass
class ExperimentConfig:
    policy: str = "collm"
    n_replicas: int = 16
    duration: float = 1200.0
    scale: float = 1.0
    slo: float = 0.5
    seed: int = 0
    model_id: str = "llama3-8b"
    control_tick: float = 0.05
    monitor_every: float = 5.0
    heterogeneous: bool = True
    enable_finetuning: bool = True       # CoLLM only
    drain: float = 5.0
    # fault injection: list of (replica_index, fail_t, recover_t)
    failures: Sequence = ()
    # straggler injection: {replica_index: slow_factor}
    stragglers: Dict[int, float] = dataclasses.field(default_factory=dict)


def build_replicas(cfg: ExperimentConfig, sim: Simulator,
                   on_result) -> Dict[str, SimReplica]:
    rng = np.random.default_rng(cfg.seed)
    replicas: Dict[str, SimReplica] = {}
    for i in range(cfg.n_replicas):
        het = rng.lognormal(0.0, 0.08) if cfg.heterogeneous else 1.0
        surface = InterferenceSurface(
            infer_alpha=0.020 * het, infer_beta=0.008 * het,
            infer_gamma=0.050 * het, train_alpha=0.030 * het,
            train_beta=0.010 * het, train_gamma=0.100 * het)
        curve = LossCurve(
            init_loss=float(rng.uniform(2.1, 2.7))
            if cfg.heterogeneous else 2.4,
            floor=float(rng.uniform(0.7, 1.0))
            if cfg.heterogeneous else 0.8,
            rate=1.0 / float(rng.uniform(4000, 9000))
            if cfg.heterogeneous else 1.0 / 6000.0)
        rid = f"r{i:02d}"
        replicas[rid] = SimReplica(
            rid, cfg.model_id, sim, on_result, surface, curve,
            seed=cfg.seed * 1000 + i,
            slow_factor=cfg.stragglers.get(i, 1.0))
    return replicas


def run_experiment(cfg: ExperimentConfig,
                   trace: Optional[List[Request]] = None) -> Dict:
    sim = Simulator()
    metrics = MetricsCollector(horizon=cfg.duration)
    if trace is None:
        trace = traces_lib.merged_trace(cfg.duration, scale=cfg.scale,
                                        stream_id=cfg.model_id,
                                        seed=cfg.seed)

    control_wall = [0.0]
    dispatch_delay = [0.0]

    if cfg.policy == "collm":
        cluster = ClusterController(ClusterConfig(
            slo=cfg.slo, enable_finetuning=cfg.enable_finetuning))

        def on_result(result: BatchResult, stream_id: str) -> None:
            metrics.on_result(result, stream_id)
            cluster.on_batch_result(result, stream_id)

        replicas = build_replicas(cfg, sim, on_result)
        for r in replicas.values():
            cluster.add_replica(r)

        def tick(now: float) -> None:
            t0 = _time.perf_counter()
            cluster.tick(now)
            control_wall[0] += _time.perf_counter() - t0

        sim.schedule_every(cfg.control_tick, tick, "control",
                           until=cfg.duration + cfg.drain)
        submit = cluster.submit_request
        state_of = cluster.states.state_of
    else:
        def on_result(result: BatchResult, stream_id: str) -> None:
            metrics.on_result(result, stream_id)
            if hasattr(dispatcher, "observe"):
                dispatcher.observe(result)

        replicas = build_replicas(cfg, sim, on_result)
        klass = {"dlora": DLoRADispatcher, "shepherd": ShepherdDispatcher,
                 "peft": PEFTDispatcher, "rr": RoundRobinDispatcher}[
                     cfg.policy]
        dispatcher = klass(replicas, slo=cfg.slo)

        def tick(now: float) -> None:
            t0 = _time.perf_counter()
            dispatcher.on_tick(now)
            control_wall[0] += _time.perf_counter() - t0

        sim.schedule_every(cfg.control_tick, tick, "control",
                           until=cfg.duration + cfg.drain)
        submit = dispatcher.submit
        state_of = lambda rid: ReplicaState.SERVING

    # --- faults ---------------------------------------------------------
    rids = list(replicas)
    for (idx, fail_t, recover_t) in cfg.failures:
        rid = rids[idx % len(rids)]
        sim.schedule(fail_t, lambda now, r=rid: replicas[r].fail(now),
                     "fail")
        if recover_t is not None:
            sim.schedule(recover_t,
                         lambda now, r=rid: replicas[r].recover(now),
                         "recover")

    # --- monitoring -------------------------------------------------------
    def sample(now: float) -> None:
        for rid, r in replicas.items():
            metrics.sample_utilization(rid, now, r.utilization(now))

    sim.schedule_every(cfg.monitor_every, sample, "monitor",
                       until=cfg.duration)

    traces_lib.replay(trace, sim, submit)
    sim.run(cfg.duration + cfg.drain)

    out = metrics.goodput(trace)
    out.update(metrics.utilization_summary())
    out["policy"] = cfg.policy
    out["scale"] = cfg.scale
    out["control_wall_s"] = control_wall[0]
    # overhead (Fig. 14): control-plane compute vs data-plane execution.
    # Control is real wall-clock of the Python control path; data-plane is
    # simulated busy seconds — conservative (control is biased up).
    infer_s = sum(r.total_infer_time for r in replicas.values())
    train_s = sum(r.total_train_time for r in replicas.values())
    out["infer_time_s"] = infer_s
    out["train_time_s"] = train_s
    out["overhead_frac"] = control_wall[0] / max(
        control_wall[0] + infer_s + train_s, 1e-9)
    out["train_frac"] = train_s / max(infer_s + train_s, 1e-9)
    out["events"] = sim.processed
    if cfg.policy == "collm":
        states = [state_of(rid).value for rid in replicas]
        out["final_states"] = {s: states.count(s) for s in set(states)}
        out["fl_rounds"] = cluster.launcher.completed_rounds
        out["mean_loss"] = float(np.mean(
            [r.loss_curve.loss() for r in replicas.values()]))
    out["_metrics"] = metrics
    out["_replicas"] = replicas
    return out
