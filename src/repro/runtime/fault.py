"""Fault tolerance: failure injection, detection, and straggler
mitigation on top of the simulator + control plane.

Design targets (1000+ nodes):
  * replica crash  -> detected via missed heartbeats; controller removes
    the replica; its in-dispatcher requests simply flow to surviving
    subflows (requests already on the dead replica are lost and counted,
    like a real serving system's connection resets).
  * replica rejoin -> re-registered; dispatcher grows a fresh subflow;
    FL sessions pick it up at the next launch decision.
  * stragglers     -> CoLLM-native mitigation: the dispatcher's per-
    replica latency models observe the slowdown and shrink b_max
    (macro-cycle), the priority allocation (Eq. 18-19) shifts batch
    budget to healthy replicas, and the §4.3 early-stopper sheds slow
    FL members.  ``StragglerWatch`` additionally flags gross outliers
    for operator visibility.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.cluster import ClusterController


@dataclasses.dataclass
class Heartbeat:
    last_seen: float = 0.0
    misses: int = 0


class FailureDetector:
    """Heartbeat-based crash detection (the controller's view)."""

    def __init__(self, cluster: ClusterController, timeout: float = 3.0,
                 max_misses: int = 3):
        self.cluster = cluster
        self.timeout = timeout
        self.max_misses = max_misses
        self.beats: Dict[str, Heartbeat] = {}
        self.removed: List[str] = []

    def heartbeat(self, replica_id: str, now: float) -> None:
        hb = self.beats.setdefault(replica_id, Heartbeat())
        hb.last_seen = now
        hb.misses = 0

    def poll(self, now: float) -> List[str]:
        """Returns replicas declared dead this poll (and removes them)."""
        dead = []
        for rid in list(self.cluster.replicas):
            hb = self.beats.setdefault(rid, Heartbeat(last_seen=now))
            handle = self.cluster.replicas[rid]
            alive = not getattr(handle, "failed", False)
            if alive:
                hb.last_seen = now
                hb.misses = 0
                continue
            if now - hb.last_seen > self.timeout:
                hb.misses += 1
            if hb.misses >= self.max_misses:
                dead.append(rid)
        for rid in dead:
            self.cluster.remove_replica(rid, now)
            self.removed.append(rid)
        return dead


class StragglerWatch:
    """Flags replicas whose recent batch latencies are gross outliers
    (median × threshold) — mitigation itself is CoLLM-native (see module
    docstring); this provides detection + an optional quarantine hook."""

    def __init__(self, threshold: float = 2.5, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.samples: Dict[str, List[float]] = {}

    def observe(self, replica_id: str, normalized_latency: float) -> None:
        buf = self.samples.setdefault(replica_id, [])
        buf.append(normalized_latency)
        if len(buf) > self.window:
            del buf[0]

    def stragglers(self) -> List[str]:
        med = {rid: float(np.median(v))
               for rid, v in self.samples.items() if len(v) >= 8}
        if len(med) < 3:
            return []
        cluster_med = float(np.median(list(med.values())))
        return [rid for rid, m in med.items()
                if m > self.threshold * cluster_med]
