"""Fault tolerance for the LIVE serving fabric: deterministic fault
injection, pump-driven health monitoring, straggler quarantine, and
request-lifecycle retry policy.

Detection source — real pump progress, not simulator attributes.  Every
successful ``LiveReplica.pump_once`` registers a heartbeat with the
``HealthMonitor`` (serving ticks also feed their wall latency to the
``StragglerWatch``); an exception escaping a pump is contained by
``ServingFabric.tick`` and reported as an immediate failure.  A replica
is declared DEAD when its pump raises, or when it misses
``max_misses`` beat windows of ``beat_timeout`` seconds — the fabric
then runs the full ``fail_replica`` path (drain + requeue + multi-tenant
adapter re-registration), so no undispatched request is ever lost.
Gross stragglers are QUARANTINED instead of killed: their pending work
is drained and requeued through the same ``drain_pending`` path, their
dispatcher subflows are suspended for a cooldown, and their latency
samples reset so a recovered replica rejoins with a clean slate.

Retry / deadline contract (``RetryPolicy``) — every re-admission after
a failover or quarantine drain consumes one unit of the request's retry
budget and pushes its ``not_before`` gate out exponentially; the SLO
clock (arrival/deadline) is NEVER extended — a retried request races
its ORIGINAL deadline.  A request whose accepting replica dies
``max_failures`` times is a poison request: it is rejected with a
terminal ``status="failed"`` instead of being requeued forever.

Publish-gate semantics — training faults must never corrupt serving.
``LiveReplica.finish_round``/``publish_adapter`` reject a non-finite
shadow tree (NaN/Inf gradients poisoned the round): the round is
aborted, the served adapter stays bit-identical at its last published
version, and the rejection is counted in
``ServeStats.nan_publishes_blocked``.  ``AdapterRegistry.update``
enforces the same invariant at the registry seam.

``FaultInjector`` drives all of the above deterministically for tests
and ``benchmarks/chaos.py``: a seeded schedule of crash / stall / oom /
nan_grads events against named replicas, hooked into
``LiveReplica.pump_once`` (crash raises, stall sleeps), ``_ingest``
(oom raises at admission) and the fused train step (nan_grads poisons
the shadow tree).
"""
from __future__ import annotations

import collections
import dataclasses
import time as _time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterController
from repro.core.interfaces import Request


# =========================================================================
# Fault injection (deterministic, seeded)
# =========================================================================
class InjectedFault(RuntimeError):
    """A FaultInjector-scheduled crash surfacing inside a pump."""


class InjectedOOM(MemoryError):
    """A FaultInjector-scheduled allocator OOM at admission."""


@dataclasses.dataclass
class FaultEvent:
    """One scheduled fault against one replica.

    kind:
      crash      pump_once raises ``InjectedFault`` from ``at`` onward
                 (sticky: a crashed replica never pumps again)
      stall      every pump in ``[at, at + duration]`` sleeps
                 ``stall_s`` extra wall seconds (straggler injection)
      oom        admission in ``[at, at + duration]`` raises
                 ``InjectedOOM``
      nan_grads  ONE train tick at/after ``at`` poisons the session's
                 shadow tree with NaN (one-shot per event)
    """
    at: float
    replica_id: str
    kind: str
    duration: float = 0.0
    stall_s: float = 0.05


class FaultInjector:
    """Deterministic fault schedule for live replicas.

    The injector is pure bookkeeping — replicas call its hooks at the
    relevant points of their tick and the injector raises/sleeps/flags
    per the schedule.  ``injected`` logs every fired event
    ``(now, replica_id, kind)`` for telemetry and test asserts."""

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self.events: List[FaultEvent] = sorted(events, key=lambda e: e.at)
        self.crashed: set = set()
        self._consumed: set = set()     # one-shot events already fired
        self.injected: List[Tuple[float, str, str]] = []

    def _active(self, replica_id: str, now: float, kind: str
                ) -> Optional[FaultEvent]:
        for e in self.events:
            if e.replica_id != replica_id or e.kind != kind:
                continue
            if e.at > now:
                break                   # events are time-sorted
            if kind == "crash" or now <= e.at + max(e.duration, 0.0):
                return e
        return None

    # ---------------------------------------------------------- hooks ----
    def before_pump(self, replica_id: str, now: float) -> None:
        """Top of ``LiveReplica.pump_once``: crash raises (sticky),
        stall sleeps the scheduled straggler delay."""
        if replica_id in self.crashed \
                or self._active(replica_id, now, "crash") is not None:
            self.crashed.add(replica_id)
            self.injected.append((now, replica_id, "crash"))
            raise InjectedFault(f"{replica_id}: injected crash")
        stall = self._active(replica_id, now, "stall")
        if stall is not None:
            self.injected.append((now, replica_id, "stall"))
            _time.sleep(stall.stall_s)

    def at_admission(self, replica_id: str, now: float) -> None:
        """``LiveReplica._ingest``: scheduled allocator OOM."""
        if self._active(replica_id, now, "oom") is not None:
            self.injected.append((now, replica_id, "oom"))
            raise InjectedOOM(f"{replica_id}: injected allocator OOM")

    def poison_grads(self, replica_id: str, now: float) -> bool:
        """After a fused train tick: True exactly once per scheduled
        ``nan_grads`` event — the caller NaN-fills its shadow tree."""
        for i, e in enumerate(self.events):
            if e.replica_id == replica_id and e.kind == "nan_grads" \
                    and e.at <= now and i not in self._consumed:
                self._consumed.add(i)
                self.injected.append((now, replica_id, "nan_grads"))
                return True
        return False

    # ------------------------------------------------------- schedules ----
    @staticmethod
    def random_plan(replica_ids: Sequence[str], *, seed: int = 0,
                    horizon: float = 5.0, n_crashes: int = 1,
                    n_stalls: int = 1, n_ooms: int = 0,
                    n_nan_rounds: int = 0, stall_duration: float = 1.0,
                    stall_s: float = 0.05) -> List[FaultEvent]:
        """A seeded chaos schedule over ``replica_ids``: crashes and
        stalls land on DISTINCT replicas (so a 2-replica pool always
        keeps one survivor per event class), at deterministic times
        drawn inside the horizon."""
        rng = np.random.default_rng(seed)
        ids = list(replica_ids)
        victims = rng.permutation(len(ids))
        events: List[FaultEvent] = []
        k = 0
        for _ in range(n_crashes):
            events.append(FaultEvent(
                at=float(rng.uniform(0.2, 0.6) * horizon),
                replica_id=ids[victims[k % len(ids)]], kind="crash"))
            k += 1
        for _ in range(n_stalls):
            events.append(FaultEvent(
                at=float(rng.uniform(0.05, 0.3) * horizon),
                replica_id=ids[victims[k % len(ids)]], kind="stall",
                duration=stall_duration, stall_s=stall_s))
            k += 1
        for _ in range(n_ooms):
            events.append(FaultEvent(
                at=float(rng.uniform(0.1, 0.5) * horizon),
                replica_id=ids[victims[k % len(ids)]], kind="oom",
                duration=0.2))
            k += 1
        for _ in range(n_nan_rounds):
            events.append(FaultEvent(
                at=float(rng.uniform(0.0, 0.2) * horizon),
                replica_id=ids[victims[k % len(ids)]],
                kind="nan_grads"))
            k += 1
        return sorted(events, key=lambda e: e.at)


# =========================================================================
# Heartbeat crash detection
# =========================================================================
@dataclasses.dataclass
class Heartbeat:
    last_seen: float = 0.0
    misses: int = 0


class FailureDetector:
    """Heartbeat-based crash detection over a ClusterController.

    Detection keys off ACTUAL ``heartbeat()`` calls: a replica that
    stops beating accrues one miss per ``poll`` whose gap since the last
    beat exceeds ``timeout``, and is removed from the cluster after
    ``max_misses`` — there is no liveness back-channel (the old
    ``failed``-attribute peek made ``heartbeat()`` dead code and the
    timeout logic unreachable for real silent failures)."""

    def __init__(self, cluster: ClusterController, timeout: float = 3.0,
                 max_misses: int = 3):
        self.cluster = cluster
        self.timeout = timeout
        self.max_misses = max_misses
        self.beats: Dict[str, Heartbeat] = {}
        self.removed: List[str] = []

    def heartbeat(self, replica_id: str, now: float) -> None:
        hb = self.beats.setdefault(replica_id, Heartbeat())
        hb.last_seen = now
        hb.misses = 0

    def poll(self, now: float) -> List[str]:
        """Returns replicas declared dead this poll (and removes them).
        A replica first seen at poll time gets a grace window from
        ``now`` — registration is not a missed beat."""
        dead = []
        for rid in list(self.cluster.replicas):
            hb = self.beats.setdefault(rid, Heartbeat(last_seen=now))
            if now - hb.last_seen > self.timeout:
                hb.misses += 1
                # one miss per elapsed timeout window, not per poll
                # frequency: restart the window from this poll
                hb.last_seen = now
            if hb.misses >= self.max_misses:
                dead.append(rid)
        for rid in dead:
            self.cluster.remove_replica(rid, now)
            self.beats.pop(rid, None)
            self.removed.append(rid)
        return dead


# =========================================================================
# Straggler detection
# =========================================================================
class StragglerWatch:
    """Flags replicas whose recent batch latencies are gross outliers
    against their PEERS' medians.  Detection only — quarantine/requeue
    is the fabric's move (see module docstring)."""

    def __init__(self, threshold: float = 2.5, window: int = 32,
                 min_samples: int = 8, warmup: int = 0):
        self.threshold = threshold
        self.window = window
        self.min_samples = min_samples
        self.warmup = warmup
        self.samples: Dict[str, Deque[float]] = {}
        self._seen: Dict[str, int] = {}

    def observe(self, replica_id: str, normalized_latency: float) -> None:
        # drop each replica's first ``warmup`` observations: whichever
        # replica serves a shape first pays its jit compile (seconds),
        # which would make the HEALTHY pool member look like the gross
        # outlier and quarantine the wrong replica
        seen = self._seen.get(replica_id, 0) + 1
        self._seen[replica_id] = seen
        if seen <= self.warmup:
            return
        buf = self.samples.get(replica_id)
        if buf is None:
            buf = self.samples[replica_id] = collections.deque(
                maxlen=self.window)
        buf.append(normalized_latency)

    def reset(self, replica_id: str) -> None:
        """Forget a replica's history (post-quarantine clean slate —
        stale straggler samples must not instantly re-flag it).  The
        warmup counter survives: a rehabilitated replica already paid
        its compile, so fresh evidence counts immediately."""
        self.samples.pop(replica_id, None)

    def stragglers(self) -> List[str]:
        """Replicas whose median latency exceeds ``threshold`` x the
        median of their PEERS' medians.  Peer-relative (not cluster-
        median) so the comparison works at 2 replicas and a straggler
        cannot drag the baseline toward itself; the ``peers_med > 0``
        guard keeps an all-identical / all-zero cluster from flagging
        anything (threshold x 0 is vacuous)."""
        med = {rid: float(np.median(v))
               for rid, v in self.samples.items()
               if len(v) >= self.min_samples}
        if len(med) < 2:
            return []
        out = []
        for rid, m in med.items():
            peers = [v for r, v in med.items() if r != rid]
            peers_med = float(np.median(peers))
            if peers_med > 0 and m > self.threshold * peers_med:
                out.append(rid)
        return out


# =========================================================================
# Health monitoring (the fabric's pump-driven view)
# =========================================================================
@dataclasses.dataclass
class HealthConfig:
    beat_timeout: float = 1.0       # seconds without a pump = one miss
    max_misses: int = 3             # misses before declared dead
    poll_interval: float = 0.25     # verdict cadence
    straggler_threshold: float = 3.0
    straggler_window: int = 32
    straggler_min_samples: int = 8
    straggler_warmup: int = 4       # per-replica jit-compile grace
    quarantine_cooldown: float = 1.0


class HealthMonitor:
    """Pump-progress health: ``beat`` on every successful
    ``pump_once`` (serving ticks feed latency to the StragglerWatch),
    ``failure`` on a contained pump exception, ``poll`` for verdicts.

    ``poll`` returns ``(dead, stragglers)``: replicas to fail over
    (pump raised, or ``max_misses`` beat windows elapsed silently) and
    replicas to quarantine.  The monitor tracks quarantine windows so a
    replica is neither double-quarantined nor re-flagged from stale
    samples during its cooldown."""

    def __init__(self, cfg: Optional[HealthConfig] = None):
        self.cfg = cfg or HealthConfig()
        self.beats: Dict[str, Heartbeat] = {}
        self.watch = StragglerWatch(
            threshold=self.cfg.straggler_threshold,
            window=self.cfg.straggler_window,
            min_samples=self.cfg.straggler_min_samples,
            warmup=self.cfg.straggler_warmup)
        self.quarantined: Dict[str, float] = {}     # rid -> until
        self.failures: List[Tuple[float, str, str]] = []
        self._pending_dead: Dict[str, str] = {}     # rid -> reason
        self._next_poll = 0.0

    # ---------------------------------------------------------- inputs ----
    def beat(self, replica_id: str, now: float,
             busy_s: Optional[float] = None) -> None:
        """One successful pump.  ``busy_s`` is the tick's wall latency
        when the pump did SERVING work — idle ticks are ~free and would
        poison the straggler medians toward zero."""
        hb = self.beats.setdefault(replica_id, Heartbeat(last_seen=now))
        hb.last_seen = now
        hb.misses = 0
        if busy_s is not None:
            self.watch.observe(replica_id, busy_s)

    def failure(self, replica_id: str, now: float, reason: str) -> None:
        """A pump raised: the replica is dead NOW — no beat-timeout
        dance."""
        self._pending_dead[replica_id] = reason
        self.failures.append((now, replica_id, reason))

    def forget(self, replica_id: str) -> None:
        """A replica left the pool (failover/scale-down): drop all its
        health state."""
        self.beats.pop(replica_id, None)
        self.quarantined.pop(replica_id, None)
        self._pending_dead.pop(replica_id, None)
        self.watch.reset(replica_id)

    # --------------------------------------------------------- verdicts ---
    def quarantine(self, replica_id: str, now: float) -> float:
        """Mark a straggler quarantined until ``now + cooldown``; its
        samples reset so it rejoins on fresh evidence.  Returns the
        release time."""
        until = now + self.cfg.quarantine_cooldown
        self.quarantined[replica_id] = until
        self.watch.reset(replica_id)
        return until

    def in_quarantine(self, replica_id: str, now: float) -> bool:
        return self.quarantined.get(replica_id, 0.0) > now

    def poll(self, now: float) -> Tuple[List[str], List[str]]:
        """(dead, stragglers) this poll.  Rate-limited by
        ``poll_interval`` except that pump failures always surface
        immediately (waiting a poll window on a dead replica only
        strands its requests)."""
        dead = list(self._pending_dead)
        self._pending_dead.clear()
        if now < self._next_poll:
            return dead, []
        self._next_poll = now + self.cfg.poll_interval
        for rid, hb in self.beats.items():
            if rid in dead:
                continue
            if now - hb.last_seen > self.cfg.beat_timeout:
                hb.misses += 1
                hb.last_seen = now
                if hb.misses >= self.cfg.max_misses:
                    dead.append(rid)
                    self.failures.append((now, rid, "missed_beats"))
        stragglers = [rid for rid in self.watch.stragglers()
                      if rid not in dead
                      and not self.in_quarantine(rid, now)]
        return dead, stragglers


# =========================================================================
# Request-lifecycle retry policy
# =========================================================================
@dataclasses.dataclass
class RetryPolicy:
    """Per-request retry budget + exponential backoff for re-admission
    after a failover or quarantine drain.

    The SLO clock is untouched: a retried request keeps its ORIGINAL
    arrival/deadline and only gains a ``not_before`` gate the
    dispatcher honors.  ``max_failures`` is the poison-request bound: a
    request whose accepting replica DIES that many times is terminally
    rejected instead of requeued forever (quarantine drains count
    toward retries but not failures — the replica survived)."""
    max_retries: int = 4
    max_failures: int = 3
    backoff_base: float = 0.05
    backoff_factor: float = 2.0

    def __post_init__(self):
        self.retried = 0
        self.rejected: List[Request] = []
        # terminal-stays-terminal FSM shadow, armed by REPRO_SANITIZE=1
        from repro.runtime.sanitize import request_sanitizer
        self._san = request_sanitizer()

    def on_requeue(self, req: Request, now: float, *,
                   replica_died: bool) -> bool:
        """Charge one re-admission.  Returns True if the request may be
        requeued; False marks it terminally failed (the caller must NOT
        requeue it)."""
        if self._san is not None:
            self._san.check_requeue(req)
        if replica_died:
            req.failures += 1
        req.retries += 1
        if req.failures >= self.max_failures:
            req.status = "failed"
            req.failed_reason = "poison"
        elif req.retries > self.max_retries:
            req.status = "failed"
            req.failed_reason = "retries_exhausted"
        if req.status == "failed":
            self.rejected.append(req)
            return False
        req.not_before = now + self.backoff_base \
            * self.backoff_factor ** (req.retries - 1)
        self.retried += 1
        return True

    def filter_requeue(self, requests: Sequence[Request], now: float, *,
                       replica_died: bool) -> List[Request]:
        """Apply the budget to a drained batch; returns the survivors
        (order preserved) with backoff gates stamped."""
        return [r for r in requests
                if self.on_requeue(r, now, replica_died=replica_died)]
