"""Elastic scaling: checkpoint-based re-meshing for the training path
and replica join/leave for the serving path.

``elastic_restore`` is the 1000-node story: a training job checkpointed
under mesh A (say 2×16×16) restarts under mesh B (16×16, a pod lost) —
the checkpoint stores full logical arrays, restore device_puts them
under the new mesh's shardings.  Nothing in the step functions changes;
pjit re-lowers for the new mesh.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer


def shardings_for(tree: Any, mesh: Mesh,
                  spec_fn: Callable[[str, Any], P]) -> Any:
    """Build a NamedSharding tree from a per-leaf spec function
    (key, leaf) -> PartitionSpec."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append(NamedSharding(mesh, spec_fn(key, leaf)))
    return jax.tree_util.tree_unflatten(treedef, out)


def elastic_restore(ckpt: Checkpointer, template: Any, new_mesh: Mesh,
                    spec_fn: Callable[[str, Any], P],
                    step: Optional[int] = None) -> Tuple[Any, Dict]:
    """Restore a checkpoint onto a *different* mesh (elastic restart)."""
    shardings = shardings_for(template, new_mesh, spec_fn)
    return ckpt.restore(template, step=step, shardings=shardings)


class ElasticServingPool:
    """Serving-side elasticity: replicas join/leave at runtime; the
    dispatcher's subflow set and the launcher's cohort logic adapt on
    the next control tick (no global reconfiguration)."""

    def __init__(self, cluster):
        self.cluster = cluster
        self.joined = 0
        self.left = 0

    def join(self, handle, now: float) -> None:
        # dispatcher replica sets are live views over the cluster
        # registry, so existing stream dispatchers pick the newcomer up
        # on their next tick — nothing to patch
        self.cluster.add_replica(handle)
        self.joined += 1

    def leave(self, replica_id: str, now: float) -> None:
        self.cluster.remove_replica(replica_id, now)
        self.left += 1
