"""Opt-in shadow-state sanitizers for the serving runtime (reprosan).

``REPRO_SANITIZE=1`` arms thin instrumentation points inside the
hand-maintained correctness regimes of the runtime — the invariants
that are otherwise enforced only by convention and review:

  BlockSanitizer     mirrors ``runtime.paging.BlockAllocator``: an
                     independent shadow refcount/reservation ledger is
                     advanced on every allocator mutation and
                     cross-checked against the allocator, plus
                     decode-wave checks over the batcher's block
                     tables — use-after-free gather, use-after-swap
                     gather of a chain whose contents were swapped to
                     host (preemption), write into a shared
                     (refcount > 1) block without copy-on-write, an
                     active slot writing scratch block 0, and
                     reservation leaks at eviction/drain.
  AdapterSanitizer   mirrors the ``AdapterRegistry`` residency state:
                     decode-wave reads of a refcount-0 / non-resident /
                     mid-publish tenant slot, LRU eviction of a tenant
                     with live refs, release-without-acquire, and
                     version regression at publish.
  RequestLifecycle   a per-batcher FSM over ``GenRequest`` objects
                     (queued -> active -> finished, drain -> requeue):
                     flags double submission, decode of an evicted or
                     never-admitted slot, and replay of a terminal
                     (finished) request.
  RequestFSM         the control-plane twin: a TERMINAL ``Request``
                     (served, or status == "failed") handed back to
                     ``RetryPolicy.on_requeue`` is a lifecycle bug —
                     "backoff never extends the SLO clock" only holds
                     if terminal requests stay terminal.

Every check raises ``SanitizeError`` with a precise diagnostic (and
records it in ``reports()`` for telemetry).  When the env var is unset
the factory helpers return ``None`` and the instrumented call sites
reduce to one ``is not None`` test — no hot-path cost when off.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


def enabled() -> bool:
    """True iff shadow-state sanitizers are armed for this process."""
    return os.environ.get("REPRO_SANITIZE", "") == "1"


class SanitizeError(AssertionError):
    """A hand-maintained runtime invariant was violated (reprosan)."""


_REPORTS: List[str] = []


def reports() -> List[str]:
    """Every diagnostic raised so far in this process (telemetry)."""
    return list(_REPORTS)


def _fail(check: str, msg: str) -> None:
    diag = f"[reprosan:{check}] {msg}"
    _REPORTS.append(diag)
    raise SanitizeError(diag)


# =========================================================================
# Block pool shadow state
# =========================================================================
class BlockSanitizer:
    """Shadow ledger mirroring one ``BlockAllocator`` plus decode-wave
    checks over the owning batcher's slot/block tables.

    The mirror is advanced by the allocator's own mutation hooks
    (``on_take``/``on_free``/...) so a divergence between mirror and
    allocator pinpoints a refcount-accounting bug inside the allocator;
    the wave checks consume the batcher's view (``slot_blocks``,
    ``slot_pos``, ``slot_reserved``) so a divergence there pinpoints
    allocator *misuse* by the runtime (skipped COW, stale table)."""

    def __init__(self, alloc: Any):
        self.alloc = alloc
        self.ref = np.zeros(alloc.n_blocks, np.int64)
        self.reserved = 0
        # blocks whose CONTENTS left the device (preemption swap-out):
        # free-list members whose bytes live host-side until a swap_in
        # re-takes fresh blocks — gathering one before then is a
        # use-after-swap, distinct from plain use-after-free
        self.swapped: set = set()
        self.pinned: set = set()

    # ------------------------------------------------- allocator hooks --
    def on_reserve(self, n: int) -> None:
        self.reserved += n

    def on_release(self, n: int) -> None:
        if n > self.reserved:
            _fail("reservation-underflow",
                  f"release({n}) exceeds shadow reservation "
                  f"{self.reserved}")
        self.reserved -= n

    def on_take(self, ids: List[int]) -> None:
        for b in ids:
            if self.ref[b] != 0:
                _fail("double-hand-out",
                      f"take handed out block {b} with shadow refcount "
                      f"{int(self.ref[b])} (still referenced)")
            self.ref[b] = 1
            # a re-taken block is a fresh allocation: its new owner
            # overwrites the contents, so the swapped/pinned marks from
            # its previous life are cleared (reclaim discards the
            # allocator pin without an unpin hook)
            self.swapped.discard(b)
            self.pinned.discard(b)
        self.reserved -= len(ids)
        if self.reserved < 0:
            _fail("reservation-underflow",
                  f"take({len(ids)}) drove the shadow reservation "
                  f"negative ({self.reserved})")

    def on_acquire(self, ids: List[int]) -> None:
        for b in ids:
            self.ref[b] += 1

    def on_share(self, ids: List[int]) -> None:
        for b in ids:
            if self.ref[b] < 1:
                _fail("share-of-free",
                      f"share aliased block {b} with shadow refcount 0")
            self.ref[b] += 1

    def on_free(self, ids: List[int]) -> None:
        for b in ids:
            if self.ref[b] < 1:
                _fail("double-free",
                      f"free of block {b} with shadow refcount 0")
            self.ref[b] -= 1

    def on_swap_out(self, ids: List[int]) -> None:
        for b in ids:
            if self.ref[b] != 1:
                _fail("swap-out-shared",
                      f"swap-out of block {b} with shadow refcount "
                      f"{int(self.ref[b])} — only a sole-referenced "
                      "private block may leave the device")
            if b in self.pinned:
                _fail("swap-out-pinned",
                      f"swap-out of pinned (prefix-cached) block {b} — "
                      "registered blocks stay pool-resident")
            self.ref[b] = 0
            self.swapped.add(b)

    def on_swap_in(self, ids: List[int]) -> None:
        # fresh blocks scattered from host copies are live again
        # (``on_take`` already cleared any stale swapped marks)
        self.swapped.difference_update(ids)

    def on_pin(self, bid: int) -> None:
        if self.ref[bid] < 1:
            _fail("pin-of-free",
                  f"pin of block {bid} with shadow refcount "
                  f"{int(self.ref[bid])} — only live blocks may be "
                  "registered")
        self.pinned.add(bid)

    def on_unpin(self, bid: int) -> None:
        self.pinned.discard(bid)

    # ---------------------------------------------------- wave checks --
    def _check_mirror(self) -> None:
        """Mirror-vs-allocator cross-check: any drift means the
        allocator's own ledger went wrong (not just its callers)."""
        if self.reserved != self.alloc.reserved:
            _fail("reservation-drift",
                  f"shadow reservation {self.reserved} != allocator "
                  f"reservation {self.alloc.reserved}")
        theirs = np.asarray(self.alloc._ref, np.int64)
        if not np.array_equal(self.ref, theirs):
            bad = np.nonzero(self.ref != theirs)[0][:8]
            _fail("refcount-drift",
                  "shadow refcounts diverged from allocator at blocks "
                  f"{bad.tolist()} (shadow "
                  f"{self.ref[bad].tolist()} vs allocator "
                  f"{theirs[bad].tolist()})")
        if self.pinned != set(self.alloc._pinned):
            _fail("pin-drift",
                  "shadow pin set diverged from allocator: shadow-only "
                  f"{sorted(self.pinned - set(self.alloc._pinned))[:8]} "
                  "allocator-only "
                  f"{sorted(set(self.alloc._pinned) - self.pinned)[:8]}")

    def check_decode_wave(self, batcher: Any, active: List[int]) -> None:
        """Pre-decode: every gathered block must be live, every write
        target must be private (COW done) and non-scratch, and the
        reservation ledger must balance across slots."""
        self._check_mirror()
        alloc = self.alloc
        for i in active:
            blocks = batcher.slot_blocks[i]
            for b in blocks:
                # swapped-out first: the block IS refcount-0, but the
                # precise diagnosis is that its contents left the
                # device — restore must swap_in before decoding
                if b in self.swapped:
                    _fail("use-after-swap",
                          f"slot {i} decode wave gathers block {b} "
                          "whose contents were swapped out to host — "
                          "the chain must swap_in (fresh blocks + "
                          "scatter) before it decodes")
                if alloc.ref(b) < 1:
                    _fail("use-after-free-gather",
                          f"slot {i} decode wave gathers block {b} with "
                          f"refcount {alloc.ref(b)} (freed or retained "
                          "content)")
            wr = int(batcher.slot_pos[i]) % batcher.ring_len
            bidx = wr // batcher.block_size
            if bidx >= len(blocks):
                _fail("table-underflow",
                      f"slot {i} writes position {wr} (block index "
                      f"{bidx}) beyond its {len(blocks)}-block table")
            wb = blocks[bidx]
            if wb < alloc.n_scratch:
                _fail("scratch-write",
                      f"slot {i} (active) would write scratch block "
                      f"{wb} — its KV would be silently shared with "
                      "every dead lane")
            if alloc.ref(wb) > 1:
                _fail("shared-write",
                      f"slot {i} writes block {wb} with refcount "
                      f"{alloc.ref(wb)} (> 1) — copy-on-write was "
                      "skipped; sharers would observe torn KV")
        total = int(np.sum(batcher.slot_reserved))
        if alloc.reserved != total:
            _fail("reservation-leak",
                  f"allocator holds {alloc.reserved} reserved blocks "
                  f"but slots account for {total}")

    def check_evicted(self, batcher: Any, slot: int) -> None:
        """Post-eviction: the slot must hold no blocks, no reservation,
        and its table row must be parked on scratch."""
        if batcher.slot_blocks[slot]:
            _fail("eviction-block-leak",
                  f"slot {slot} evicted but still maps blocks "
                  f"{batcher.slot_blocks[slot]}")
        if int(batcher.slot_reserved[slot]) != 0:
            _fail("reservation-leak",
                  f"slot {slot} evicted with "
                  f"{int(batcher.slot_reserved[slot])} reserved blocks "
                  "never released")
        if int(np.max(batcher.block_tables[slot])) != 0:
            _fail("eviction-table-leak",
                  f"slot {slot} evicted but its table row still points "
                  "at pool blocks")

    def check_quiescent(self, batcher: Any) -> None:
        """Post-drain: nothing may stay referenced or reserved (retained
        prefix-cache blocks are refcount-0 by definition)."""
        self._check_mirror()
        if self.alloc.reserved != 0:
            _fail("reservation-leak",
                  f"drained batcher leaks {self.alloc.reserved} "
                  "reserved blocks")
        if self.alloc.n_used != 0:
            _fail("drain-block-leak",
                  f"drained batcher leaks {self.alloc.n_used} "
                  "referenced pool blocks")


# =========================================================================
# Adapter registry shadow state
# =========================================================================
class AdapterSanitizer:
    """Shadow residency/refcount/version ledger for one
    ``AdapterRegistry`` plus decode-wave read checks."""

    def __init__(self) -> None:
        self.refs: Dict[str, int] = {}
        self.versions: Dict[str, int] = {}
        self.resident: set = set()
        self.publishing: set = set()

    # ------------------------------------------------- registry hooks --
    def on_register(self, aid: str, version: int) -> None:
        self.versions[aid] = version

    def on_unregister(self, aid: str) -> None:
        if self.refs.get(aid, 0) > 0:
            _fail("unregister-live",
                  f"adapter {aid!r} unregistered with "
                  f"{self.refs[aid]} live refs")
        self.refs.pop(aid, None)
        self.versions.pop(aid, None)
        self.resident.discard(aid)

    def on_acquire(self, aid: str) -> None:
        self.refs[aid] = self.refs.get(aid, 0) + 1
        self.resident.add(aid)

    def on_release(self, aid: str) -> None:
        if self.refs.get(aid, 0) <= 0:
            _fail("release-without-acquire",
                  f"adapter {aid!r} released with shadow refcount 0")
        self.refs[aid] -= 1

    def on_evict(self, aid: str) -> None:
        """LRU eviction of a cold tenant: refs must be exactly 0 —
        evicting a pinned tenant would rip the weights out from under
        its in-flight rows."""
        if self.refs.get(aid, 0) != 0:
            _fail("evict-live-refs",
                  f"adapter {aid!r} evicted with {self.refs[aid]} "
                  "live refs (in-flight rows still index its slot)")
        self.resident.discard(aid)

    def begin_publish(self, aid: str, version: Optional[int]) -> None:
        if version is not None and version < self.versions.get(aid, 0):
            _fail("version-regression",
                  f"adapter {aid!r} publish at version {version} after "
                  f"version {self.versions[aid]} was already served")
        self.publishing.add(aid)

    def end_publish(self, aid: str, version: Optional[int]) -> None:
        self.publishing.discard(aid)
        if version is not None:
            self.versions[aid] = version

    # ---------------------------------------------------- wave checks --
    def check_decode_wave(self, batcher: Any, active: List[int]) -> None:
        reg = batcher.adapters
        for i in active:
            aid = batcher.slot_aid[i]
            if aid is None:
                continue
            if reg.refcount(aid) < 1:
                _fail("refcount0-read",
                      f"slot {i} decodes through adapter {aid!r} with "
                      "registry refcount 0 — its slot can be evicted "
                      "mid-wave")
            if reg.slot_index(aid) < 0:
                _fail("non-resident-read",
                      f"slot {i} decodes through adapter {aid!r} which "
                      "is not device-resident")
            if aid in self.publishing:
                _fail("mid-publish-read",
                      f"slot {i} decodes through adapter {aid!r} while "
                      "its slot publish is in flight (torn weights)")


# =========================================================================
# Request lifecycle FSMs
# =========================================================================
_QUEUED, _ACTIVE, _FINISHED, _DRAINED = ("queued", "active", "finished",
                                         "drained")


class RequestLifecycle:
    """Per-batcher FSM over ``GenRequest`` objects.

    Legal transitions::

        (new) ───────────── submit ──> queued
        drained ─────────── submit ──> queued      (failover resubmit)
        queued ──────────── admit ───> active
        queued/active ───── finish ──> finished    (finish-at-admission)
        queued/active ───── drain ───> drained
        finished ────────── *  ──────> ERROR       (terminal replay)

    Keyed by object identity with a strong reference held (sanitizers
    trade memory for certainty), so a recycled ``id()`` can never
    alias two requests."""

    def __init__(self) -> None:
        self._state: Dict[int, Tuple[Any, str]] = {}

    def _get(self, req: Any) -> Optional[str]:
        entry = self._state.get(id(req))
        return entry[1] if entry is not None else None

    def _set(self, req: Any, state: str) -> None:
        self._state[id(req)] = (req, state)

    def on_submit(self, req: Any) -> None:
        prev = self._get(req)
        if prev == _FINISHED:
            _fail("terminal-replay",
                  f"request {req.request_id} resubmitted after it "
                  "finished — a terminal request must never re-enter "
                  "the queue")
        if prev in (_QUEUED, _ACTIVE):
            _fail("double-submit",
                  f"request {req.request_id} submitted while already "
                  f"{prev}")
        self._set(req, _QUEUED)

    def on_admit(self, req: Any) -> None:
        prev = self._get(req)
        if prev != _QUEUED:
            _fail("illegal-admit",
                  f"request {req.request_id} admitted from state "
                  f"{prev!r} (expected queued)")
        self._set(req, _ACTIVE)

    def on_finish(self, req: Any) -> None:
        prev = self._get(req)
        if prev not in (_QUEUED, _ACTIVE, None):
            _fail("illegal-finish",
                  f"request {req.request_id} finished from state "
                  f"{prev!r}")
        self._set(req, _FINISHED)

    def on_drain(self, req: Any) -> None:
        prev = self._get(req)
        if prev == _FINISHED:
            _fail("terminal-drain",
                  f"request {req.request_id} drained for requeue after "
                  "finishing — its results would be regenerated and "
                  "double-counted")
        self._set(req, _DRAINED)

    def check_decode_wave(self, batcher: Any, active: List[int]) -> None:
        """Every slot the decode wave advances must hold an ACTIVE
        request — an evicted/drained slot decoding means the runtime is
        generating tokens into freed state."""
        for i in active:
            req = batcher.slot_req[i]
            state = self._get(req)
            if state != _ACTIVE:
                _fail("evicted-decoding",
                      f"slot {i} decodes request "
                      f"{getattr(req, 'request_id', '?')} in state "
                      f"{state!r} (expected active)")


class RequestFSM:
    """Control-plane twin: terminal ``Request`` objects must stay
    terminal (never retried / requeued)."""

    def check_requeue(self, req: Any) -> None:
        if getattr(req, "terminal", False):
            why = "completed" if req.completed_at is not None \
                else f"status={req.status!r} ({req.failed_reason})"
            _fail("terminal-retried",
                  f"request {req.request_id} charged a retry while "
                  f"already terminal ({why}) — retries must never "
                  "resurrect a settled request")


# =========================================================================
# Factories (the instrumentation points call these once, at init)
# =========================================================================
def block_sanitizer(alloc: Any) -> Optional[BlockSanitizer]:
    return BlockSanitizer(alloc) if enabled() else None


def adapter_sanitizer() -> Optional[AdapterSanitizer]:
    return AdapterSanitizer() if enabled() else None


def lifecycle_sanitizer() -> Optional[RequestLifecycle]:
    return RequestLifecycle() if enabled() else None


def request_sanitizer() -> Optional[RequestFSM]:
    return RequestFSM() if enabled() else None
