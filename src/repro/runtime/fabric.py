"""Multi-replica live serving fabric: one ``ClusterController`` routing
dispatcher subflows across a pool of ``ContinuousBatcher``-backed
``LiveReplica``s — the paper's shared-cluster system over the real JAX
runtime instead of ``SimReplica`` surfaces.

The fabric owns the wall-clock control loop:

  tick        ``ClusterController.tick(now)`` runs the two-timescale
              dispatcher (macro: latency-model refits + b_max budgets,
              micro: Eq. 18-19 priority reallocation + queued-request
              rebalancing) and, with fine-tuning enabled, the launcher/
              coordinator replanning of per-replica train/infer splits;
  pump        every live replica advances ONE runtime tick
              (``pump_once``: gated ingest → decode step → emit), so
              replicas interleave on a shared device instead of one
              ``pump`` monopolizing it; a replica with an active train
              session fuses ITS tick with one shadow-adapter
              ``combined_step`` (incremental rounds — no blocking
              ``train_round`` call ever stalls the pool);
  placement   the dispatcher fires subflows in *headroom* order (free
              pool blocks / free slots / queue depth via
              ``ReplicaHandle.pressure``) and routes requests whose
              prompts match a replica's registered prefix-cache chains
              to that replica (``prefix_affinity``);
  failover    ``fail_replica`` tears a replica down mid-flight
              (``drain_pending``: all pool blocks freed) and requeues
              its unfinished requests on the survivors — no request is
              lost, and greedy outputs are unchanged because survivors
              regenerate from the prompt.

``build_fabric`` is the one-call constructor used by
``launch/serve.py --replicas N`` and ``benchmarks/multi_replica.py``:
every replica shares the same frozen base params (the paper's
model-sharing premise) but owns its adapter, optimizer state, and KV
cache pool.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterConfig, ClusterController
from repro.core.interfaces import BatchResult, Request
from repro.runtime.fault import (
    FaultInjector, HealthConfig, HealthMonitor, RetryPolicy,
)
from repro.runtime.metrics import aggregate_serve_stats
from repro.runtime.replica import LiveReplica


@dataclasses.dataclass
class FabricConfig:
    """Throughput-oriented defaults for live multi-replica serving."""
    slo: float = 120.0              # generous: live smoke runs are slow
    in_flight_limit: int = 2        # keep each replica double-buffered
    monitor_interval: float = 0.05
    t_fit: float = 2.0
    t_adjust: float = 0.5
    bootstrap_b_max: int = 8
    enable_finetuning: bool = False
    # live COMBINED sessions (enable_finetuning=True): cohort + round
    # pacing sized for wall-clock smoke fabrics — the simulator's
    # 50-step / 5-second-decision defaults would starve a live loop
    min_cohort: int = 2
    decision_interval: float = 0.25
    bootstrap_steps: int = 4
    steps_per_round: int = 4
    train_batch: int = 4            # B0 bootstrap train batch
    max_rounds: int = 1000
    # fault tolerance (runtime/fault.py): pump-driven health + retries
    beat_timeout: float = 1.0       # silent seconds = one missed beat
    max_missed_beats: int = 3
    health_poll_interval: float = 0.1
    straggler_threshold: float = 3.0
    straggler_window: int = 32
    straggler_min_samples: int = 8
    straggler_warmup: int = 4       # jit-compile grace per replica
    quarantine_cooldown: float = 1.0
    max_retries: int = 4            # re-admissions per request
    max_request_failures: int = 3   # replica deaths before poison verdict
    retry_backoff: float = 0.05     # base of the exponential backoff
    # token-level co-scheduling (chunked prefill + SLO tick budgets):
    # prefill_chunk > 0 splits prompt prefill into fixed-token chunks
    # interleaved with decode ticks; tpot_target > 0 (seconds/token)
    # budgets each tick — decode first, prefill chunks in slack order,
    # leftover slack admits (possibly shrunk) train microbatches
    prefill_chunk: int = 0
    tpot_target: float = 0.0
    # oversubscribed KV pool (paged only): oversubscribe in (0, 1]
    # reserves only near-term need against that pool watermark and
    # preempts on exhaustion (victims swap to host or drop+re-prefill,
    # swap=False forces drop); 0 keeps preemption-free worst-case
    # reservations
    oversubscribe: float = 0.0
    swap: bool = True


class ServingFabric:
    """Dispatcher-routed pool of live replicas with placement-aware
    admission, micro-cycle rebalancing, and mid-flight failover.  With
    ``enable_finetuning=True`` the fabric tick also drives the
    Launcher/Coordinator two-timescale loop over the SAME replicas:
    incremental COMBINED train sessions advance one fused step per
    ``pump_once`` tick, and round aggregation publishes merged adapters
    at round boundaries only (shadow-adapter double buffering keeps
    in-round serving bit-identical to serve-only)."""

    def __init__(self, cfg: Optional[FabricConfig] = None):
        self.cfg = cfg or FabricConfig()
        ccfg = ClusterConfig(slo=self.cfg.slo,
                             monitor_interval=self.cfg.monitor_interval,
                             enable_finetuning=self.cfg.enable_finetuning)
        ccfg.dispatcher.in_flight_limit = self.cfg.in_flight_limit
        ccfg.dispatcher.t_fit = self.cfg.t_fit
        ccfg.dispatcher.t_adjust = self.cfg.t_adjust
        ccfg.dispatcher.bootstrap_b_max = self.cfg.bootstrap_b_max
        if self.cfg.enable_finetuning:
            ccfg.launcher.min_cohort = self.cfg.min_cohort
            ccfg.launcher.decision_interval = self.cfg.decision_interval
            ccfg.launcher.max_rounds = self.cfg.max_rounds
            ccfg.launcher.coordinator.bootstrap_steps = \
                self.cfg.bootstrap_steps
            ccfg.launcher.coordinator.steps_per_round = \
                self.cfg.steps_per_round
            ccfg.launcher.coordinator.bootstrap_train_batch = \
                self.cfg.train_batch
        self.cluster = ClusterController(ccfg)
        self.replicas: Dict[str, LiveReplica] = {}
        # failed/removed replicas' serving counters: their pre-kill work
        # must stay in the cluster totals
        self.retired_stats: Dict[str, Any] = {}
        self.results: List[BatchResult] = []
        # fault tolerance: pump-driven health verdicts + the request
        # retry budget the failover drain path charges
        self.health = HealthMonitor(HealthConfig(
            beat_timeout=self.cfg.beat_timeout,
            max_misses=self.cfg.max_missed_beats,
            poll_interval=self.cfg.health_poll_interval,
            straggler_threshold=self.cfg.straggler_threshold,
            straggler_window=self.cfg.straggler_window,
            straggler_min_samples=self.cfg.straggler_min_samples,
            straggler_warmup=self.cfg.straggler_warmup,
            quarantine_cooldown=self.cfg.quarantine_cooldown))
        self.retry_policy = RetryPolicy(
            max_retries=self.cfg.max_retries,
            max_failures=self.cfg.max_request_failures,
            backoff_base=self.cfg.retry_backoff)
        self.cluster.retry_policy = self.retry_policy
        self.injector: Optional[FaultInjector] = None
        # fault log: (now, replica_id, action) — failover/quarantine
        # decisions for telemetry and post-mortems
        self.fault_log: List[Tuple[float, str, str]] = []
        self.quarantines = 0
        self.failovers = 0

    # ------------------------------------------------------------ registry -
    def on_result(self, result: BatchResult, stream_id: str) -> None:
        """Completion callback wired into every replica at build time."""
        self.results.append(result)
        self.cluster.on_batch_result(result, stream_id)

    def add_replica(self, rep: LiveReplica) -> None:
        from repro.core.states import ReplicaState
        if self.injector is not None and getattr(rep, "injector",
                                                 None) is None:
            rep.injector = self.injector
        self.replicas[rep.replica_id] = rep
        # with fine-tuning on, fresh replicas join IDLE so the launcher
        # can cohort them immediately (a new replica has served nothing
        # — waiting for the Eq. 1 EWMAs to notice would be pure delay);
        # unselected ones roll back to SERVING after T' decisions
        self.cluster.add_replica(
            rep, ReplicaState.IDLE if self.cfg.enable_finetuning
            else ReplicaState.SERVING)

    def fail_replica(self, replica_id: str, now: float) -> LiveReplica:
        """Mid-flight failure: the controller drains the dead replica
        (all pool blocks freed) and requeues its unfinished requests on
        the survivors.  Returns the removed handle for post-mortems."""
        rep = self.replicas.pop(replica_id)
        self.cluster.remove_replica(replica_id, now)
        self.retired_stats[replica_id] = rep.batcher.stats
        self.health.forget(replica_id)
        self.failovers += 1
        self.fault_log.append((now, replica_id, "failover"))
        # multi-tenant failover: every tenant the dead replica served
        # must stay servable — re-register its host tree (at the dead
        # replica's version) on any survivor that lacks it; survivors
        # already serving the tenant keep their own copy
        if rep.adapters is not None:
            for aid in rep.adapters.registered():
                tree = rep.adapters.host_tree(aid)
                ver = rep.adapters.version(aid)
                for peer in self.replicas.values():
                    if peer.adapters is not None \
                            and not peer.adapters.is_registered(aid):
                        peer.adapters.register(aid, tree, version=ver)
        return rep

    # ------------------------------------------------------------ serving --
    def submit(self, req: Request) -> None:
        self.cluster.submit_request(req)

    def tick(self, now: float) -> bool:
        """ONE fabric tick: run the control plane (dispatcher macro/
        micro cycles AND — with fine-tuning enabled — the launcher's
        session polling / round aggregation), then advance every live
        replica one runtime tick (``pump_once``: serving decode fused
        with its session's train step).  Returns True while any replica
        holds unfinished serving work.

        Fault containment: an exception escaping a pump NEVER crashes
        the loop — it is reported to the HealthMonitor as a detected
        failure, and the tick closes by acting on health verdicts
        (dead -> ``fail_replica`` failover, straggler -> quarantine
        drain + dispatcher suspension)."""
        self.cluster.tick(now)
        busy = False
        for rid, rep in list(self.replicas.items()):
            if rid not in self.replicas:
                continue        # removed by an earlier verdict this tick
            t0 = time.perf_counter()
            try:
                served = rep.pump_once(now)
            except Exception as e:          # noqa: BLE001 — containment
                self.health.failure(rid, now,
                                    reason=type(e).__name__)
                continue
            # heartbeat off REAL pump progress; serving ticks feed
            # their wall latency to the straggler watch (idle ticks
            # are ~free and would drag the medians toward zero)
            self.health.beat(rid, now,
                             busy_s=time.perf_counter() - t0
                             if served else None)
            busy = served or busy
        dead, stragglers = self.health.poll(now)
        for rid in dead:
            if rid in self.replicas:
                self.fail_replica(rid, now)
        for rid in stragglers:
            if rid in self.replicas:
                self.quarantine_replica(rid, now)
        return busy

    def quarantine_replica(self, replica_id: str, now: float) -> None:
        """Straggler mitigation: drain the replica's pending work back
        through the SAME ``drain_pending`` path failover uses (charged
        to the retry budget as a non-fatal re-admission), requeue it on
        the stream queues, and suspend the replica's subflows for the
        health cooldown.  The replica stays a pool member — after the
        cooldown the dispatcher resumes routing to it and the watch
        re-evaluates from fresh samples."""
        rep = self.replicas[replica_id]
        until = self.health.quarantine(replica_id, now)
        drained = rep.drain_pending(now)
        survivors = self.retry_policy.filter_requeue(
            drained, now, replica_died=False)
        by_stream: Dict[str, List[Request]] = {}
        for req in survivors:
            by_stream.setdefault(req.stream_id, []).append(req)
        for sid, reqs in by_stream.items():
            self.cluster.dispatcher_for(sid).requeue(reqs)
        for d in self.cluster.dispatchers.values():
            d.suspend_replica(replica_id, until)
        self.quarantines += 1
        self.fault_log.append((now, replica_id, "quarantine"))

    @property
    def training(self) -> bool:
        """True while any FL session is open on the fabric."""
        return bool(self.cluster.launcher.sessions)

    def run(self, requests: Sequence[Request], *,
            timeout: float = 600.0,
            failures: Sequence[Tuple[float, str]] = (),
            min_rounds: int = 0) -> Dict:
        """Drive the fabric until every request completes (or re-queues
        are impossible).  ``requests`` are submitted when the wall clock
        passes their ``arrival``; ``failures`` is a list of
        ``(time, replica_id)`` kill events injected mid-run.  With
        fine-tuning enabled, ``min_rounds`` keeps the loop ticking until
        that many FL rounds have aggregated (bounded by ``timeout``).
        Returns the aggregate serving summary (see
        ``aggregate_serve_stats``) plus dispatcher/routing telemetry
        and, when training ran, the launcher's round history."""
        todo = sorted(requests, key=lambda r: r.arrival)
        kills = sorted(failures)
        next_req = 0
        t0 = time.perf_counter()
        while True:
            now = time.perf_counter() - t0
            while next_req < len(todo) and todo[next_req].arrival <= now:
                self.submit(todo[next_req])
                next_req = next_req + 1
            while kills and kills[0][0] <= now:
                _, rid = kills.pop(0)
                if rid in self.replicas:
                    self.fail_replica(rid, now)
            busy = self.tick(now)
            rounds_ok = self.cluster.launcher.completed_rounds \
                >= min_rounds
            # a request is settled once TERMINAL: served, or
            # terminally rejected (retry budget / poison / deadline) —
            # waiting on a failed request would spin out the timeout
            if next_req >= len(todo) and not kills and not busy \
                    and all(r.terminal for r in todo) \
                    and (rounds_ok or not self.training):
                break
            if not self.replicas:
                # every replica failed: requeued requests have nowhere
                # to go — report the stranding instead of spinning out
                # the timeout
                break
            if now > timeout:
                break
            if not busy and not self.training:
                # idle until the next arrival / subflow fire instead of
                # hot-spinning the control loop (a live session keeps
                # the loop hot: every tick is one fused train step)
                time.sleep(0.002)
        out = self.summary()
        out["incomplete_requests"] = sum(
            1 for r in todo if r.completed_at is None)
        out["failed_requests"] = sum(
            1 for r in todo if r.status == "failed")
        return out

    # ---------------------------------------------------------- telemetry --
    def summary(self) -> Dict:
        out = aggregate_serve_stats(
            {**self.retired_stats,
             **{rid: rep.batcher.stats
                for rid, rep in self.replicas.items()}})
        out["dispatchers"] = {
            sid: {"dispatched": d.dispatched, "dropped": d.dropped,
                  "affinity_routed": d.affinity_routed,
                  "adapter_routed": d.adapter_routed,
                  "rebalanced": d.rebalanced,
                  "overload_promotions": d.overload_promotions}
            for sid, d in self.cluster.dispatchers.items()}
        launcher = self.cluster.launcher
        out["fl_rounds"] = launcher.completed_rounds
        out["rounds"] = [dict(r) for r in launcher.round_history]
        out["adapter_versions"] = dict(launcher.adapter_versions)
        out["fault_tolerance"] = {
            "failovers": self.failovers,
            "quarantines": self.quarantines,
            "failures_detected": len(self.health.failures),
            "retried_requests": self.retry_policy.retried,
            "rejected_requests": len(self.retry_policy.rejected),
            "nan_publishes_blocked":
                out["cluster"]["nan_publishes_blocked"],
            "injected": list(self.injector.injected)
                if self.injector is not None else [],
            "log": list(self.fault_log),
        }
        return out


def make_tenant_adapters(model, n: int, *, seed: int = 0) -> List[Any]:
    """``n`` distinct tenant LoRA trees for multi-tenant serving.

    Standard init sets ``b = 0`` (a fresh adapter is a no-op), which
    would make every tenant serve identical base-model tokens — so
    tenants t >= 1 get a NONZERO ``b`` drawn per target, giving each a
    distinct greedy stream (the 0.5 scale is deliberate: much smaller
    perturbations shift logits without flipping any argmax on smoke
    configs, collapsing every tenant onto the base stream).  Tenant 0
    keeps the no-op init: it is the co-training tenant whose weights
    the publish path rewrites."""
    import jax

    out = []
    for t in range(n):
        key = jax.random.key(seed + 101 * t)
        tree = model.init_lora(key)
        if t > 0:
            for i, tgt in enumerate(sorted(tree)):
                k = jax.random.fold_in(key, i + 1)
                b = tree[tgt]["b"]
                tree[tgt]["b"] = 0.5 * jax.random.normal(
                    k, b.shape, b.dtype)
        out.append(tree)
    return out


def build_fabric(arch: str, n_replicas: int, *, smoke: bool = True,
                 n_slots: int = 4, prompt_len: int = 32,
                 gen_tokens: int = 16, paged: bool = False,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 prefix_cache: bool = False, seed: int = 0,
                 train_pool: int = 0, n_adapters: int = 0,
                 adapter_slots: Optional[int] = None,
                 cfg: Optional[FabricConfig] = None,
                 injector: Optional[FaultInjector] = None,
                 ) -> Tuple[ServingFabric, Any]:
    """Build a fabric of ``n_replicas`` live replicas over ONE shared
    set of frozen base params (each replica owns its adapter, optimizer
    state, and cache pool).  Returns ``(fabric, model_cfg)``.

    ``train_pool > 0`` fixes the fine-tuning corpus to that many
    batches cycled epoch-style (a finite finetuning set, the realistic
    FL PEFT workload — and a train-loss signal strong enough to gate
    on); 0 streams fresh synthetic batches every step.

    ``n_adapters > 0`` turns on multi-tenant serving: every replica
    gets an ``AdapterRegistry`` (``adapter_slots`` device slots, all
    tenants by default) with the SAME ``tenant0..tenant{k-1}`` host
    trees registered, so any replica can serve any tenant and failover
    regeneration stays bit-identical.  ``tenant0``'s tree IS the
    replica's co-training adapter: each publish writes through to its
    registry slot (``LiveReplica.publish_adapter``)."""
    import jax

    from repro.configs.registry import get_config
    from repro.core.engine import make_engine
    from repro.data.synthetic import SyntheticDataset

    mcfg = get_config(arch)
    if smoke:
        mcfg = mcfg.scaled()
    assert mcfg.has_decode, f"{arch} is encoder-only; no decode serving"
    engine = make_engine(mcfg, lr=3e-3)
    model = engine.model
    params = model.init(jax.random.key(seed))
    data = SyntheticDataset("alpaca", vocab_size=mcfg.vocab_size,
                            seq_len=max(prompt_len, 16), seed=seed)
    pools: Dict[int, List[Dict[str, Any]]] = {}

    def make_data_fn() -> Callable[[int], Dict[str, Any]]:
        """Per-replica cursor over the SHARED batch pool: every member
        walks the same finite corpus in the same epoch order (the FL
        local-dataset pass), independent of how the fabric interleaves
        replica ticks — pool consumption stays deterministic."""
        cursors: Dict[int, int] = {}

        def data_fn(b: int) -> Dict[str, Any]:
            import jax.numpy as jnp

            def fresh():
                return {k: jnp.asarray(v)
                        for k, v in data.batch(b).items()}

            if train_pool <= 0:
                return fresh()
            if b not in pools:
                pools[b] = [fresh() for _ in range(train_pool)]
            i = cursors.get(b, 0)
            cursors[b] = i + 1
            return pools[b][i % train_pool]

        return data_fn

    tenant_trees: List[Any] = []
    if n_adapters > 0:
        tenant_trees = make_tenant_adapters(model, n_adapters,
                                            seed=seed + 1)
    fabric = ServingFabric(cfg)
    if train_pool > 0:
        # prewarm the shared pool at build time: materializing
        # train_pool device batches lazily would land on the first
        # train-due tick — usually a SERVING tick — and charge data
        # prep to measured serving wall time
        make_data_fn()(fabric.cfg.train_batch)
    fabric.injector = injector
    for i in range(n_replicas):
        if n_adapters > 0:
            # tenant0's no-op tree doubles as the replica's co-training
            # adapter — identical on every replica, so mixed placement
            # and failover keep greedy streams bit-identical
            lora = tenant_trees[0]
        else:
            lora = model.init_lora(jax.random.key(seed + 1))
        opt_state = engine.optimizer.init(lora)
        registry = None
        train_tenant = None
        if n_adapters > 0:
            from repro.runtime.serving_loop import AdapterRegistry
            registry = AdapterRegistry(
                model, capacity=adapter_slots or n_adapters)
            for t, tree in enumerate(tenant_trees):
                registry.register(f"tenant{t}", tree)
            train_tenant = "tenant0"
        fabric.add_replica(LiveReplica(
            f"r{i}", mcfg.name, engine, params, lora, opt_state,
            on_result=fabric.on_result, data_fn=make_data_fn(),
            serve_slots=n_slots, serve_prompt_len=prompt_len,
            max_gen_tokens=gen_tokens, serve_paged=paged,
            serve_block_size=block_size, serve_n_blocks=n_blocks,
            serve_prefix_cache=prefix_cache, adapters=registry,
            train_tenant=train_tenant,
            serve_prefill_chunk=fabric.cfg.prefill_chunk,
            serve_tpot_target=fabric.cfg.tpot_target,
            serve_oversubscribe=fabric.cfg.oversubscribe,
            serve_swap=fabric.cfg.swap))
    return fabric, mcfg
