"""Serving metrics (paper §8.1).

goodput   — output tokens/s of responses that met their SLO deadline
Q-goodput — goodput weighted by response quality (= 1 / CE loss)
plus utilization timelines and control-plane overhead accounting.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.interfaces import BatchResult, Request


@dataclasses.dataclass
class MetricsCollector:
    horizon: float

    def __post_init__(self):
        self.results: List[BatchResult] = []
        self.util_samples: Dict[str, List[Tuple[float, float]]] = \
            collections.defaultdict(list)
        self.overhead_time: float = 0.0
        self.infer_time: float = 0.0
        self.train_time: float = 0.0

    # ------------------------------------------------------------- inputs --
    def on_result(self, result: BatchResult, stream_id: str) -> None:
        self.results.append(result)
        self.infer_time += result.infer_latency

    def sample_utilization(self, replica_id: str, now: float,
                           util: float) -> None:
        self.util_samples[replica_id].append((now, util))

    # ------------------------------------------------------------ outputs --
    def goodput(self, requests: Sequence[Request]) -> Dict[str, float]:
        done = [r for r in requests if r.completed_at is not None]
        met = [r for r in done if r.slo_met]
        tokens_met = sum(r.tokens for r in met)
        q_tokens = sum(r.tokens * r.quality for r in met)
        dur = max(self.horizon, 1e-9)
        return {
            "requests": len(requests),
            "completed": len(done),
            "slo_met": len(met),
            "slo_rate": len(met) / max(len(requests), 1),
            "goodput_tok_s": tokens_met / dur,
            "q_goodput": q_tokens / dur,
            "mean_quality": float(np.mean([r.quality for r in met]))
            if met else 0.0,
        }

    def utilization_summary(self) -> Dict[str, float]:
        vals = [u for s in self.util_samples.values() for _, u in s]
        if not vals:
            return {"mean_util": 0.0, "p10_util": 0.0}
        return {"mean_util": float(np.mean(vals)),
                "p10_util": float(np.quantile(vals, 0.10)),
                "p90_util": float(np.quantile(vals, 0.90))}

    def utilization_timeline(self, bucket: float = 60.0
                             ) -> Tuple[np.ndarray, np.ndarray]:
        """Cluster-mean utilization per time bucket (Fig. 11a)."""
        allsamp = [(t, u) for s in self.util_samples.values() for t, u in s]
        if not allsamp:
            return np.zeros(0), np.zeros(0)
        allsamp.sort()
        ts = np.asarray([t for t, _ in allsamp])
        us = np.asarray([u for _, u in allsamp])
        nb = max(int(self.horizon / bucket), 1)
        idx = np.minimum((ts / bucket).astype(int), nb - 1)
        sums = np.bincount(idx, weights=us, minlength=nb)
        cnts = np.maximum(np.bincount(idx, minlength=nb), 1)
        return (np.arange(nb) + 0.5) * bucket, sums / cnts

    def overhead_fraction(self) -> float:
        total = self.overhead_time + self.infer_time + self.train_time
        return self.overhead_time / max(total, 1e-9)


# =========================================================================
# Cluster-wide serving-stats aggregation (multi-replica fabric)
# =========================================================================
_SERVE_COUNTERS = ("admitted", "finished", "prefill_tokens",
                   "cached_prefix_tokens", "generated_tokens",
                   "decode_steps", "train_steps",
                   "nan_publishes_blocked",
                   "budget_ticks", "budget_spent_s", "budget_target_s",
                   "train_skipped_ticks",
                   "preemptions", "swap_out_blocks", "swap_in_blocks",
                   "reprefill_tokens")


def _pctl(vals: List[float]) -> Dict[str, float]:
    """p50/p99 summary of a latency sample list (empty -> None)."""
    if not vals:
        return {"p50": None, "p99": None}
    a = np.asarray(vals, dtype=float)
    return {"p50": float(np.quantile(a, 0.50)),
            "p99": float(np.quantile(a, 0.99))}


def aggregate_serve_stats(per_replica: Dict[str, "object"]) -> Dict:
    """Fold per-replica ``ServeStats`` into one coherent cluster summary.

    Returns ``{"replicas": {rid: {...}}, "cluster": {...}}`` where the
    cluster row sums every token/step counter and reports throughput two
    ways: ``throughput_sum_tok_s`` — the sum of per-replica rates (the
    pool's aggregate rate with each replica on its own accelerator, the
    deployment model) — and ``throughput_wall_tok_s`` — total tokens
    over the SUMMED per-replica busy time (replicas time-slice one
    device, so its sustained rate divides by total busy seconds, not
    the longest replica's).  Duck-typed over the ServeStats fields so
    the metrics module stays JAX-free."""
    replicas: Dict[str, Dict[str, float]] = {}
    cluster: Dict[str, float] = {f: 0 for f in _SERVE_COUNTERS}
    rates: List[float] = []
    walls: List[float] = []
    versions: List[int] = []
    train_losses: List[float] = []
    all_ttft: List[float] = []
    all_tpot: List[float] = []
    for rid in sorted(per_replica):
        s = per_replica[rid]
        row = {f: getattr(s, f, 0) for f in _SERVE_COUNTERS}
        row["wall_time"] = float(s.wall_time)
        row["throughput_tok_s"] = float(s.throughput())
        # SLO latency distributions: per-request ttft (arrival ->
        # first token) and tpot (mean seconds/token after the first)
        r_ttft = list(getattr(s, "ttft", []) or [])
        r_tpot = list(getattr(s, "tpot", []) or [])
        row["ttft"] = _pctl(r_ttft)
        row["tpot"] = _pctl(r_tpot)
        all_ttft.extend(r_ttft)
        all_tpot.extend(r_tpot)
        # token-budget scheduler: fraction of each tick's SLO budget
        # actually spent (None when the budget planner is off)
        tgt = float(getattr(s, "budget_target_s", 0.0))
        row["budget_utilization"] = \
            float(getattr(s, "budget_spent_s", 0.0)) / tgt if tgt > 0 \
            else None
        # quality progression: which adapter the replica serves and the
        # latest train CE its fused steps saw (None until it trained)
        row["adapter_version"] = int(getattr(s, "adapter_version", 0))
        tl = float(getattr(s, "train_loss", float("nan")))
        row["train_loss"] = tl if tl == tl else None
        # multi-tenant serving: per-adapter finished-request counts and
        # the tenant's adapter version at last touch ({} on
        # single-adapter replicas / pre-registry stats objects)
        row["adapter_requests"] = dict(
            getattr(s, "adapter_requests", {}) or {})
        row["adapter_versions"] = dict(
            getattr(s, "adapter_versions", {}) or {})
        replicas[rid] = row
        for f in _SERVE_COUNTERS:
            cluster[f] += row[f]
        rates.append(row["throughput_tok_s"])
        walls.append(row["wall_time"])
        versions.append(row["adapter_version"])
        if row["train_loss"] is not None:
            train_losses.append(row["train_loss"])
    cluster["n_replicas"] = len(replicas)
    cluster["wall_time_busy"] = float(sum(walls))
    cluster["wall_time_max"] = float(max(walls, default=0.0))
    cluster["throughput_sum_tok_s"] = float(sum(rates))
    cluster["throughput_wall_tok_s"] = \
        cluster["generated_tokens"] / max(cluster["wall_time_busy"], 1e-9)
    # adapter spread: min == max once every member serves the merged
    # global; a lagging min flags a replica stuck on an old version
    cluster["adapter_version_min"] = int(min(versions, default=0))
    cluster["adapter_version_max"] = int(max(versions, default=0))
    cluster["train_loss"] = float(np.mean(train_losses)) \
        if train_losses else None
    # cluster latency distributions over the CONCATENATED per-request
    # samples (every request counts once, whichever replica served it)
    cluster["ttft"] = _pctl(all_ttft)
    cluster["tpot"] = _pctl(all_tpot)
    tgt = float(cluster["budget_target_s"])
    cluster["budget_utilization"] = \
        float(cluster["budget_spent_s"]) / tgt if tgt > 0 else None
    # per-adapter cluster rollup: requests summed across replicas,
    # version spread per tenant (min < max flags a replica serving a
    # stale copy of that tenant's adapter)
    adapters: Dict[str, Dict[str, int]] = {}
    for row in replicas.values():
        for aid, n in row["adapter_requests"].items():
            a = adapters.setdefault(
                aid, {"requests": 0, "version_min": None,
                      "version_max": None})
            a["requests"] += int(n)
        for aid, v in row["adapter_versions"].items():
            a = adapters.setdefault(
                aid, {"requests": 0, "version_min": None,
                      "version_max": None})
            v = int(v)
            a["version_min"] = v if a["version_min"] is None \
                else min(a["version_min"], v)
            a["version_max"] = v if a["version_max"] is None \
                else max(a["version_max"], v)
    cluster["adapters"] = {aid: adapters[aid] for aid in sorted(adapters)}
    return {"replicas": replicas, "cluster": cluster}
