"""Slot-based continuous-batching decode runtime with a paged KV cache
(FlexLLM-style token-level co-serving over one shared base model).

A ``ContinuousBatcher`` owns a fixed pool of decode *slots* whose KV
lives in one of two cache layouts:

  contiguous  ``model.init_caches(n_slots, max_seq)`` — every slot owns
              a worst-case ``max_seq`` stripe (the pre-paging design,
              kept as the equivalence baseline);
  paged       ``paged=True``: a global block pool
              ``[L, n_blocks, block_size, Hkv, Dh]``
              (``model.init_paged_caches``) plus per-slot block tables.
              A ``BlockAllocator`` (runtime/paging.py) reserves each
              request's worst case at admission and hands out blocks
              lazily — prompt blocks at admission, one more whenever
              decode crosses a block boundary — so cache memory scales
              with live tokens, not ``n_slots * max_seq``, and admission
              is rejected (queue backpressure, preemption-free) when the
              pool can't cover a request's worst case.  With
              ``oversubscribe=w`` (0 < w <= 1) admission reserves only
              near-term need (prompt blocks + a one-block lookahead)
              against a ``w``-fraction watermark of the pool instead,
              and mid-decode pool exhaustion PREEMPTS a victim slot:
              its private block chain either swaps to host memory
              (batched device->host gather; restored by a batched
              scatter into fresh blocks) or is dropped and re-prefilled
              from host-kept token ids, whichever an EMA cost model
              prices cheaper.  COW-shared / prefix-registered blocks
              are never copied — they stay pool-resident (or revive via
              the ``PrefixCache``).  Restores run ahead of the decode
              wave in deadline-slack order and greedy output stays
              bit-identical to a never-preempted run.

Paged mode can additionally share prompt prefixes copy-on-write
(``prefix_cache=True``): full, immutable prompt blocks are registered
in a hash-indexed ``PrefixCache`` (runtime/paging.py); a request whose
prompt starts with a cached block chain aliases those pool blocks at
refcount+1, prefills ONLY the uncached suffix
(``model.prefill_ragged_suffix`` attends the suffix over prefix K/V
gathered straight from the pool), and copy-on-writes a private block
before any decode write would land in a shared one (sliding-window
ring wraps).  Evicted-but-cached blocks park in an LRU retained pool
and are reclaimed on allocator pressure, so warm prefixes survive
across requests; cache memory then scales with *distinct* live tokens.

The runtime tick is unchanged by the layout:

  admission   free slots take queued requests; the whole wave prefills
              through ONE ragged ``model.prefill_ragged`` program and
              lands in the cache with ONE batched scatter
              (``write_prefill_slots`` / ``write_prefill_blocks``) —
              no per-request write calls;
  decode      every step advances ALL active slots one token with
              per-slot positions (``decode_step`` / ``decode_step_paged``
              with ``pos [B]``); paged decode streams only the bucketed
              live block range, and ``attention_decode`` dispatches to
              the Pallas kernels (kernels/decode_attention.py) on TPU
              with the jnp path as interpreter/CPU fallback;
  eviction    a slot frees the moment its request hits max_new_tokens /
              EOS — its blocks return to the allocator and the next
              queued request is admitted mid-flight;
  co-serving  passing a training batch to ``step`` runs the fused
              ``engine.combined_step`` / ``combined_step_paged`` — LoRA
              finetuning + the decode tick in ONE program over shared
              base weights (the paper's model-sharing semantics, per
              token instead of per batch).  With a shadow staged
              (``train_lora``), the optimizer trains IT while decode
              reads the published ``lora`` snapshot — see the
              ContinuousBatcher docstring.

``static_batch_serve`` is the lock-step baseline (prefill a batch,
decode until every request in the batch finishes, dead slots riding
along) used by benchmarks/ and the equivalence tests.

Scope: non-VLM families; full-attention or cache-covering windows
(``sliding_window == 0 or >= max_seq``) on the contiguous path, plus
ring-over-blocks sliding windows on the paged path (the paged ring
wraps at ``min(max_seq, window)`` exactly like the contiguous ring, so
greedy outputs are identical).  Paged mode needs an attention-only
stack — SSM state is per-slot, not per-block.  Oversubscribed mode
additionally needs full attention (``sliding_window == 0``): a ring
wrap overwrites cache rows in place, so a dropped request could not be
re-prefilled into an equivalent state.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import os
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.interfaces import slack_order
from repro.runtime.paging import BlockAllocator, PrefixCache, blocks_for
from repro.runtime.sanitize import adapter_sanitizer, lifecycle_sanitizer


@functools.lru_cache(maxsize=16)
def _engine_jits(engine) -> Dict[str, Callable]:
    """One set of jitted step programs per (frozen, hashable) Engine —
    shared across every batcher / baseline run on that engine so fresh
    runtimes never retrace (donation is per-call, sharing is safe)."""
    model = engine.model
    return {
        "decode": jax.jit(model.decode_step, donate_argnums=(2,),
                          static_argnames=("attn_backend",)),
        "decode_paged": jax.jit(
            model.decode_step_paged, donate_argnums=(2,),
            static_argnames=("ring_len", "attn_backend")),
        "prefill_ragged": jax.jit(model.prefill_ragged),
        "prefill_exact": jax.jit(model.prefill),
        "write": jax.jit(model.write_prefill_slot, donate_argnums=(0,)),
        "write_slots": jax.jit(model.write_prefill_slots,
                               donate_argnums=(0,)),
        "write_blocks": jax.jit(model.write_prefill_blocks,
                                donate_argnums=(0,)),
        "prefill_suffix": jax.jit(model.prefill_ragged_suffix),
        "prefill_continue": jax.jit(model.prefill_ragged_continue),
        "write_rows": jax.jit(model.write_prefill_rows,
                              donate_argnums=(0,)),
        "copy_blocks": jax.jit(model.copy_blocks, donate_argnums=(0,)),
        "gather_blocks": jax.jit(model.gather_blocks),
        "scatter_blocks": jax.jit(model.scatter_blocks,
                                  donate_argnums=(0,)),
        "combined": jax.jit(
            engine.combined_step, donate_argnums=(2, 4),
            static_argnames=("attn_backend", "grad_accum",
                             "train_tokens")),
        "combined_paged": jax.jit(
            engine.combined_step_paged, donate_argnums=(2, 4),
            static_argnames=("ring_len", "attn_backend", "grad_accum",
                             "train_tokens")),
        "train": jax.jit(engine.train_step, donate_argnums=(2,),
                         static_argnames=("grad_accum", "train_tokens")),
        "loss": jax.jit(
            lambda p, l, b: engine.model.forward_loss(p, l, b)[0]),
    }


@dataclasses.dataclass
class GenRequest:
    """One generation request: prompt in, sampled tokens out (greedy by
    default — ``temperature <= 0``)."""
    request_id: int
    prompt: np.ndarray                  # [P] int32 token ids
    max_new_tokens: int = 16
    arrival: float = 0.0
    # SLO deadline (same clock as ``arrival``): the chunked-prefill
    # scheduler spends each tick's leftover budget in deadline-slack
    # order (core/interfaces.slack_order, shared with the dispatcher)
    deadline: float = float("inf")
    # multi-tenant serving: which registered adapter this request's
    # tokens flow through (None = the base model / single-adapter mode)
    adapter_id: Optional[str] = None
    # sampling: temperature <= 0 is exact greedy (the argmax fast path,
    # no host logits transfer); top_k/top_p filter before the softmax;
    # ``seed`` makes the sampled stream reproducible per request
    # (defaults to request_id so identical traces replay identically)
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    # filled by the runtime
    tokens: List[int] = dataclasses.field(default_factory=list)
    prefill_at: Optional[float] = None
    # when the FIRST generated token landed — equals ``prefill_at`` on
    # monolithic prefill, later under chunking (the TTFT stamp)
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    # wall-clock (perf_counter) finish stamp — ``finished_at`` carries
    # whatever clock the caller's ``now`` uses, which may be sim time
    finished_wall: Optional[float] = None
    rng: Any = None                     # per-request sampling stream

    @property
    def done(self) -> bool:
        return self.finished_at is not None

    @property
    def samples(self) -> bool:
        return self.temperature > 0.0


def sample_token(logits: np.ndarray, *, temperature: float = 0.0,
                 top_k: int = 0, top_p: float = 1.0,
                 rng: Optional[np.random.Generator] = None) -> int:
    """Sample one token id from a ``[V]`` logits row.

    ``temperature <= 0`` (or no rng) is exact greedy argmax.  Otherwise:
    scale by temperature, keep the ``top_k`` highest logits (0 = all),
    then the nucleus — the smallest probability mass >= ``top_p`` —
    and draw from the renormalized distribution.  float64 softmax so
    the host-side distribution is deterministic across platforms."""
    if temperature <= 0.0 or rng is None:
        return int(np.argmax(logits))
    row = np.asarray(logits, np.float64) / temperature
    if 0 < top_k < row.size:
        kth = np.partition(row, -top_k)[-top_k]
        row = np.where(row < kth, -np.inf, row)
    row -= row.max()
    probs = np.exp(row)
    probs /= probs.sum()
    if top_p < 1.0:
        order = np.argsort(-probs, kind="stable")
        csum = np.cumsum(probs[order])
        # smallest prefix whose mass reaches top_p (always >= 1 token)
        cut = int(np.searchsorted(csum, top_p)) + 1
        mask = np.zeros_like(probs, bool)
        mask[order[:cut]] = True
        probs = np.where(mask, probs, 0.0)
        probs /= probs.sum()
    return int(rng.choice(probs.size, p=probs))


@dataclasses.dataclass
class ServeStats:
    admitted: int = 0
    finished: int = 0
    # prompt tokens actually COMPUTED by a prefill program (with prefix
    # sharing on, cached prefixes are skipped and counted separately)
    prefill_tokens: int = 0
    cached_prefix_tokens: int = 0
    generated_tokens: int = 0
    decode_steps: int = 0
    train_steps: int = 0
    wall_time: float = 0.0
    # quality progression telemetry: the adapter version this replica
    # currently serves (bumped by set_adapter/publish_adapter) and the
    # latest train CE loss seen by its fused/plain train steps — NaN
    # until the replica has trained at all
    adapter_version: int = 0
    train_loss: float = float("nan")
    # publish-gate telemetry: rounds whose shadow (or incoming global)
    # tree was non-finite and therefore REJECTED instead of swapped
    # into serving (runtime/fault.py publish-gate contract)
    nan_publishes_blocked: int = 0
    # multi-tenant telemetry: per-adapter finished-request counts and
    # the version each tenant's adapter was serving at last touch (the
    # legacy scalar above tracks only the co-training tenant)
    adapter_requests: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    adapter_versions: Dict[str, int] = dataclasses.field(
        default_factory=dict)
    # token-budget scheduler telemetry (tpot_target > 0 only): ticks
    # planned under a budget, measured seconds of work spent vs the
    # summed per-tick target, and ticks whose train microbatch was
    # dropped outright to protect the decode TPOT SLO
    budget_ticks: int = 0
    budget_spent_s: float = 0.0
    budget_target_s: float = 0.0
    train_skipped_ticks: int = 0
    # oversubscribed-pool telemetry: victim slots preempted on pool
    # exhaustion, blocks moved device->host / host->device by the swap
    # paths, and prompt+generated tokens recomputed by drop-restore
    # re-prefills (those ALSO count in prefill_tokens — prefill_tokens
    # is total prefill compute, reprefill_tokens the restore subset)
    preemptions: int = 0
    swap_out_blocks: int = 0
    swap_in_blocks: int = 0
    reprefill_tokens: int = 0
    # per-finished-request latency samples (caller's ``now`` clock):
    # time to first token and seconds per subsequent output token —
    # aggregate_serve_stats folds these into p50/p99
    ttft: List[float] = dataclasses.field(default_factory=list)
    tpot: List[float] = dataclasses.field(default_factory=list)

    def throughput(self) -> float:
        return self.generated_tokens / max(self.wall_time, 1e-9)


class _TickBudget:
    """Per-tick token-budget planner for a decode-TPOT SLO target.

    Keeps EMA cost estimates of the three kinds of work a tick can
    carry — the decode wave, prefill-chunk tokens, train tokens — from
    measured wall times, then plans each tick FlexLLM-style: decode is
    first-class, leftover budget goes to prefill chunks (the caller
    picks rows in deadline-slack order), and whatever slack remains
    admits train tokens.  Unknown costs plan optimistically so each
    work type gets measured once before it is regulated."""

    def __init__(self, target_s: float):
        self.target_s = target_s
        self.decode_tick_s: Optional[float] = None
        self.prefill_tok_s: Optional[float] = None
        self.train_tok_s: Optional[float] = None

    @staticmethod
    def _ema(old: Optional[float], new: float) -> float:
        return new if old is None else 0.75 * old + 0.25 * new

    def observe_decode(self, dt: float) -> None:
        self.decode_tick_s = self._ema(self.decode_tick_s, dt)

    def observe_prefill(self, tokens: int, dt: float) -> None:
        if tokens > 0:
            self.prefill_tok_s = self._ema(self.prefill_tok_s,
                                           dt / tokens)

    def observe_train(self, tokens: int, dt: float) -> None:
        if tokens > 0 and dt > 0:
            self.train_tok_s = self._ema(self.train_tok_s, dt / tokens)

    def prefill_allowance(self, n_decoding: int) -> float:
        """Prefill tokens this tick may spend after decode's share.
        With no decoding slots prefill owns the whole tick — there is
        no TPOT to protect, only TTFT to win."""
        if n_decoding == 0:
            return float("inf")
        rem = self.target_s - (self.decode_tick_s or 0.0)
        if rem <= 0:
            return 0.0
        if self.prefill_tok_s is None:
            return float("inf")
        return rem / self.prefill_tok_s

    def train_tokens(self, b: int, s: int,
                     prefill_spent_s: float) -> Optional[int]:
        """Token cap for a [B, S] train microbatch in this tick's
        remaining slack: 0 = run the full batch, a positive cap shrinks
        it, None = skip training this tick.  Bucketed to {full, half,
        skip} so the fused program compiles at most twice."""
        rem = self.target_s - (self.decode_tick_s or 0.0) \
            - prefill_spent_s
        if self.train_tok_s is None:
            # unknown train cost: never stack an unmeasured train
            # program on a tick carrying serving work — one mispriced
            # probe can blow several ticks' budget.  Fully idle ticks
            # (no decode wave, no prefill) train unconditionally via
            # the caller, so the cost gets measured the moment serving
            # drains and later ticks can price half/full correctly.
            return None
        if rem >= b * s * self.train_tok_s:
            return 0
        half = (b // 2) * s
        if b >= 2 and rem >= half * self.train_tok_s:
            return half
        return None


class _SwapCost:
    """EMA cost model for the per-victim preemption choice, priced like
    ``_TickBudget``: measured seconds per byte of a device<->host block
    copy vs seconds per re-prefilled token.  Swap preserves state
    exactly, so unknown costs prefer swap — each path gets measured
    before it is regulated, and the safe choice is the default."""

    def __init__(self) -> None:
        self.swap_byte_s: Optional[float] = None
        self.prefill_tok_s: Optional[float] = None

    @staticmethod
    def _ema(old: Optional[float], new: float) -> float:
        return new if old is None else 0.75 * old + 0.25 * new

    def observe_swap(self, nbytes: int, dt: float) -> None:
        if nbytes > 0 and dt > 0:
            self.swap_byte_s = self._ema(self.swap_byte_s, dt / nbytes)

    def observe_prefill(self, tokens: int, dt: float) -> None:
        if tokens > 0 and dt > 0:
            self.prefill_tok_s = self._ema(self.prefill_tok_s,
                                           dt / tokens)

    def prefer_swap(self, tail_bytes: int, reprefill_tokens: int) -> bool:
        """Swap round trip (out + in) cheaper than recomputing the
        dropped rows?"""
        if self.swap_byte_s is None or self.prefill_tok_s is None:
            return True
        return 2.0 * tail_bytes * self.swap_byte_s \
            <= reprefill_tokens * self.prefill_tok_s


@dataclasses.dataclass
class _Swapped:
    """A preempted request parked off its slot.  ``kept`` blocks (the
    COW-shared / prefix-registered chain prefix) stay pool-resident
    with our reference held; the private tail either lives host-side in
    ``host_kv`` (mode "swap") or was dropped and will be recomputed
    from the request's host-kept token ids (mode "reprefill").  The
    pinned adapter reference is kept across the preemption so restore
    can never fail on adapter residency."""
    req: GenRequest
    adapter_id: Optional[str]
    mode: str                     # "swap" | "reprefill"
    kept: List[int]               # pool-resident chain prefix (refs held)
    host_kv: Any                  # (k, v) host arrays, swap mode only
    n_tail: int                   # private blocks to restore
    pos: int                      # decode frontier: next write position
    tok: int                      # next token to feed
    cached: int                   # prefix-cache hit tokens at admission


class AdapterError(RuntimeError):
    """Misuse of the AdapterRegistry (unknown id, double free, ...)."""


class OutOfAdapterSlots(AdapterError):
    """Every device slot is pinned by in-flight requests."""


@functools.partial(jax.jit, donate_argnums=(0,))
def _write_adapter_slot(stack, tree, slot):
    """Overwrite device slot ``slot`` of a stacked multi-adapter tree
    (leaves [L, A, din, r]) with a single-adapter tree's leaves — one
    traced program for every slot index."""
    return jax.tree.map(
        lambda stk, leaf: stk.at[:, slot].set(leaf.astype(stk.dtype)),
        stack, tree)


class AdapterRegistry:
    """Per-replica multi-tenant adapter residency: every registered
    tenant keeps a HOST copy of its LoRA tree; up to ``capacity`` of
    them are DEVICE-resident in one stacked tree (leaves
    ``[L, capacity, din, r]``) that the decode wave indexes per row
    (``segmented`` paths in models/).

    Residency is refcounted like the paged pool's ``BlockAllocator``:
    ``acquire`` pins a tenant's slot for the lifetime of a request
    (loading it from host into a free slot on a miss), ``release``
    unpins it, and refcount-0 residents park in an LRU retained list —
    still servable at hit cost zero — until a miss needs their slot
    (cold-adapter eviction).  ``update`` rewrites a resident tenant's
    slot in place, which is what makes ``publish_adapter`` an atomic
    swap under co-training: in-flight rows keep reading the slot and
    simply see the new version on their next tick, exactly like the
    single-tenant pointer swap.

    Free/evicted slots are zero-filled at init and overwritten on load,
    so the stacked tensors stay finite — a requirement of the fused
    segmented kernel, whose concatenated B contraction touches every
    slot's columns (masked rows contribute exact zeros, not NaN)."""

    def __init__(self, model, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        specs = model.lora_specs()
        self._stack = jax.tree.map(
            lambda s: jnp.zeros((s.shape[0], capacity) + s.shape[1:],
                                s.dtype), specs)
        self._host: Dict[str, Any] = {}
        self._version: Dict[str, int] = {}
        self._slot: Dict[str, int] = {}        # resident tenants only
        self._refs: Dict[str, int] = {}        # resident tenants only
        self._free: List[int] = list(range(capacity))
        # refcount-0 residents, oldest first (the LRU retained pool)
        self._lru: "collections.OrderedDict[str, int]" = \
            collections.OrderedDict()
        self.hits = 0
        self.loads = 0
        self.evictions = 0
        # shadow residency/refcount/version mirror, armed by
        # REPRO_SANITIZE=1 (None otherwise)
        self.san = adapter_sanitizer()

    # ---------------------------------------------------------- tenants --
    def register(self, adapter_id: str, tree: Any,
                 version: int = 0) -> None:
        """Add (or overwrite) a tenant's host-resident adapter tree."""
        if adapter_id in self._slot:
            raise AdapterError(
                f"{adapter_id}: already registered and resident — use "
                "update() to change a live tenant's weights")
        self._host[adapter_id] = tree
        self._version[adapter_id] = version
        if self.san is not None:
            self.san.on_register(adapter_id, version)

    def unregister(self, adapter_id: str) -> None:
        if self.refcount(adapter_id) > 0:
            raise AdapterError(
                f"{adapter_id}: unregister with {self.refcount(adapter_id)} "
                "in-flight refs")
        if self.san is not None:
            self.san.on_unregister(adapter_id)
        if adapter_id in self._slot:
            self._free.append(self._slot.pop(adapter_id))
            self._refs.pop(adapter_id, None)
            self._lru.pop(adapter_id, None)
        self._host.pop(adapter_id, None)
        self._version.pop(adapter_id, None)

    def is_registered(self, adapter_id: str) -> bool:
        return adapter_id in self._host

    def registered(self) -> List[str]:
        return sorted(self._host)

    def host_tree(self, adapter_id: str) -> Any:
        return self._host[adapter_id]

    def version(self, adapter_id: str) -> int:
        return self._version.get(adapter_id, 0)

    # -------------------------------------------------------- residency --
    def refcount(self, adapter_id: str) -> int:
        return self._refs.get(adapter_id, 0)

    def slot_index(self, adapter_id: str) -> int:
        """Device slot of a resident tenant, -1 otherwise."""
        return self._slot.get(adapter_id, -1)

    def resident_ids(self) -> tuple:
        return tuple(sorted(self._slot))

    def can_acquire(self, adapter_id: str) -> bool:
        if not self.is_registered(adapter_id):
            return False
        return adapter_id in self._slot or bool(self._free) \
            or bool(self._lru)

    def acquire(self, adapter_id: str) -> int:
        """Pin ``adapter_id``'s device slot (+1 ref), loading it from
        host on a miss — evicting the LRU cold tenant if no slot is
        free.  Raises ``OutOfAdapterSlots`` when every slot is pinned."""
        if not self.is_registered(adapter_id):
            raise AdapterError(f"{adapter_id}: not registered")
        slot = self._slot.get(adapter_id)
        if slot is not None:
            self.hits += 1
            self._lru.pop(adapter_id, None)
            self._refs[adapter_id] = self._refs.get(adapter_id, 0) + 1
            if self.san is not None:
                self.san.on_acquire(adapter_id)
            return slot
        if self._free:
            slot = self._free.pop()
        elif self._lru:
            cold, slot = self._lru.popitem(last=False)
            if self.san is not None:
                self.san.on_evict(cold)
            del self._slot[cold]
            self._refs.pop(cold, None)
            self.evictions += 1
        else:
            raise OutOfAdapterSlots(
                f"{adapter_id}: all {self.capacity} adapter slots are "
                "pinned by in-flight requests")
        self._stack = _write_adapter_slot(
            self._stack, self._host[adapter_id],
            jnp.asarray(slot, jnp.int32))
        self.loads += 1
        self._slot[adapter_id] = slot
        self._refs[adapter_id] = 1
        if self.san is not None:
            self.san.on_acquire(adapter_id)
        return slot

    def release(self, adapter_id: str) -> None:
        refs = self._refs.get(adapter_id, 0)
        if refs <= 0:
            raise AdapterError(f"{adapter_id}: release without acquire")
        if self.san is not None:
            self.san.on_release(adapter_id)
        refs -= 1
        self._refs[adapter_id] = refs
        if refs == 0:
            # stays resident (warm) until a miss needs the slot
            self._lru[adapter_id] = self._slot[adapter_id]

    def update(self, adapter_id: str, tree: Any,
               version: Optional[int] = None) -> None:
        """Swap a tenant's weights: host copy always, device slot in
        place when resident — the atomic publish under co-training
        (in-flight rows read the new weights on their next tick)."""
        if not self.is_registered(adapter_id):
            raise AdapterError(f"{adapter_id}: not registered")
        # registry-seam publish gate: refusing a non-finite tree here
        # keeps every resident slot servable even if a caller skipped
        # the LiveReplica-level gates
        from repro.runtime.replica import tree_finite
        if not tree_finite(tree):
            raise AdapterError(
                f"{adapter_id}: refusing non-finite adapter publish")
        if self.san is not None:
            self.san.begin_publish(adapter_id, version)
        self._host[adapter_id] = tree
        if version is not None:
            self._version[adapter_id] = version
        slot = self._slot.get(adapter_id)
        if slot is not None:
            self._stack = _write_adapter_slot(
                self._stack, tree, jnp.asarray(slot, jnp.int32))
        if self.san is not None:
            self.san.end_publish(adapter_id, version)

    def device_lora(self) -> Any:
        """The stacked device tree the segmented decode paths consume."""
        return self._stack


class ContinuousBatcher:
    """Fixed-slot continuous batching over one model replica.

    Owns the adapter + optimizer state so the fused combined path can
    donate/update them in place; ``LiveReplica`` delegates its adapter
    accessors here.  With ``paged=True`` it also owns the block
    allocator and per-slot block tables (see module docstring).

    Shadow-adapter double buffering: ``self.lora`` is the PUBLISHED
    snapshot — every prefill/decode reads it.  When ``self.train_lora``
    is set (a train session's shadow tree), the fused combined step
    trains THAT tree while decoding with the snapshot, so a whole round
    of optimizer updates never perturbs in-flight generation; greedy
    outputs stay bit-identical to serve-only until the owner swaps the
    shadow in (``LiveReplica.publish_adapter``) at a round boundary.
    With ``train_lora`` unset, training updates ``self.lora`` in place
    (the single-replica ``--combined`` behaviour, continuous
    adaptation per tick).

    Multi-tenant mode: pass an ``AdapterRegistry`` as ``adapters`` and
    route requests by ``GenRequest.adapter_id``.  Every prefill/decode
    then reads the registry's STACKED device tree with a per-row slot
    index (the segmented model paths), so one wave mixes tenants;
    admission pins each request's adapter (refcount+1, loading it on a
    miss) and eviction unpins it.  ``adapter_id=None`` rows serve the
    bare base model (slot -1).  The co-training pair is orthogonal:
    ``self.lora``/``train_lora`` stay the published/shadow trees of the
    co-train tenant, and the owner mirrors publishes into the registry
    (``LiveReplica.publish_adapter``).
    """

    def __init__(self, engine, params, lora, *, n_slots: int = 8,
                 max_seq: int = 128, prompt_pad: int = 32,
                 opt_state: Any = None, eos_id: Optional[int] = None,
                 paged: bool = False, block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 prefix_cache: bool = False,
                 attn_backend: Optional[str] = None,
                 adapters: Optional[AdapterRegistry] = None,
                 prefill_chunk: int = 0, tpot_target: float = 0.0,
                 oversubscribe: float = 0.0, swap: bool = True):
        cfg = engine.model.cfg
        if n_slots < 1:
            # run() makes progress only through slots; zero would spin
            # forever on a non-empty queue
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if not cfg.has_decode:
            raise NotImplementedError(
                f"{cfg.name}: encoder-only, no decode serving")
        if cfg.family.value == "vlm":
            raise NotImplementedError(
                f"{cfg.name}: VLM cross-KV slot plumbing (units-leading "
                "cache layout + per-request vision inputs) is a ROADMAP "
                "item; use the prefill/decode API directly")
        if cfg.sliding_window > 0 and prompt_pad > cfg.sliding_window:
            # ring handoff is sound as long as the whole prompt fits the
            # window: prefill K/V land in the ring verbatim and decode
            # wraps exactly like the seed's ring-buffer parity test
            raise ValueError(
                f"{cfg.name}: prompt_pad {prompt_pad} exceeds the "
                f"attention window {cfg.sliding_window}; windowed "
                "prompt eviction at admission is not implemented")
        if adapters is not None and cfg.has_ssm:
            raise NotImplementedError(
                f"{cfg.name}: multi-tenant adapter serving needs the "
                "ragged attention paths (SSM prefill is exact-length "
                "per request)")
        self.engine = engine
        self.model = engine.model
        self.cfg = cfg
        self.params = params
        self.lora = lora
        self.opt_state = opt_state
        self.adapters = adapters
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.prompt_pad = min(prompt_pad, max_seq)
        self.eos_id = eos_id
        # static decode-attention backend (None -> Pallas on TPU, jnp
        # elsewhere); the env override is read ONCE here, host-side, so
        # jitted programs cache per backend instead of per env state
        self.attn_backend = attn_backend \
            or os.environ.get("REPRO_DECODE_BACKEND") or None

        # logical cache length per slot: sliding-window archs ring-wrap
        # at the window, everyone else uses the full budget
        self.ring_len = min(max_seq, cfg.sliding_window) \
            if cfg.sliding_window > 0 else max_seq
        self.paged = paged
        if paged:
            if cfg.has_ssm or not cfg.has_attention:
                raise NotImplementedError(
                    f"{cfg.name}: paged KV serving needs an "
                    "attention-only stack (SSM/conv state is per-slot, "
                    "not per-block)")
            self.block_size = block_size
            self.blocks_per_slot = blocks_for(self.ring_len, block_size)
            if n_blocks is None:
                # full worst case + scratch block 0: paged-but-safe
                # default; callers shrink it to realize memory savings
                n_blocks = 1 + n_slots * self.blocks_per_slot
            if n_blocks < 1 + self.blocks_per_slot:
                raise ValueError(
                    f"n_blocks {n_blocks} cannot cover one worst-case "
                    f"request ({self.blocks_per_slot} blocks + scratch); "
                    "admission would deadlock")
            self.n_blocks = n_blocks
            self.allocator = BlockAllocator(n_blocks, block_size)
            # copy-on-write prefix sharing: identical block-aligned
            # prompt prefixes alias pool blocks at refcount+1 and skip
            # their prefill compute (see module docstring)
            if prefix_cache:
                from repro.models.transformer import use_dense_prefill
                if not use_dense_prefill(cfg, self.prompt_pad):
                    raise NotImplementedError(
                        f"{cfg.name}: prefix sharing needs the dense "
                        "prefill path — suffix prefill mirrors its "
                        "softmax formulation bit-for-bit, while "
                        "blockwise/unrolled prefill accumulates online "
                        "and would break cache-on/off greedy identity")
            self.prefix_cache = PrefixCache(self.allocator) \
                if prefix_cache else None
            self.caches = self.model.init_paged_caches(n_blocks,
                                                       block_size)
            # all-zero rows park inactive slots on scratch block 0
            self.block_tables = np.zeros((n_slots, self.blocks_per_slot),
                                         np.int32)
            self.slot_blocks: List[List[int]] = [[] for _ in
                                                 range(n_slots)]
            # worst-case blocks still reserved (not yet taken) per slot
            self.slot_reserved = np.zeros(n_slots, np.int32)
            # device copy of the live table slice, refreshed only when
            # tables actually change (admission/growth/eviction) — most
            # ticks reuse it instead of re-uploading
            self._dev_tables: Optional[jax.Array] = None
            self._dev_tables_width = 0
        else:
            if prefix_cache:
                raise ValueError(
                    "prefix_cache requires paged=True (sharing rides "
                    "on pool block aliasing)")
            self.prefix_cache = None
            self.caches = self.model.init_caches(n_slots, max_seq)
        # --------------------------------------- oversubscribed pool --
        # oversubscribe = w (0 < w <= 1): admission reserves only
        # near-term need against a w-fraction watermark of the pool;
        # mid-decode exhaustion preempts victims (swap-out to host or
        # drop + re-prefill).  0 keeps the preemption-free default.
        self.oversubscribe = float(oversubscribe)
        self.swap = bool(swap)
        if self.oversubscribe > 0:
            if not paged:
                raise ValueError(
                    "oversubscribe requires paged=True (preemption "
                    "moves pool blocks, not contiguous slot stripes)")
            if not (0 < self.oversubscribe <= 1):
                raise ValueError(
                    f"oversubscribe must be in (0, 1], got "
                    f"{self.oversubscribe}")
            if cfg.sliding_window > 0:
                raise NotImplementedError(
                    f"{cfg.name}: oversubscribed preemption needs full "
                    "attention — a sliding-window ring wrap overwrites "
                    "cache rows in place, so a dropped request cannot "
                    "be re-prefilled into an equivalent state")
            from repro.models.transformer import use_dense_prefill
            if not use_dense_prefill(cfg, self.prompt_pad):
                raise NotImplementedError(
                    f"{cfg.name}: drop-restore re-prefill rides the "
                    "suffix-continuation programs, which mirror the "
                    "dense prefill path bit-for-bit")
            # (1 - w) * capacity blocks stay unreservable at admission:
            # headroom for decode growth and swap-in restores
            self._headroom_blocks = self.allocator.capacity \
                - int(self.oversubscribe * self.allocator.capacity)
            self.swap_cost: Optional[_SwapCost] = _SwapCost()
        else:
            self._headroom_blocks = 0
            self.swap_cost = None
        # preempted requests parked off their slots, restored (swap-in
        # or re-prefill) ahead of admission in deadline-slack order
        self._swapped: List[_Swapped] = []
        # ------------------------------------------- chunked prefill --
        # prefill_chunk > 0: prompts prefill in fixed token-budget
        # chunks across successive ticks (chunk K attends over chunks
        # 1..K-1's K/V via the suffix/continuation programs), so
        # partially-prefilled slots coexist with decoding slots
        self.prefill_chunk = int(prefill_chunk)
        if self.prefill_chunk > 0:
            if cfg.has_ssm or not cfg.has_attention:
                raise NotImplementedError(
                    f"{cfg.name}: chunked prefill needs an "
                    "attention-only stack (SSM state threads through "
                    "every token in order)")
            from repro.models.transformer import use_dense_prefill
            if not use_dense_prefill(cfg, self.prompt_pad):
                raise NotImplementedError(
                    f"{cfg.name}: chunked prefill needs the dense "
                    "prefill path — the continuation programs mirror "
                    "its softmax formulation bit-for-bit, while "
                    "blockwise/unrolled prefill accumulates online and "
                    "would break chunked-vs-monolithic greedy identity")
            if paged:
                # chunk boundaries must stay block-aligned mid-prefill:
                # write_prefill_blocks scatters whole blocks, so round
                # the chunk up to a block multiple (only a prompt's
                # FINAL chunk may be ragged)
                self.prefill_chunk = self.block_size * blocks_for(
                    self.prefill_chunk, self.block_size)
        # chunk width of one _advance_prefill wave: the chunking knob
        # when set; otherwise (oversubscribed drop-restores still
        # re-prefill through _advance_prefill) a block-aligned
        # prompt_pad so one restore chunk covers a typical prompt
        if self.prefill_chunk > 0:
            self._prefill_pad = self.prefill_chunk
        elif paged:
            self._prefill_pad = self.block_size * blocks_for(
                self.prompt_pad, self.block_size)
        else:
            self._prefill_pad = self.prompt_pad
        self.tpot_target = float(tpot_target)
        self.budget = _TickBudget(self.tpot_target) \
            if self.tpot_target > 0 else None
        # per-slot prefill progress: prompt tokens already in cache
        # (== len(prompt) once the slot is decoding) and how many of
        # those were prefix-cache hits rather than computed chunks
        self.slot_prefilled = np.zeros(n_slots, np.int32)
        self.slot_cached = np.zeros(n_slots, np.int32)
        # prefill goal per slot: len(prompt) normally; a drop-restore
        # re-prefills prompt + already-generated tokens, so its goal is
        # the restore sequence length (slot_seq overrides the token
        # source, slot_restore_tok re-installs the decode frontier
        # token on the final chunk instead of sampling a new one)
        self.slot_goal = np.zeros(n_slots, np.int32)
        self.slot_seq: List[Optional[np.ndarray]] = [None] * n_slots
        self.slot_restore_tok = np.full(n_slots, -1, np.int32)
        # what the latest step() actually trained (the token-budget
        # scheduler may shrink or skip a tick's microbatch) — the
        # replica's session bookkeeping reads these instead of assuming
        # one full train step per tick
        self.last_tick_trained = False
        self.last_tick_train_rows = 0
        self.queue: Deque[GenRequest] = collections.deque()
        self.slot_req: List[Optional[GenRequest]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)   # next write position
        self.slot_tok = np.zeros(n_slots, np.int32)   # next token to feed
        # registry mode: the adapter id each slot's request pinned at
        # admission (None = base-only row, decode slot index -1)
        self.slot_aid: List[Optional[str]] = [None] * n_slots
        # request-lifecycle FSM shadow, armed by REPRO_SANITIZE=1
        # (None otherwise — hooks cost one is-not-None test)
        self._lsan = lifecycle_sanitizer()
        self.stats = ServeStats()
        self.train_losses: List[float] = []
        # shadow adapter for double-buffered train sessions (None = train
        # self.lora in place) + the microbatch split the session wants
        self.train_lora: Optional[Any] = None
        self.train_grad_accum: int = 1
        # host copies of the latest train step's scalar metrics (ce_loss,
        # micro_grad_sqnorm, grad_sqnorm) — the noise-scale estimator's
        # inputs
        self.last_train_metrics: Dict[str, float] = {}

        jits = _engine_jits(engine)
        self._jit_decode = jits["decode"]
        self._jit_decode_paged = jits["decode_paged"]
        self._jit_prefill_ragged = jits["prefill_ragged"]
        self._jit_prefill_exact = jits["prefill_exact"]
        self._jit_write = jits["write"]
        self._jit_write_slots = jits["write_slots"]
        self._jit_write_blocks = jits["write_blocks"]
        self._jit_prefill_suffix = jits["prefill_suffix"]
        self._jit_prefill_continue = jits["prefill_continue"]
        self._jit_write_rows = jits["write_rows"]
        self._jit_copy_blocks = jits["copy_blocks"]
        self._jit_gather_blocks = jits["gather_blocks"]
        self._jit_scatter_blocks = jits["scatter_blocks"]
        self._jit_combined = jits["combined"]
        self._jit_combined_paged = jits["combined_paged"]
        self._jit_train = jits["train"]

    # ------------------------------------------------------------ ingestion -
    def submit(self, req: GenRequest) -> None:
        req.prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        assert len(req.prompt) <= self.prompt_pad, \
            f"prompt len {len(req.prompt)} > prompt_pad {self.prompt_pad}"
        if req.adapter_id is not None:
            if self.adapters is None:
                raise AdapterError(
                    f"request {req.request_id} names adapter "
                    f"{req.adapter_id!r} but this batcher has no "
                    "AdapterRegistry")
            if not self.adapters.is_registered(req.adapter_id):
                raise AdapterError(
                    f"request {req.request_id}: adapter "
                    f"{req.adapter_id!r} is not registered")
        # a slot holds prompt + generation; clamp so writes stay in-pool
        budget = self.max_seq - len(req.prompt)
        req.max_new_tokens = max(1, min(req.max_new_tokens, budget))
        if self._lsan is not None:
            self._lsan.on_submit(req)
        self.queue.append(req)

    def active_slots(self) -> List[int]:
        return [i for i in range(self.n_slots)
                if self.slot_req[i] is not None]

    def _is_prefilling(self, i: int) -> bool:
        """Slot ``i`` holds a request whose prefill goal (prompt, or
        prompt + generated tokens for a drop-restore) is not fully in
        cache yet — parked out of the decode wave."""
        req = self.slot_req[i]
        return req is not None \
            and int(self.slot_prefilled[i]) < int(self.slot_goal[i])

    def _slot_seq(self, i: int) -> np.ndarray:
        """The token sequence slot ``i``'s prefill consumes: the
        request's prompt, unless a drop-restore installed a longer
        restore sequence (prompt + already-generated tokens)."""
        seq = self.slot_seq[i]
        return seq if seq is not None else self.slot_req[i].prompt

    def decoding_slots(self) -> List[int]:
        return [i for i in self.active_slots()
                if not self._is_prefilling(i)]

    def prefilling_slots(self) -> List[int]:
        return [i for i in self.active_slots() if self._is_prefilling(i)]

    def idle(self) -> bool:
        return not self.queue and not self.active_slots() \
            and not self._swapped

    @property
    def n_preempted(self) -> int:
        """Requests currently parked off-device by preemption (swap or
        drop) — the replica's thrashing signal for the dispatcher."""
        return len(self._swapped)

    # ------------------------------------------------------------ admission -
    def _worst_blocks(self, req: GenRequest) -> int:
        """Worst-case block count over the request's lifetime: prompt
        plus ``max_new_tokens - 1`` decode writes (the last sampled
        token is never fed back), capped by the ring length.  Under
        prefix sharing, full-attention requests reserve only the
        non-matched remainder (aliased blocks are already-used pool
        capacity); sliding-window requests reserve the full worst case
        because a ring wrap may copy-on-write every aliased block."""
        tokens = min(len(req.prompt) + req.max_new_tokens - 1,
                     self.ring_len)
        return blocks_for(tokens, self.block_size)

    # ---------------------------------------------------- adapter routing --
    def _serve_lora(self) -> Any:
        """The tree every prefill/decode reads: the registry's stacked
        device tree in multi-tenant mode, the single published adapter
        otherwise."""
        return self.adapters.device_lora() if self.adapters is not None \
            else self.lora

    def _wave_adapter_idx(self, reqs: List[GenRequest]):
        """Per-row registry slots for a prefill wave (requests were
        pinned at admission, so slots are stable); None without a
        registry."""
        if self.adapters is None:
            return None
        return jnp.asarray(
            [self.adapters.slot_index(r.adapter_id)
             if r.adapter_id is not None else -1 for r in reqs],
            jnp.int32)

    def _record_finish(self, req: GenRequest, now: float) -> None:
        if self._lsan is not None:
            self._lsan.on_finish(req)
        req.finished_at = now
        req.finished_wall = time.perf_counter()
        self.stats.finished += 1
        first = req.first_token_at if req.first_token_at is not None \
            else req.prefill_at
        if first is not None:
            self.stats.ttft.append(max(first - req.arrival, 0.0))
            if len(req.tokens) > 1:
                self.stats.tpot.append(
                    max(now - first, 0.0) / (len(req.tokens) - 1))
        if req.adapter_id is not None:
            self.stats.adapter_requests[req.adapter_id] = \
                self.stats.adapter_requests.get(req.adapter_id, 0) + 1
            if self.adapters is not None:
                self.stats.adapter_versions[req.adapter_id] = \
                    self.adapters.version(req.adapter_id)

    def _prefill_wave(self, reqs: List[GenRequest],
                      plans: Optional[List] = None):
        """Prefill an admission wave; returns (first_tokens [W] np,
        [(prefill_caches, src_row)]).  Attention stacks: ONE ragged
        (right-padded) prefill program for the whole wave and ONE
        batched argmax sync for the wave's first tokens.  SSM/hybrid:
        state threads through pads, so exact-length per-request prefill
        (one compile per distinct prompt length).  With prefix-cache
        hits in the wave (``plans`` rows carry matched block chains),
        ONE suffix program computes only each row's uncached tokens,
        attending over the cached prefix K/V gathered from the pool."""
        if self.cfg.has_ssm:
            outs = [self._jit_prefill_exact(
                self.params, self.lora,
                {"tokens": jnp.asarray(r.prompt[None])}) for r in reqs]
            last = [logits[0, -1] for logits, _ in outs]
            # stack the wave's last-position logits on device so the
            # wave costs ONE argmax transfer, not one per request
            firsts = np.asarray(  # lint: host-sync-ok one batched argmax pull per prefill wave
                jnp.argmax(jnp.stack(last), axis=-1), np.int32)
            return firsts, [(pre, 0) for _, pre in outs], last
        lens = np.array([len(r.prompt) for r in reqs], np.int32)
        matched = [m for m, _ in plans] if plans else [[] for _ in reqs]
        if any(matched):
            bs = self.block_size
            pre_lens = np.array([len(m) * bs for m in matched], np.int32)
            suf_lens = lens - pre_lens
            # suffix width bucketed to block multiples, prefix width to
            # a power of two over the wave max (extra columns are
            # scratch-padded and masked): a handful of jit variants,
            # not one per distinct matched-chain length
            suf_pad = bs * blocks_for(int(suf_lens.max()), bs)
            npre = max(len(m) for m in matched)
            npre = min(1 << (npre - 1).bit_length(),
                       blocks_for(self.prompt_pad, bs))
            padded = np.zeros((len(reqs), suf_pad), np.int32)
            # scratch block 0 pads unmatched rows; their lanes are
            # masked by pre_lens inside the program
            pre_tables = np.zeros((len(reqs), npre), np.int32)
            for j, r in enumerate(reqs):
                padded[j, :suf_lens[j]] = r.prompt[pre_lens[j]:]
                pre_tables[j, :len(matched[j])] = matched[j]
            logits, pre = self._jit_prefill_suffix(
                self.params, self._serve_lora(),
                {"tokens": jnp.asarray(padded)},
                jnp.asarray(suf_lens), jnp.asarray(pre_lens),
                self.caches, jnp.asarray(pre_tables),
                self._wave_adapter_idx(reqs))
            firsts = np.asarray(  # lint: host-sync-ok one batched argmax pull per prefill wave
                jnp.argmax(logits[:, -1], axis=-1), np.int32)
            return firsts, [(pre, j) for j in range(len(reqs))], \
                logits[:, -1]
        padded = np.zeros((len(reqs), self.prompt_pad), np.int32)
        for j, r in enumerate(reqs):
            padded[j, :lens[j]] = r.prompt
        logits, pre = self._jit_prefill_ragged(
            self.params, self._serve_lora(),
            {"tokens": jnp.asarray(padded)}, jnp.asarray(lens),
            adapter_idx=self._wave_adapter_idx(reqs))
        firsts = np.asarray(  # lint: host-sync-ok one batched argmax pull per prefill wave
            jnp.argmax(logits[:, -1], axis=-1), np.int32)
        return firsts, [(pre, j) for j in range(len(reqs))], logits[:, -1]

    def admit(self, now: float = 0.0) -> List[GenRequest]:
        """Fill free slots from the queue; returns requests that finished
        at admission (max_new_tokens == 1 / instant EOS).  Paged mode
        admits FCFS only while the allocator can cover the head
        request's worst case — otherwise the queue waits for an
        eviction (preemption-free backpressure).  With the prefix cache
        on, the head request's longest cached block-aligned prefix is
        aliased at refcount+1 (reviving retained blocks as needed),
        only the uncached suffix is prefilled, and the request's
        newly written full prompt blocks are registered for the next
        admission."""
        finished: List[GenRequest] = []
        free = [i for i in range(self.n_slots)
                if self.slot_req[i] is None]
        reqs: List[GenRequest] = []
        # per admitted request: (matched block chain, blocks reserved)
        plans: List = []
        picked: List[int] = []      # queue indices claimed this wave
        idx = 0
        while len(reqs) < len(free) and idx < len(self.queue):
            head = self.queue[idx]
            if self.adapters is not None and head.adapter_id is not None \
                    and not self.adapters.can_acquire(head.adapter_id):
                # every slot of THIS tenant's adapter is pinned by
                # in-flight requests — skip past it within the arrival
                # wave (it keeps its queue position for the next one)
                # instead of head-of-line blocking the whole FCFS scan
                idx += 1
                continue
            if self.paged:
                matched = self.prefix_cache.match(
                    head.prompt, namespace=head.adapter_id) \
                    if self.prefix_cache is not None else []
                worst = self._worst_blocks(head)

                # sliding windows wrap decode writes back into prompt
                # blocks, so every aliased block may need a COW block;
                # full attention never writes an aliased block.  Over-
                # subscribed admission reserves only near-term need —
                # the prompt's uncached blocks plus a one-block decode
                # lookahead; growth past that is _ensure_headroom's
                # job (reserve-or-preempt at the block boundary).
                def need_for(m):
                    full = worst if self.cfg.sliding_window > 0 \
                        else worst - len(m)
                    if self.oversubscribe <= 0:
                        return full
                    near = blocks_for(
                        len(head.prompt) - len(m) * self.block_size,
                        self.block_size) + 1
                    return min(full, near)

                # a match can be too expensive to honor: reviving
                # retained blocks costs pool capacity ON TOP of the
                # worst-case reservation under sliding windows.  Trim
                # the aliased prefix until it fits — a cold admission
                # (no match) always fits one worst-case request, so
                # warm hits can never deadlock an idle pool.  The
                # oversubscription watermark holds (1 - w) * capacity
                # out of admission's reach so growth and swap-in
                # restores always find headroom (0 when off).
                while matched and self.allocator.available() \
                        < need_for(matched) \
                        + self.allocator.n_would_revive(matched) \
                        + self._headroom_blocks:
                    matched.pop()
                need = need_for(matched)
                if self.allocator.available() \
                        < need + self.allocator.n_would_revive(matched) \
                        + self._headroom_blocks:
                    # pool backpressure stays strict FCFS: nothing
                    # behind the head may jump an exhausted pool
                    break
                self.allocator.acquire(matched)
                self.allocator.reserve(need)
                if self.prefix_cache is not None:
                    self.prefix_cache.count_admitted(
                        head.prompt, len(matched),
                        namespace=head.adapter_id)
                plans.append((matched, need))
            if self._lsan is not None:
                self._lsan.on_admit(head)
            if self.adapters is not None and head.adapter_id is not None:
                # pin the tenant's device slot for the request lifetime
                # (loads from host on a miss; can_acquire gated above)
                self.adapters.acquire(head.adapter_id)
            reqs.append(head)
            picked.append(idx)
            idx += 1
        for j in reversed(picked):
            del self.queue[j]
        if not reqs:
            return finished
        if self.prefill_chunk > 0:
            # chunked mode only ASSIGNS slots here; chunk 1 (and every
            # continuation) runs through _advance_prefill under the
            # tick's token budget
            self._assign_chunked(free, reqs, plans, now)
            return finished
        firsts, entries, last_logits = self._prefill_wave(
            reqs, plans if self.paged else None)
        # one batched scatter per wave on the ragged-attention paths;
        # rows flagged with an out-of-range id are dropped (requests
        # that finished at admission)
        batched = not self.cfg.has_ssm
        wave_pre = entries[0][0] if batched else None
        if self.paged:
            # wave table width follows the prefill width: full prompts
            # on a cold wave, just the suffix when prefixes were cached
            nbp = blocks_for(wave_pre["kv"][0].shape[2], self.block_size)
            wave_tables = np.full((len(reqs), nbp), self.n_blocks,
                                  np.int32)
        elif batched:
            wave_slots = np.full(len(reqs), self.n_slots, np.int32)
        admitted_rows = 0
        for k, (slot, req, first, (pre_caches, src)) in enumerate(zip(
                free, reqs, firsts, entries)):
            first = int(first)
            if req.samples:
                # the wave's k-th logits row belongs to the k-th request
                # on every prefill path (SSM stacks per request)
                req.rng = np.random.default_rng(
                    req.seed if req.seed is not None else req.request_id)
                first = sample_token(
                    np.asarray(last_logits[k]),
                    temperature=req.temperature, top_k=req.top_k,
                    top_p=req.top_p, rng=req.rng)
            matched, reserved = plans[k] if self.paged else ([], 0)
            n_cached = len(matched) * (self.block_size if self.paged
                                       else 0)
            req.tokens.append(first)
            req.prefill_at = now
            req.first_token_at = now
            self.stats.admitted += 1
            self.stats.prefill_tokens += len(req.prompt) - n_cached
            self.stats.cached_prefix_tokens += n_cached
            self.stats.generated_tokens += 1
            if len(req.tokens) >= req.max_new_tokens \
                    or first == self.eos_id:
                # done at admission: never occupies the slot, so skip
                # the cache write entirely and drop the aliased prefix
                self._record_finish(req, now)
                if self.adapters is not None \
                        and req.adapter_id is not None:
                    self.adapters.release(req.adapter_id)
                if self.paged:
                    self.allocator.release(reserved)
                    if matched:
                        self.allocator.free(matched)
                finished.append(req)
                continue
            if self.paged:
                need = blocks_for(len(req.prompt) - n_cached,
                                  self.block_size)
                ids = self.allocator.take(need)
                self.slot_blocks[slot] = list(matched) + ids
                self.slot_reserved[slot] = reserved - need
                self.block_tables[slot, :] = 0
                self.block_tables[slot, :len(matched) + need] = \
                    self.slot_blocks[slot]
                wave_tables[src, :need] = ids
                # register the freshly written full prompt blocks —
                # except for a request whose decode will ring-wrap back
                # into them: those blocks are doomed to be overwritten
                # mid-flight, and an owner forced to COW its own
                # registered blocks would outrun its reservation
                wraps = len(req.prompt) + req.max_new_tokens - 1 \
                    > self.ring_len
                if self.prefix_cache is not None and not wraps:
                    self.prefix_cache.register(
                        req.prompt, self.slot_blocks[slot], len(matched),
                        namespace=req.adapter_id)
                self._dev_tables = None
            elif batched:
                wave_slots[src] = slot
            else:
                self.caches = self._jit_write(self.caches, pre_caches,
                                              slot, src)
            admitted_rows += 1
            self.slot_req[slot] = req
            self.slot_aid[slot] = req.adapter_id
            self.slot_pos[slot] = len(req.prompt)
            self.slot_tok[slot] = first
            self.slot_prefilled[slot] = len(req.prompt)
            self.slot_goal[slot] = len(req.prompt)
            self.slot_cached[slot] = n_cached
        if admitted_rows and self.paged:
            self.caches = self._jit_write_blocks(
                self.caches, wave_pre, jnp.asarray(wave_tables))
        elif admitted_rows and batched:
            self.caches = self._jit_write_slots(
                self.caches, wave_pre, jnp.asarray(wave_slots))
        return finished

    # ------------------------------------------------- chunked prefill -
    def _assign_chunked(self, free: List[int], reqs: List[GenRequest],
                        plans: List, now: float) -> None:
        """Chunked admission: bind each selected request to a slot in
        PREFILLING state (no prefill program runs here).  The slot is
        parked out of the decode wave — ``slot_prefilled < len(prompt)``
        — until ``_advance_prefill`` lands its final chunk."""
        for k, (slot, req) in enumerate(zip(free, reqs)):
            matched, reserved = plans[k] if self.paged else ([], 0)
            n_cached = len(matched) * (self.block_size if self.paged
                                       else 0)
            req.prefill_at = now
            self.stats.admitted += 1
            self.stats.cached_prefix_tokens += n_cached
            self.slot_req[slot] = req
            self.slot_aid[slot] = req.adapter_id
            self.slot_prefilled[slot] = n_cached
            self.slot_goal[slot] = len(req.prompt)
            self.slot_cached[slot] = n_cached
            # parked: the decode wave's write for this row is garbage
            # aimed at position ``slot_prefilled`` (contiguous — the
            # next chunk overwrites it before it can be attended) or at
            # scratch block 0 (paged — the dev-table row is zeroed)
            self.slot_pos[slot] = n_cached
            self.slot_tok[slot] = 0
            if self.paged:
                self.slot_blocks[slot] = list(matched)
                self.slot_reserved[slot] = reserved
                self.block_tables[slot, :] = 0
                self.block_tables[slot, :len(matched)] = matched
                self._dev_tables = None

    def _advance_prefill(self, now: float, allowance: float):
        """Spend up to ``allowance`` prefill tokens on the most urgent
        partially-prefilled slots (deadline-slack order), one chunk per
        slot, as ONE wave program + ONE batched cache write.  A slot
        whose final chunk lands gets its first token from the wave's
        logits and joins the decode wave this same tick.  Returns
        (requests finished at prefill completion, measured seconds)."""
        done: List[GenRequest] = []
        pref = self.prefilling_slots()
        if not pref or allowance <= 0:
            return done, 0.0
        order = slack_order(pref, now,
                            key=lambda i: self.slot_req[i].deadline)
        rows: List = []             # (slot, chunk_len)
        used = 0
        for i in order:
            c = min(int(self.slot_goal[i]) - int(self.slot_prefilled[i]),
                    self._prefill_pad)
            if rows and used + c > allowance:
                break               # first chunk always makes progress
            rows.append((i, c))
            used += c
            if used >= allowance:
                break
        t0 = time.perf_counter()
        w = len(rows)
        slots_arr = [i for i, _ in rows]
        slots_np = np.asarray(slots_arr, np.int32)
        wave_reqs = [self.slot_req[i] for i in slots_arr]
        chunk_lens = np.array([c for _, c in rows], np.int32)
        pre_lens = self.slot_prefilled[slots_np]    # host counters
        pad = self._prefill_pad
        tokens = np.zeros((w, pad), np.int32)
        for j, (i, c) in enumerate(rows):
            p = int(self.slot_prefilled[i])
            tokens[j, :c] = self._slot_seq(i)[p:p + c]
        if self.paged:
            bs = self.block_size
            # prefix tables: each slot's blocks so far, width bucketed
            # to a power of two (extra lanes are scratch, masked by
            # pre_lens inside the program)
            npre = max(max(len(self.slot_blocks[i])
                           for i in slots_arr), 1)
            npre = min(1 << (npre - 1).bit_length(),
                       self.blocks_per_slot)
            pre_tables = np.zeros((w, npre), np.int32)
            for j, i in enumerate(slots_arr):
                blk = self.slot_blocks[i]
                pre_tables[j, :len(blk)] = blk
            logits, pre = self._jit_prefill_suffix(
                self.params, self._serve_lora(),
                {"tokens": jnp.asarray(tokens)},
                jnp.asarray(chunk_lens), jnp.asarray(pre_lens),
                self.caches, jnp.asarray(pre_tables),
                self._wave_adapter_idx(wave_reqs))
            # land the chunk in fresh blocks against each slot's
            # admission-time reservation (chunks are block-aligned, so
            # sum-over-chunks == the monolithic block count)
            nbp = blocks_for(pad, bs)
            wave_tables = np.full((w, nbp), self.n_blocks, np.int32)
            for j, (i, c) in enumerate(rows):
                need = blocks_for(c, bs)
                assert self.slot_reserved[i] >= need, \
                    f"slot {i}: chunk beyond admission reservation"
                ids = self.allocator.take(need)
                self.slot_reserved[i] -= need
                base = len(self.slot_blocks[i])
                self.slot_blocks[i].extend(ids)
                self.block_tables[i, base:base + need] = ids
                wave_tables[j, :need] = ids
            self._dev_tables = None
            self.caches = self._jit_write_blocks(
                self.caches, pre, jnp.asarray(wave_tables))
        else:
            logits, pre = self._jit_prefill_continue(
                self.params, self._serve_lora(),
                {"tokens": jnp.asarray(tokens)},
                jnp.asarray(chunk_lens), jnp.asarray(pre_lens),
                self.caches, jnp.asarray(slots_arr, dtype=jnp.int32),
                adapter_idx=self._wave_adapter_idx(wave_reqs))
            self.caches = self._jit_write_rows(
                self.caches, pre, slots_np, pre_lens, chunk_lens)
        final_rows = [j for j, (i, c) in enumerate(rows)
                      if int(self.slot_prefilled[i]) + c
                      >= int(self.slot_goal[i])]
        nxt = None
        host_rows = None
        if final_rows:
            nxt = np.asarray(  # lint: host-sync-ok one batched argmax pull per chunk wave
                jnp.argmax(logits[:, -1], axis=-1), np.int32)
            if any(wave_reqs[j].samples for j in final_rows):
                host_rows = np.asarray(logits[:, -1])  # lint: host-sync-ok one batched logits pull per sampling chunk wave
        for j, (i, c) in enumerate(rows):
            req = wave_reqs[j]
            p = int(self.slot_prefilled[i]) + c
            self.slot_prefilled[i] = p
            self.stats.prefill_tokens += c
            if p < int(self.slot_goal[i]):
                self.slot_pos[i] = p    # stay parked at the frontier
                continue
            if int(self.slot_restore_tok[i]) >= 0:
                # drop-restore final chunk: every generated token was
                # already emitted before preemption — re-install the
                # decode frontier (next position + stored feed token)
                # instead of sampling a new one
                self.slot_pos[i] = int(self.slot_goal[i])
                self.slot_tok[i] = int(self.slot_restore_tok[i])
                self.slot_restore_tok[i] = -1
                self.slot_seq[i] = None
                continue
            # final chunk: the wave's logits row IS the full prompt's
            # last-token logits (bit-identical to monolithic prefill)
            first = int(nxt[j])
            if req.samples:
                req.rng = np.random.default_rng(
                    req.seed if req.seed is not None else req.request_id)
                first = sample_token(
                    host_rows[j], temperature=req.temperature,
                    top_k=req.top_k, top_p=req.top_p, rng=req.rng)
            req.tokens.append(first)
            req.first_token_at = now
            self.stats.generated_tokens += 1
            if self.paged:
                wraps = len(req.prompt) + req.max_new_tokens - 1 \
                    > self.ring_len
                if self.prefix_cache is not None and not wraps:
                    self.prefix_cache.register(
                        req.prompt, self.slot_blocks[i],
                        int(self.slot_cached[i]) // self.block_size,
                        namespace=req.adapter_id)
            if len(req.tokens) >= req.max_new_tokens \
                    or first == self.eos_id:
                self._record_finish(req, now)
                self._evict(i)
                done.append(req)
                continue
            self.slot_pos[i] = len(req.prompt)
            self.slot_tok[i] = first
        dt = time.perf_counter() - t0
        if self.budget is not None:
            self.budget.observe_prefill(used, dt)
        if self.swap_cost is not None:
            self.swap_cost.observe_prefill(used, dt)
        return done, dt

    # ---------------------------------------------- preemption / swap -
    def _block_bytes(self) -> int:
        """Host bytes one pool block occupies across every cache leaf
        (the swap cost model's unit)."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(self.caches)) \
            // self.n_blocks

    def _pick_victim(self, protect: int, now: float) -> Optional[int]:
        """Victim slot for one preemption: among active slots that
        would actually return pool capacity (a sole-referenced block to
        free, or an unused reservation), the one with the MOST deadline
        slack — cost-to-restore (fewest live rows) breaks ties.
        ``slack_order`` puts the most urgent first, so the victim is
        the tail of the order."""
        cands = []
        for j in self.active_slots():
            if j == protect or self.slot_req[j] is None:
                continue
            gain = int(self.slot_reserved[j]) + sum(
                1 for b in self.slot_blocks[j]
                if self.allocator.ref(b) == 1)
            if gain > 0:
                cands.append(j)
        if not cands:
            return None
        # stable pre-sort by restore cost so slack ties resolve to the
        # cheapest victim once the most-slack tail is taken
        cands.sort(key=lambda j: -int(self.slot_pos[j]))
        order = slack_order(cands, now,
                            key=lambda j: self.slot_req[j].deadline)
        return order[-1]

    def _preempt(self, i: int, now: float) -> None:
        """Preempt slot ``i``: park its request off the device and
        return its private pool capacity.  The COW-shared /
        prefix-registered chain prefix stays pool-resident with our
        references held; the private tail either swaps to host (ONE
        batched device->host gather) or is dropped for re-prefill from
        the request's host-kept token ids, whichever the EMA cost model
        prices cheaper.  The pinned adapter reference is kept across
        the preemption so restore can never fail on adapter
        residency."""
        req = self.slot_req[i]
        chain = self.slot_blocks[i]
        bs = self.block_size

        def resident(b: int) -> bool:
            return self.allocator.ref(b) > 1 \
                or (self.prefix_cache is not None
                    and self.prefix_cache.is_registered(b))

        kept = 0
        while kept < len(chain) and resident(chain[kept]):
            kept += 1
        tail = chain[kept:]
        # under full attention resident blocks always form a chain
        # PREFIX (decode never writes shared/registered blocks and
        # registration covers full prompt blocks only) — but verify:
        # an interior resident block forces the drop path, whose
        # ``free`` handles shared and registered blocks correctly
        mode = "swap"
        if self._is_prefilling(i) or not tail \
                or any(resident(b) for b in tail) or not self.swap:
            mode = "reprefill"
        elif self.swap_cost is not None and not self.swap_cost.prefer_swap(
                len(tail) * self._block_bytes(),
                int(self.slot_pos[i]) - kept * bs):
            mode = "reprefill"
        if mode == "swap":
            t0 = time.perf_counter()
            width = 1 << max(len(tail) - 1, 0).bit_length()
            ids = np.zeros(width, np.int32)  # pads gather scratch rows
            ids[:len(tail)] = tail
            host = jax.device_get(  # lint: host-sync-ok one batched device->host block copy per swap-out
                self._jit_gather_blocks(self.caches, ids))
            hk, hv = host["kv"]
            host_kv = (hk[:, :len(tail)], hv[:, :len(tail)])
            self.allocator.swap_out(tail)
            if self.swap_cost is not None:
                self.swap_cost.observe_swap(
                    len(tail) * self._block_bytes(),
                    time.perf_counter() - t0)
            entry = _Swapped(
                req=req, adapter_id=self.slot_aid[i], mode="swap",
                kept=chain[:kept], host_kv=host_kv, n_tail=len(tail),
                pos=int(self.slot_pos[i]), tok=int(self.slot_tok[i]),
                cached=int(self.slot_cached[i]))
            self.stats.swap_out_blocks += len(tail)
        else:
            # drop the whole chain: shared blocks lose our alias,
            # registered sole-ref blocks park in the retained pool and
            # revive through the PrefixCache at restore
            if chain:
                self.allocator.free(chain)
            entry = _Swapped(
                req=req, adapter_id=self.slot_aid[i], mode="reprefill",
                kept=[], host_kv=None, n_tail=0,
                pos=int(self.slot_pos[i]), tok=int(self.slot_tok[i]),
                cached=0)
        self._swapped.append(entry)
        self.stats.preemptions += 1
        # clear the slot WITHOUT finishing the request (it stays ACTIVE
        # in the lifecycle FSM — restore is not a re-admission) and
        # WITHOUT releasing its adapter pin
        self.allocator.release(int(self.slot_reserved[i]))
        self.slot_reserved[i] = 0
        self.slot_req[i] = None
        self.slot_aid[i] = None
        self.slot_blocks[i] = []
        self.slot_pos[i] = 0
        self.slot_tok[i] = 0
        self.slot_prefilled[i] = 0
        self.slot_cached[i] = 0
        self.slot_goal[i] = 0
        self.slot_seq[i] = None
        self.slot_restore_tok[i] = -1
        self.block_tables[i, :] = 0
        self._dev_tables = None

    def _ensure_headroom(self, active: List[int],
                         now: float) -> List[int]:
        """Oversubscribed decode: every slot crossing a block boundary
        this tick must hold a reservation for the fresh block BEFORE
        ``_grow_tables`` takes it.  On pool exhaustion, preempt victims
        (most deadline slack first) until the reservation fits; as a
        last resort the needy slot preempts itself.  Returns the active
        set minus any preempted slots."""
        active = list(active)
        for i in list(active):
            if self.slot_req[i] is None or i not in active:
                continue
            wr = int(self.slot_pos[i]) % self.ring_len
            if wr // self.block_size < len(self.slot_blocks[i]) \
                    or int(self.slot_reserved[i]) > 0:
                continue
            while not self.allocator.can_reserve(1):
                victim = self._pick_victim(protect=i, now=now)
                if victim is None:
                    victim = i   # last resort: the needy slot itself
                self._preempt(victim, now)
                if victim in active:
                    active.remove(victim)
                if victim == i:
                    break
            if self.slot_req[i] is not None:
                self.allocator.reserve(1)
                self.slot_reserved[i] += 1
        return active

    def _demote(self, e: _Swapped) -> None:
        """Give up a parked entry's remaining pool footprint: drop the
        kept-chain references (registered blocks park retained, shared
        ones lose our alias) and discard any host KV — the entry will
        restore through the reprefill path instead."""
        if e.kept:
            self.allocator.free(e.kept)
            e.kept = []
        e.host_kv = None
        e.n_tail = 0
        e.mode = "reprefill"
        e.cached = 0

    def _demote_one(self, prefer_not: int) -> bool:
        """Demote one demotable parked entry (preferring any entry but
        ``prefer_not``, which is the one being forced in).  False when
        nothing is left to demote."""
        cand = None
        for k, e in enumerate(self._swapped):
            if e.mode == "swap" or e.kept:
                if k != prefer_not:
                    cand = k
                elif cand is None:
                    cand = k
        if cand is None:
            return False
        self._demote(self._swapped[cand])
        return True

    def _try_restore(self, e: _Swapped, slot: int, now: float) -> bool:
        """Re-enter one parked request into free slot ``slot``.  Swap
        mode: fresh blocks + ONE batched host->device scatter; decode
        resumes exactly where it stopped.  Reprefill mode: back into
        PREFILLING state over prompt + generated tokens (the suffix
        programs recompute the dropped KV bit-identically; the final
        chunk re-installs the stored frontier token).  Returns False —
        with no side effects — when the pool cannot cover it yet."""
        req = e.req
        if e.mode == "swap":
            if not self.allocator.can_reserve(e.n_tail):
                return False
            ids = self.allocator.swap_in(e.n_tail)
            width = 1 << max(e.n_tail - 1, 0).bit_length()
            pad_ids = np.full(width, self.n_blocks, np.int32)
            pad_ids[:e.n_tail] = ids     # pads are dropped (mode=drop)
            hk, hv = e.host_kv
            if width != e.n_tail:
                zk = np.zeros(hk.shape[:1] + (width,) + hk.shape[2:],
                              hk.dtype)
                zv = np.zeros(hv.shape[:1] + (width,) + hv.shape[2:],
                              hv.dtype)
                zk[:, :e.n_tail] = hk
                zv[:, :e.n_tail] = hv
                hk, hv = zk, zv
            self.caches = self._jit_scatter_blocks(
                self.caches, pad_ids, (hk, hv))
            self.slot_blocks[slot] = list(e.kept) + ids
            self.slot_reserved[slot] = 0
            self.slot_prefilled[slot] = len(req.prompt)
            self.slot_goal[slot] = len(req.prompt)
            self.slot_cached[slot] = e.cached
            self.slot_pos[slot] = e.pos
            self.slot_tok[slot] = e.tok
            self.slot_seq[slot] = None
            self.slot_restore_tok[slot] = -1
            self.stats.swap_in_blocks += e.n_tail
        else:
            # drop-restore: re-prefill prompt + all generated tokens
            # but the last, whose KV is never needed (it is the next
            # token to FEED) — slot_restore_tok re-installs it
            seq = req.prompt if not req.tokens else np.concatenate(
                [req.prompt, np.asarray(req.tokens[:-1], np.int32)])
            matched = self.prefix_cache.match(
                req.prompt, namespace=e.adapter_id) \
                if self.prefix_cache is not None else []
            bs = self.block_size
            worst = self._worst_blocks(req)

            def need_for(m):
                return min(worst - len(m),
                           blocks_for(len(seq) - len(m) * bs, bs) + 1)

            while matched and self.allocator.available() \
                    < need_for(matched) \
                    + self.allocator.n_would_revive(matched):
                matched.pop()
            need = need_for(matched)
            if self.allocator.available() \
                    < need + self.allocator.n_would_revive(matched):
                return False
            self.allocator.acquire(matched)
            self.allocator.reserve(need)
            n_cached = len(matched) * bs
            self.slot_blocks[slot] = list(matched)
            self.slot_reserved[slot] = need
            self.slot_prefilled[slot] = n_cached
            self.slot_goal[slot] = len(seq)
            self.slot_cached[slot] = n_cached
            self.slot_pos[slot] = n_cached
            self.slot_tok[slot] = 0
            self.slot_seq[slot] = seq if req.tokens else None
            self.slot_restore_tok[slot] = req.tokens[-1] \
                if req.tokens else -1
            self.stats.reprefill_tokens += len(seq) - n_cached
        self.slot_req[slot] = req
        self.slot_aid[slot] = e.adapter_id
        self.block_tables[slot, :] = 0
        blks = self.slot_blocks[slot]
        self.block_tables[slot, :len(blks)] = blks
        self._dev_tables = None
        return True

    def _restore(self, now: float) -> None:
        """Bring preempted requests back into free slots ahead of
        admission, most urgent (smallest deadline slack) first; entries
        the pool cannot cover yet stay parked.  If NOTHING else can run
        — no active slot, and the queue is empty or its head cannot be
        admitted either — capacity is forcibly reclaimed from the other
        parked entries' kept chains (demotion to reprefill) so the most
        urgent restore always goes through: the batcher can never
        livelock on its own parked work."""
        free = [i for i in range(self.n_slots)
                if self.slot_req[i] is None]
        if not free:
            return
        order = slack_order(
            list(range(len(self._swapped))), now,
            key=lambda k: self._swapped[k].req.deadline)
        restored: set = set()
        for k in order:
            if not free:
                break
            if self._try_restore(self._swapped[k], free[0], now):
                free.pop(0)
                restored.add(k)
        if not restored and free and not self.active_slots():
            blocked_queue = False
            if self.queue:
                # conservative cold-admission check (a prefix match
                # only shrinks the head's need, so "fits" is exact)
                head = self.queue[0]
                need = min(self._worst_blocks(head),
                           blocks_for(len(head.prompt),
                                      self.block_size) + 1)
                blocked_queue = self.allocator.available() \
                    < need + self._headroom_blocks
            if not self.queue or blocked_queue:
                k = order[0]
                while not self._try_restore(self._swapped[k], free[0],
                                            now):
                    if not self._demote_one(k):
                        break
                if self.slot_req[free[0]] is not None:
                    restored.add(k)
        if restored:
            self._swapped = [e for k, e in enumerate(self._swapped)
                             if k not in restored]

    # --------------------------------------------------------------- decode -
    def _grow_tables(self, active: List[int]) -> None:
        """Make the block each slot's next write lands in writable:
        allocate it if the table doesn't cover it yet (the 'grow one
        block at a time' step, always against the slot's admission-time
        reservation); under prefix sharing, a covered-but-shared block
        (refcount > 1 — a ring wrap re-entering an aliased prompt
        block) is copy-on-written to a private block first, and a
        registered refcount-1 block is unregistered from the prefix
        cache so its cached entry never goes stale in place."""
        cow_src: List[int] = []
        cow_dst: List[int] = []
        for i in active:
            wr = int(self.slot_pos[i]) % self.ring_len
            bidx = wr // self.block_size
            if bidx >= len(self.slot_blocks[i]):
                assert self.slot_reserved[i] > 0, \
                    f"slot {i}: growth beyond admission reservation"
                (bid,) = self.allocator.take(1)
                self.slot_reserved[i] -= 1
                self.slot_blocks[i].append(bid)
                self.block_tables[i, bidx] = bid
                self._dev_tables = None
            elif self.prefix_cache is not None:
                bid = self.slot_blocks[i][bidx]
                if self.allocator.ref(bid) > 1:
                    assert self.slot_reserved[i] > 0, \
                        f"slot {i}: copy-on-write beyond reservation"
                    (nb,) = self.allocator.take(1)
                    self.slot_reserved[i] -= 1
                    cow_src.append(bid)
                    cow_dst.append(nb)
                    self.allocator.free([bid])   # drop our alias
                    self.slot_blocks[i][bidx] = nb
                    self.block_tables[i, bidx] = nb
                    self._dev_tables = None
                elif self.prefix_cache.is_registered(bid):
                    self.prefix_cache.unregister_block(bid)
        if cow_src:
            # one batched device copy per tick; pad to a small bucket
            # of widths so the jit cache stays bounded (0 -> 0 copies
            # the scratch block onto itself: harmless)
            width = 1 << (len(cow_src) - 1).bit_length()
            pad = width - len(cow_src)
            src = np.asarray(cow_src + [0] * pad, np.int32)
            dst = np.asarray(cow_dst + [0] * pad, np.int32)
            self.caches = self._jit_copy_blocks(self.caches, src, dst)

    def _table_width(self, active: List[int]) -> int:
        """Bucketed live-table width: the decode program only streams
        blocks up to the longest active slot, rounded up to a small
        bucket (1, 2, then multiples of 2) so the jit cache stays at a
        handful of variants instead of one per length."""
        need = max(len(self.slot_blocks[i]) for i in active)
        width = need if need <= 2 else 2 * (-(-need // 2))
        return min(width, self.blocks_per_slot)

    def step(self, train_batch: Optional[Dict[str, Any]] = None,
             now: float = 0.0) -> List[GenRequest]:
        """One runtime tick under the token budget: admit, spend the
        decode-TPOT slack on prefill chunks (deadline-slack order),
        advance every DECODING slot one token, and fit a train
        microbatch (full / halved / skipped) into whatever budget
        remains.  Without chunking/budget knobs this reduces to the
        original admit + full-wave tick.  Returns the requests that
        finished this tick."""
        if train_batch is not None and self.opt_state is None:
            raise ValueError(
                "step(train_batch=...) requires opt_state (pass it to "
                "the ContinuousBatcher constructor)")
        budget = self.budget
        self.last_tick_trained = False
        self.last_tick_train_rows = 0
        if self._swapped:
            self._restore(now)
        finished = self.admit(now)
        prefill_spent = 0.0
        chunked = self.prefill_chunk > 0 or self.oversubscribe > 0
        if chunked and self.prefilling_slots():
            allowance = float("inf") if budget is None else \
                budget.prefill_allowance(len(self.decoding_slots()))
            done, prefill_spent = self._advance_prefill(now, allowance)
            finished.extend(done)
        active = self.decoding_slots() if chunked \
            else self.active_slots()
        if self.oversubscribe > 0 and active:
            # preempt-or-reserve BEFORE _grow_tables takes fresh blocks
            active = self._ensure_headroom(active, now)
        if not active:
            if train_batch is not None:
                ref = train_batch.get("tokens",
                                      train_batch.get("embeds"))
                b, s = int(ref.shape[0]), int(ref.shape[1])
                tt: Optional[int] = 0
                if budget is not None and self.prefilling_slots():
                    # mid-prefill slots are waiting on TTFT — only
                    # train in whatever slack this tick has left
                    tt = budget.train_tokens(b, s, prefill_spent)
                if tt is None:
                    self.stats.train_skipped_ticks += 1
                else:
                    t0 = time.perf_counter()
                    self._plain_train(train_batch, train_tokens=tt)
                    rows = b if tt == 0 else max(1, min(b, tt // s))
                    if budget is not None:
                        budget.observe_train(
                            rows * s, time.perf_counter() - t0)
                    self.last_tick_trained = True
                    self.last_tick_train_rows = rows
            self._record_budget(prefill_spent)
            return finished
        toks = jnp.asarray(self.slot_tok[:, None])
        pos = jnp.asarray(self.slot_pos)
        # registry mode: per-slot device adapter slots for the segmented
        # decode paths (inactive / base-only rows select -1 -> bitwise
        # base output); without a registry the kwargs stay absent so the
        # single-adapter traces are untouched
        if self.adapters is not None:
            idx = np.full(self.n_slots, -1, np.int32)
            for i in active:
                aid = self.slot_aid[i]
                if aid is not None:
                    idx[i] = self.adapters.slot_index(aid)
            serve_idx = jnp.asarray(idx)
            dec_kw = {"adapter_idx": serve_idx}
            comb_kw = {"serve_adapter_idx": serve_idx}
        else:
            dec_kw = {}
            comb_kw = {}
        if self.paged:
            self._grow_tables(active)
            width = self._table_width(active)
            if self._dev_tables is None \
                    or self._dev_tables_width != width:
                tbl = self.block_tables[:, :width]
                pref = self.prefilling_slots()
                if pref:
                    # park mid-prefill slots on scratch block 0: the
                    # paged write index CLAMPS out-of-range table
                    # lookups, so a live row here would let the parked
                    # slot's garbage decode write corrupt a real block
                    tbl = tbl.copy()
                    tbl[pref, :] = 0
                self._dev_tables = jnp.asarray(tbl)
                self._dev_tables_width = width
            tables = self._dev_tables
        if self._lsan is not None:
            self._sanitize_wave(active)
        # budget the tick's leftover slack into the train microbatch:
        # full batch / half batch / skipped (tt=None), a static knob so
        # the fused program compiles at most twice per shape
        tt: Optional[int] = 0
        train_rows = 0
        if train_batch is not None:
            ref = train_batch.get("tokens", train_batch.get("embeds"))
            b, s = int(ref.shape[0]), int(ref.shape[1])
            if budget is not None:
                tt = budget.train_tokens(b, s, prefill_spent)
            if tt is None:
                self.stats.train_skipped_ticks += 1
            else:
                train_rows = b if tt == 0 else max(1, min(b, tt // s))
        t0 = time.perf_counter()
        if train_batch is not None and tt is not None:
            if self.paged:
                (new_tl, self.opt_state, logits, self.caches,
                 metrics) = self._jit_combined_paged(
                    self.params, self._train_adapter(), self.opt_state,
                    train_batch, self.caches, toks, pos, tables,
                    ring_len=self.ring_len, serve_lora=self._serve_lora(),
                    attn_backend=self.attn_backend,
                    grad_accum=self.train_grad_accum,
                    train_tokens=tt, **comb_kw)
            else:
                (new_tl, self.opt_state, logits, self.caches,
                 metrics) = self._jit_combined(
                    self.params, self._train_adapter(), self.opt_state,
                    train_batch, self.caches, toks, pos,
                    serve_lora=self._serve_lora(),
                    attn_backend=self.attn_backend,
                    grad_accum=self.train_grad_accum,
                    train_tokens=tt, **comb_kw)
            self._store_trained(new_tl)
            self._record_train(metrics)
            self.last_tick_trained = True
            self.last_tick_train_rows = train_rows
        elif self.paged:
            logits, self.caches = self._jit_decode_paged(
                self.params, self._serve_lora(), self.caches, toks, pos,
                tables, ring_len=self.ring_len,
                attn_backend=self.attn_backend, **dec_kw)
        else:
            logits, self.caches = self._jit_decode(
                self.params, self._serve_lora(), self.caches, toks, pos,
                attn_backend=self.attn_backend, **dec_kw)
        self.stats.decode_steps += 1
        nxt = np.asarray(  # lint: host-sync-ok one batched argmax pull per decode wave
            jnp.argmax(logits[:, -1], axis=-1), np.int32)
        dt = time.perf_counter() - t0
        if budget is not None:
            if self.last_tick_trained:
                # the fused tick's train share is what exceeded the
                # known decode cost (conservative before it's known)
                budget.observe_train(
                    train_rows * s,
                    max(dt - (budget.decode_tick_s or 0.0), 0.0))
            else:
                budget.observe_decode(dt)
        self._record_budget(prefill_spent + dt)
        if any(self.slot_req[i].samples for i in active):
            # ONE batched host fetch of the last-position logits for the
            # whole tick; greedy-only ticks keep the transfer-free
            # device argmax path
            nxt = nxt.copy()    # device-backed arrays are read-only
            host_rows = np.asarray(logits[:, -1])  # lint: host-sync-ok one batched logits pull per sampling tick
            for i in active:
                req = self.slot_req[i]
                if req.samples:
                    nxt[i] = sample_token(
                        host_rows[i],
                        temperature=req.temperature, top_k=req.top_k,
                        top_p=req.top_p, rng=req.rng)
        for i in active:
            req = self.slot_req[i]
            req.tokens.append(int(nxt[i]))
            self.stats.generated_tokens += 1
            self.slot_pos[i] += 1
            self.slot_tok[i] = nxt[i]
            if len(req.tokens) >= req.max_new_tokens \
                    or int(nxt[i]) == self.eos_id:
                self._record_finish(req, now)
                self._evict(i)
                finished.append(req)
        return finished

    def _sanitize_wave(self, active: List[int]) -> None:
        """REPRO_SANITIZE=1 only (``_lsan`` gates the call): verify the
        wave the decode program is about to consume — every slot holds
        an ACTIVE request, every gathered block is live, every write
        target is private and non-scratch, reservations balance, and
        every routed adapter slot is pinned, resident and not
        mid-publish."""
        self._lsan.check_decode_wave(self, active)
        if self.paged and self.allocator.san is not None:
            self.allocator.san.check_decode_wave(self, active)
        if self.adapters is not None and self.adapters.san is not None:
            self.adapters.san.check_decode_wave(self, active)

    def _evict(self, i: int) -> None:
        """Free slot ``i`` completely: request pointer, ragged position
        AND feed token (a stale ``slot_tok`` would leak the previous
        request's last token into the next admission's first tick), plus
        the slot's blocks and any unused reservation in paged mode."""
        self.slot_req[i] = None
        self.slot_pos[i] = 0
        self.slot_tok[i] = 0
        self.slot_prefilled[i] = 0
        self.slot_cached[i] = 0
        self.slot_goal[i] = 0
        self.slot_seq[i] = None
        self.slot_restore_tok[i] = -1
        if self.slot_aid[i] is not None:
            # unpin the request's adapter — without this the registry
            # leaks a ref per request and eventually deadlocks admission
            self.adapters.release(self.slot_aid[i])
            self.slot_aid[i] = None
        if self.paged:
            self.allocator.free(self.slot_blocks[i])
            self.slot_blocks[i] = []
            self.allocator.release(int(self.slot_reserved[i]))
            self.slot_reserved[i] = 0
            self.block_tables[i, :] = 0   # back to scratch block 0
            self._dev_tables = None
            if self.allocator.san is not None:
                self.allocator.san.check_evicted(self, i)

    def drain_all(self) -> List[GenRequest]:
        """Failover teardown: evict every active slot, clear the queue,
        and return all unfinished requests (their partial tokens are
        discarded — a survivor regenerates from the prompt).  In paged
        mode every slot's blocks and reservations return to the
        allocator, so ``allocator.n_used`` drops to 0."""
        out: List[GenRequest] = list(self.queue)
        self.queue.clear()
        for i in self.active_slots():
            req = self.slot_req[i]
            self._evict(i)
            out.append(req)
        for e in self._swapped:
            # parked requests still hold their kept-chain block refs
            # and their adapter pin — return both before draining
            if e.kept:
                self.allocator.free(e.kept)
            if e.adapter_id is not None and self.adapters is not None:
                self.adapters.release(e.adapter_id)
            out.append(e.req)
        self._swapped.clear()
        for r in out:
            r.tokens.clear()
            r.prefill_at = None
            r.rng = None
            if self._lsan is not None:
                self._lsan.on_drain(r)
        if self.paged and self.allocator.san is not None:
            self.allocator.san.check_quiescent(self)
        return out

    def _train_adapter(self) -> Any:
        """The tree the optimizer steps: the staged shadow during a
        train session, the published adapter otherwise (in-place
        continuous adaptation); decode/prefill ALWAYS read
        ``self.lora``."""
        return self.train_lora if self.train_lora is not None \
            else self.lora

    def _store_trained(self, new_tl: Any) -> None:
        if self.train_lora is not None:
            self.train_lora = new_tl
        else:
            self.lora = new_tl

    def _plain_train(self, train_batch, train_tokens: int = 0) -> None:
        new_tl, self.opt_state, metrics = self._jit_train(
            self.params, self._train_adapter(), self.opt_state,
            train_batch, grad_accum=self.train_grad_accum,
            train_tokens=train_tokens)
        self._store_trained(new_tl)
        self._record_train(metrics)

    def _record_budget(self, spent_s: float) -> None:
        """Per-tick budget telemetry (tpot_target > 0 only)."""
        if self.budget is None:
            return
        self.stats.budget_ticks += 1
        self.stats.budget_target_s += self.budget.target_s
        self.stats.budget_spent_s += spent_s

    def _record_train(self, metrics: Dict[str, Any]) -> None:
        """One host sync per train tick: loss history + the scalar
        gradient stats the noise-scale estimator consumes."""
        host = jax.device_get(metrics)  # lint: host-sync-ok one batched metrics pull per train tick
        self.last_train_metrics = {
            "ce_loss": float(host["ce_loss"]),
            "micro_grad_sqnorm": float(host["micro_grad_sqnorm"]),
            "grad_sqnorm": float(host["grad_sqnorm"]),
        }
        loss = self.last_train_metrics["ce_loss"]
        self.train_losses.append(loss)
        self.stats.train_loss = loss
        self.stats.train_steps += 1

    # ------------------------------------------------------------------ run -
    def run(self, requests: Sequence[GenRequest],
            train_data_fn: Optional[Callable[[], Dict[str, Any]]] = None
            ) -> ServeStats:
        """Drain ``requests`` to completion; with ``train_data_fn``,
        every tick co-runs a fused training step."""
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while not self.idle():
            tb = train_data_fn() if train_data_fn is not None else None
            self.step(train_batch=tb, now=time.perf_counter() - t0)
        self.stats.wall_time += time.perf_counter() - t0
        return self.stats

    # ---------------------------------------------------------- telemetry --
    def cache_bytes(self) -> int:
        """Allocated KV cache bytes (pool + tables)."""
        total = sum(leaf.size * leaf.dtype.itemsize
                    for leaf in jax.tree.leaves(self.caches))
        if self.paged:
            total += self.block_tables.nbytes
        return total


# ========================================================================
# Lock-step static-batch baseline
# ========================================================================
def static_batch_serve(engine, params, lora, requests: Sequence[GenRequest],
                       *, batch_size: int = 8, prompt_pad: int = 32,
                       max_seq: int = 128,
                       eos_id: Optional[int] = None) -> ServeStats:
    """The pre-continuous-batching serving loop: group requests into
    fixed batches, prefill the batch, then decode lock-step until every
    request in the batch finishes (max_new_tokens or EOS) — short /
    early-EOS requests ride along as dead slots.  Same greedy math and
    the same EOS rule as ``ContinuousBatcher`` (equivalence-tested), so
    throughput differences are pure scheduling."""
    model = engine.model
    cfg = model.cfg
    assert not cfg.has_ssm and cfg.family.value != "vlm", \
        "baseline supports attention-only stacks"
    jits = _engine_jits(engine)
    jit_prefill = jits["prefill_ragged"]
    jit_decode = jits["decode"]
    stats = ServeStats()
    t0 = time.perf_counter()

    def finish(r: GenRequest) -> None:
        r.finished_at = time.perf_counter() - t0
        r.finished_wall = time.perf_counter()
        stats.finished += 1

    reqs = list(requests)
    for lo in range(0, len(reqs), batch_size):
        batch = reqs[lo:lo + batch_size]
        bsz = len(batch)
        lens = np.array([len(r.prompt) for r in batch], np.int32)
        padded = np.zeros((bsz, prompt_pad), np.int32)
        for i, r in enumerate(batch):
            padded[i, :lens[i]] = r.prompt
            r.max_new_tokens = max(
                1, min(r.max_new_tokens, max_seq - lens[i]))
        logits, pre = jit_prefill(params, lora,
                                  {"tokens": jnp.asarray(padded)},
                                  jnp.asarray(lens))
        caches = model.init_caches(bsz, max_seq)
        caches = jax.tree.map(
            lambda pool, p: jax.lax.dynamic_update_slice(
                pool, p.astype(pool.dtype), (0,) * pool.ndim),
            caches, {"kv": pre["kv"]})
        toks = np.asarray(  # lint: host-sync-ok one batched argmax pull per prefill batch
            jnp.argmax(logits[:, -1], axis=-1), np.int32)
        pos = lens.copy()
        stats.admitted += bsz
        stats.prefill_tokens += int(lens.sum())
        for i, r in enumerate(batch):
            r.tokens.append(int(toks[i]))
            stats.generated_tokens += 1
            if len(r.tokens) >= r.max_new_tokens \
                    or int(toks[i]) == eos_id:
                finish(r)
        # lock-step decode: every slot pays until the batch's LAST
        # request finishes; finished requests are dead weight
        while not all(r.done for r in batch):
            logits, caches = jit_decode(params, lora, caches,
                                        jnp.asarray(toks[:, None]),
                                        jnp.asarray(pos))
            stats.decode_steps += 1
            toks = np.asarray(  # lint: host-sync-ok one batched argmax pull per decode step
                jnp.argmax(logits[:, -1], axis=-1), np.int32)
            pos += 1
            for i, r in enumerate(batch):
                if r.done:
                    continue
                r.tokens.append(int(toks[i]))
                stats.generated_tokens += 1
                if len(r.tokens) >= r.max_new_tokens \
                        or int(toks[i]) == eos_id:
                    finish(r)
    stats.wall_time += time.perf_counter() - t0
    return stats
