"""Slot-based continuous-batching decode runtime (FlexLLM-style
token-level co-serving over one shared base model).

A ``ContinuousBatcher`` owns a fixed pool of decode *slots* backed by a
single pre-allocated cache pool (``model.init_caches(n_slots, max_seq)``)
with per-slot KV lengths — the ragged ``kv_len [B]`` path the decode
attention (jnp and Pallas) already supports, finally exploited upstream:

  admission   a free slot takes the next queued request; the prompt runs
              through REAL ``model.prefill`` / ``model.prefill_ragged``
              (one XLA program, no per-token warm fill) and the caches
              are copied into the slot via ``model.write_prefill_slot``;
  decode      every step advances ALL active slots one token with
              per-slot positions (``decode_step`` with ``pos [B]``);
  eviction    a slot frees the moment its request hits max_new_tokens /
              EOS — the next queued request is admitted mid-flight while
              the other slots keep decoding (no lock-step drain);
  co-serving  passing a training batch to ``step`` runs the fused
              ``engine.combined_step`` — LoRA finetuning + the decode
              tick in ONE program over shared base weights (the paper's
              model-sharing semantics, per token instead of per batch).

``static_batch_serve`` is the lock-step baseline (prefill a batch,
decode until the LONGEST request finishes, then drain) used by
benchmarks/continuous_batching.py and the equivalence tests.

Scope: non-VLM families; full-attention or cache-covering windows
(``sliding_window == 0 or >= max_seq``) — ring-buffer prefill handoff
and VLM cross-KV slots are ROADMAP items.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp


@functools.lru_cache(maxsize=16)
def _engine_jits(engine) -> Dict[str, Callable]:
    """One set of jitted step programs per (frozen, hashable) Engine —
    shared across every batcher / baseline run on that engine so fresh
    runtimes never retrace (donation is per-call, sharing is safe)."""
    model = engine.model
    return {
        "decode": jax.jit(model.decode_step, donate_argnums=(2,)),
        "prefill_ragged": jax.jit(model.prefill_ragged),
        "prefill_exact": jax.jit(model.prefill),
        "write": jax.jit(model.write_prefill_slot, donate_argnums=(0,)),
        "combined": jax.jit(engine.combined_step, donate_argnums=(2, 4)),
        "train": jax.jit(engine.train_step, donate_argnums=(2,)),
        "loss": jax.jit(
            lambda p, l, b: engine.model.forward_loss(p, l, b)[0]),
    }


@dataclasses.dataclass
class GenRequest:
    """One generation request: prompt in, greedy tokens out."""
    request_id: int
    prompt: np.ndarray                  # [P] int32 token ids
    max_new_tokens: int = 16
    arrival: float = 0.0
    # filled by the runtime
    tokens: List[int] = dataclasses.field(default_factory=list)
    prefill_at: Optional[float] = None
    finished_at: Optional[float] = None
    # wall-clock (perf_counter) finish stamp — ``finished_at`` carries
    # whatever clock the caller's ``now`` uses, which may be sim time
    finished_wall: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.finished_at is not None


@dataclasses.dataclass
class ServeStats:
    admitted: int = 0
    finished: int = 0
    prefill_tokens: int = 0
    generated_tokens: int = 0
    decode_steps: int = 0
    train_steps: int = 0
    wall_time: float = 0.0

    def throughput(self) -> float:
        return self.generated_tokens / max(self.wall_time, 1e-9)


class ContinuousBatcher:
    """Fixed-slot continuous batching over one model replica.

    Owns the adapter + optimizer state so the fused combined path can
    donate/update them in place; ``LiveReplica`` delegates its adapter
    accessors here.
    """

    def __init__(self, engine, params, lora, *, n_slots: int = 8,
                 max_seq: int = 128, prompt_pad: int = 32,
                 opt_state: Any = None, eos_id: Optional[int] = None):
        cfg = engine.model.cfg
        if n_slots < 1:
            # run() makes progress only through slots; zero would spin
            # forever on a non-empty queue
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if not cfg.has_decode:
            raise NotImplementedError(
                f"{cfg.name}: encoder-only, no decode serving")
        if cfg.family.value == "vlm":
            raise NotImplementedError(
                f"{cfg.name}: VLM cross-KV slot plumbing (units-leading "
                "cache layout + per-request vision inputs) is a ROADMAP "
                "item; use the prefill/decode API directly")
        if cfg.sliding_window > 0 and prompt_pad > cfg.sliding_window:
            # ring handoff is sound as long as the whole prompt fits the
            # window: prefill K/V land in the ring verbatim and decode
            # wraps exactly like the seed's ring-buffer parity test
            raise ValueError(
                f"{cfg.name}: prompt_pad {prompt_pad} exceeds the "
                f"attention window {cfg.sliding_window}; windowed "
                "prompt eviction at admission is not implemented")
        self.engine = engine
        self.model = engine.model
        self.cfg = cfg
        self.params = params
        self.lora = lora
        self.opt_state = opt_state
        self.n_slots = n_slots
        self.max_seq = max_seq
        self.prompt_pad = min(prompt_pad, max_seq)
        self.eos_id = eos_id

        self.caches = self.model.init_caches(n_slots, max_seq)
        self.queue: Deque[GenRequest] = collections.deque()
        self.slot_req: List[Optional[GenRequest]] = [None] * n_slots
        self.slot_pos = np.zeros(n_slots, np.int32)   # next write position
        self.slot_tok = np.zeros(n_slots, np.int32)   # next token to feed
        self.stats = ServeStats()
        self.train_losses: List[float] = []

        jits = _engine_jits(engine)
        self._jit_decode = jits["decode"]
        self._jit_prefill_ragged = jits["prefill_ragged"]
        self._jit_prefill_exact = jits["prefill_exact"]
        self._jit_write = jits["write"]
        self._jit_combined = jits["combined"]
        self._jit_train = jits["train"]

    # ------------------------------------------------------------ ingestion -
    def submit(self, req: GenRequest) -> None:
        req.prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        assert len(req.prompt) <= self.prompt_pad, \
            f"prompt len {len(req.prompt)} > prompt_pad {self.prompt_pad}"
        # a slot holds prompt + generation; clamp so writes stay in-pool
        budget = self.max_seq - len(req.prompt)
        req.max_new_tokens = max(1, min(req.max_new_tokens, budget))
        self.queue.append(req)

    def active_slots(self) -> List[int]:
        return [i for i in range(self.n_slots)
                if self.slot_req[i] is not None]

    def idle(self) -> bool:
        return not self.queue and not self.active_slots()

    # ------------------------------------------------------------ admission -
    def _prefill_wave(self, reqs: List[GenRequest]):
        """Prefill an admission wave.  Attention stacks: ONE ragged
        (right-padded) prefill program for the whole wave.  SSM/hybrid:
        state threads through pads, so exact-length per-request prefill
        (one compile per distinct prompt length)."""
        if self.cfg.has_ssm:
            outs = [self._jit_prefill_exact(
                self.params, self.lora,
                {"tokens": jnp.asarray(r.prompt[None])}) for r in reqs]
            return [(logits[0], pre, 0) for logits, pre in outs]
        lens = np.array([len(r.prompt) for r in reqs], np.int32)
        padded = np.zeros((len(reqs), self.prompt_pad), np.int32)
        for j, r in enumerate(reqs):
            padded[j, :lens[j]] = r.prompt
        logits, pre = self._jit_prefill_ragged(
            self.params, self.lora, {"tokens": jnp.asarray(padded)},
            jnp.asarray(lens))
        return [(logits[j], pre, j) for j in range(len(reqs))]

    def admit(self, now: float = 0.0) -> List[GenRequest]:
        """Fill free slots from the queue; returns requests that finished
        at admission (max_new_tokens == 1)."""
        finished: List[GenRequest] = []
        free = [i for i in range(self.n_slots)
                if self.slot_req[i] is None]
        take = min(len(free), len(self.queue))
        if not take:
            return finished
        reqs = [self.queue.popleft() for _ in range(take)]
        for slot, req, (logits_row, pre_caches, src) in zip(
                free, reqs, self._prefill_wave(reqs)):
            first = int(jnp.argmax(logits_row[-1]))
            req.tokens.append(first)
            req.prefill_at = now
            self.stats.admitted += 1
            self.stats.prefill_tokens += len(req.prompt)
            self.stats.generated_tokens += 1
            if len(req.tokens) >= req.max_new_tokens \
                    or first == self.eos_id:
                # done at admission: never occupies the slot, so skip
                # the cache write entirely
                req.finished_at = now
                req.finished_wall = time.perf_counter()
                self.stats.finished += 1
                finished.append(req)
                continue
            self.caches = self._jit_write(self.caches, pre_caches,
                                          slot, src)
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt)
            self.slot_tok[slot] = first
        return finished

    # --------------------------------------------------------------- decode -
    def step(self, train_batch: Optional[Dict[str, Any]] = None,
             now: float = 0.0) -> List[GenRequest]:
        """One runtime tick: admit, then advance every active slot one
        token (fused with a LoRA training step when ``train_batch`` is
        given).  Returns the requests that finished this tick."""
        if train_batch is not None and self.opt_state is None:
            raise ValueError(
                "step(train_batch=...) requires opt_state (pass it to "
                "the ContinuousBatcher constructor)")
        finished = self.admit(now)
        active = self.active_slots()
        if not active:
            if train_batch is not None:
                self._plain_train(train_batch)
            return finished
        toks = jnp.asarray(self.slot_tok[:, None])
        pos = jnp.asarray(self.slot_pos)
        if train_batch is not None:
            (self.lora, self.opt_state, logits, self.caches,
             metrics) = self._jit_combined(
                self.params, self.lora, self.opt_state, train_batch,
                self.caches, toks, pos)
            self.train_losses.append(float(metrics["ce_loss"]))
            self.stats.train_steps += 1
        else:
            logits, self.caches = self._jit_decode(
                self.params, self.lora, self.caches, toks, pos)
        self.stats.decode_steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        for i in active:
            req = self.slot_req[i]
            req.tokens.append(int(nxt[i]))
            self.stats.generated_tokens += 1
            self.slot_pos[i] += 1
            self.slot_tok[i] = nxt[i]
            if len(req.tokens) >= req.max_new_tokens \
                    or int(nxt[i]) == self.eos_id:
                req.finished_at = now
                req.finished_wall = time.perf_counter()
                self.stats.finished += 1
                self.slot_req[i] = None
                self.slot_pos[i] = 0
                finished.append(req)
        return finished

    def _plain_train(self, train_batch) -> None:
        self.lora, self.opt_state, metrics = self._jit_train(
            self.params, self.lora, self.opt_state, train_batch)
        self.train_losses.append(float(metrics["ce_loss"]))
        self.stats.train_steps += 1

    # ------------------------------------------------------------------ run -
    def run(self, requests: Sequence[GenRequest],
            train_data_fn: Optional[Callable[[], Dict[str, Any]]] = None
            ) -> ServeStats:
        """Drain ``requests`` to completion; with ``train_data_fn``,
        every tick co-runs a fused training step."""
        for r in requests:
            self.submit(r)
        t0 = time.perf_counter()
        while not self.idle():
            tb = train_data_fn() if train_data_fn is not None else None
            self.step(train_batch=tb, now=time.perf_counter() - t0)
        self.stats.wall_time += time.perf_counter() - t0
        return self.stats


# ========================================================================
# Lock-step static-batch baseline
# ========================================================================
def static_batch_serve(engine, params, lora, requests: Sequence[GenRequest],
                       *, batch_size: int = 8, prompt_pad: int = 32,
                       max_seq: int = 128) -> ServeStats:
    """The pre-continuous-batching serving loop: group requests into
    fixed batches, prefill the batch, then decode lock-step until the
    LONGEST request in the batch finishes — short requests ride along as
    dead slots.  Same greedy math as ``ContinuousBatcher`` (equivalence-
    tested), so throughput differences are pure scheduling."""
    model = engine.model
    cfg = model.cfg
    assert not cfg.has_ssm and cfg.family.value != "vlm", \
        "baseline supports attention-only stacks"
    jits = _engine_jits(engine)
    jit_prefill = jits["prefill_ragged"]
    jit_decode = jits["decode"]
    stats = ServeStats()
    t0 = time.perf_counter()
    reqs = list(requests)
    for lo in range(0, len(reqs), batch_size):
        batch = reqs[lo:lo + batch_size]
        bsz = len(batch)
        lens = np.array([len(r.prompt) for r in batch], np.int32)
        padded = np.zeros((bsz, prompt_pad), np.int32)
        for i, r in enumerate(batch):
            padded[i, :lens[i]] = r.prompt
            r.max_new_tokens = max(
                1, min(r.max_new_tokens, max_seq - lens[i]))
        logits, pre = jit_prefill(params, lora,
                                  {"tokens": jnp.asarray(padded)},
                                  jnp.asarray(lens))
        caches = model.init_caches(bsz, max_seq)
        caches = jax.tree.map(
            lambda pool, p: jax.lax.dynamic_update_slice(
                pool, p.astype(pool.dtype), (0,) * pool.ndim),
            caches, {"kv": pre["kv"]})
        toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        pos = lens.copy()
        for i, r in enumerate(batch):
            r.tokens.append(int(toks[i]))
        stats.admitted += bsz
        stats.prefill_tokens += int(lens.sum())
        stats.generated_tokens += bsz
        # lock-step decode: every slot pays for the longest request
        steps = max(r.max_new_tokens for r in batch) - 1
        for _ in range(steps):
            logits, caches = jit_decode(params, lora, caches,
                                        jnp.asarray(toks[:, None]),
                                        jnp.asarray(pos))
            stats.decode_steps += 1
            toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
            pos += 1
            for i, r in enumerate(batch):
                if len(r.tokens) < r.max_new_tokens:
                    r.tokens.append(int(toks[i]))
                    stats.generated_tokens += 1
        for r in batch:
            r.finished_at = time.perf_counter() - t0
            stats.finished += 1
    stats.wall_time += time.perf_counter() - t0
    return stats
