"""Discrete-event cluster simulator — the stand-in for the paper's
32×H20 testbed (DESIGN.md §8.2).

A binary-heap event loop drives: request arrivals (from data/traces),
control-plane ticks (ClusterController.tick), replica batch completions,
FL round completions, and fault injections.  All latencies come from the
replicas' analytic interference surfaces (runtime/replica.SimReplica),
which share the bivariate structure CoLLM fits (Eq. 9–10) plus noise —
the control plane never sees the ground-truth coefficients.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass(order=True)
class Event:
    time: float
    seq: int
    action: Callable[[float], None] = dataclasses.field(compare=False)
    tag: str = dataclasses.field(compare=False, default="")


class Simulator:
    def __init__(self):
        self._heap: List[Event] = []
        self._seq = itertools.count()
        self.now: float = 0.0
        self.processed: int = 0

    def schedule(self, time: float, action: Callable[[float], None],
                 tag: str = "") -> None:
        heapq.heappush(self._heap,
                       Event(max(time, self.now), next(self._seq),
                             action, tag))

    def schedule_every(self, period: float, action: Callable[[float], None],
                       tag: str = "", until: Optional[float] = None,
                       start: float = 0.0) -> None:
        def fire(now: float) -> None:
            action(now)
            nxt = now + period
            if until is None or nxt <= until:
                self.schedule(nxt, fire, tag)
        self.schedule(start, fire, tag)

    def run(self, until: float) -> None:
        while self._heap and self._heap[0].time <= until:
            ev = heapq.heappop(self._heap)
            self.now = ev.time
            ev.action(ev.time)
            self.processed += 1
        self.now = until

    def peek(self) -> Optional[float]:
        return self._heap[0].time if self._heap else None
