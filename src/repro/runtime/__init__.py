from repro.runtime.simulator import Simulator  # noqa: F401
from repro.runtime.replica import (  # noqa: F401
    InterferenceSurface, LiveReplica, LossCurve, SimReplica,
)
from repro.runtime.serving_loop import (  # noqa: F401
    ContinuousBatcher, GenRequest, ServeStats, static_batch_serve,
)
