from repro.runtime.simulator import Simulator  # noqa: F401
from repro.runtime.replica import (  # noqa: F401
    InterferenceSurface, LiveReplica, LossCurve, SimReplica,
)
