"""Host-side accounting for the paged KV cache (vLLM-style), including
copy-on-write prefix sharing.

The device side is a global block pool ``[L, n_blocks, block_size, Hkv,
Dh]`` (``Model.init_paged_caches``) plus per-slot block tables; this
module owns which pool blocks are free, which slot holds which blocks,
and whether an admission's worst case fits — the policy half of paging,
kept in plain Python/numpy so the decode program never depends on it.

Reservation semantics (preemption-free admission, the default): at
admission the batcher reserves a request's WORST-CASE block count;
blocks are then taken lazily — prompt blocks at admission, one more
each time decode crosses a block boundary — always against the
reservation.  A request is admitted only if its worst case fits the
unreserved pool, so a slot can never stall mid-decode waiting for a
block.  Oversubscribed admission (``ContinuousBatcher(oversubscribe=
...)``) reserves only near-term need instead and handles mid-decode
exhaustion by preempting a victim slot: the victim's private blocks
either swap to host memory (``swap_out``/``swap_in`` below) or are
dropped and re-prefilled on restore.

Sharing semantics (prefix caching): every block carries a refcount.
Full, immutable prompt blocks are registered in a ``PrefixCache``
keyed by ``(parent block, content hash of the block's tokens)``; a new
request whose prompt starts with a cached block chain aliases those
pool blocks at refcount+1 instead of re-prefilling them.  Shared
blocks are never written — the runtime copy-on-writes a private block
before any decode write would land in one.  When the last reference
to a *registered* block is freed, the block is not returned to the
free list but parked in an LRU retained pool, so warm prefixes survive
across requests; the allocator reclaims retained blocks (oldest first,
unregistering their cache entries) only when a ``take`` outruns the
free list.

Block 0 is reserved as the scratch block: inactive decode slots keep
all-zero block tables, so their dead-lane writes land there instead of
corrupting live blocks.
"""
from __future__ import annotations

import collections
import hashlib
from typing import (
    Callable, Deque, Dict, List, Optional, Sequence, Tuple,
)

import numpy as np

from repro.runtime.sanitize import block_sanitizer


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` cache rows."""
    return -(-max(int(n_tokens), 0) // block_size)


class OutOfBlocks(RuntimeError):
    """Raised when an alloc/reserve exceeds the unreserved free pool."""


class BlockError(RuntimeError):
    """Refcount invariant violation: double free, alias of a free
    block, or a take that hands out a still-referenced block."""


class BlockAllocator:
    """Refcounted free-list allocator over ``n_blocks`` pool blocks.

    ``n_scratch`` leading blocks (default 1: block 0) are never handed
    out.  ``reserve``/``release`` move the admission-time worst-case
    bound; ``take`` converts reservation into concrete block ids (each
    at refcount 1); ``share`` aliases live blocks (refcount+1, the
    prefix-cache hit path); ``free`` drops one reference per id —
    freeing an unreferenced block is a hard error (real double-free
    detection), and a block whose refcount hits 0 returns to the free
    list unless it is *pinned* (registered in a prefix cache), in
    which case it parks in the LRU retained pool until reclaimed.
    """

    def __init__(self, n_blocks: int, block_size: int,
                 n_scratch: int = 1) -> None:
        if n_blocks <= n_scratch:
            raise ValueError(
                f"n_blocks {n_blocks} must exceed scratch count "
                f"{n_scratch}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.n_scratch = n_scratch
        self.capacity = n_blocks - n_scratch
        self._free: Deque[int] = collections.deque(
            range(n_scratch, n_blocks))
        self._ref = np.zeros(n_blocks, np.int32)
        # pinned = registered in a prefix cache: route to the retained
        # pool on last free, notify ``on_reclaim`` when reclaimed
        self._pinned: set = set()
        # LRU of pinned blocks with refcount 0 (insertion order = age)
        self._retained: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self.reserved = 0
        self.peak_used = 0
        # called with a block id when a retained block is reclaimed by
        # ``take`` — the prefix cache drops its entry there
        self.on_reclaim: Optional[Callable[[int], None]] = None
        # shadow refcount/reservation mirror, armed by REPRO_SANITIZE=1
        # (None otherwise — every hook below is one is-not-None test)
        self.san = block_sanitizer(self)

    # ------------------------------------------------------------ queries --
    @property
    def n_free(self) -> int:
        """Blocks holding no content at all (not retained)."""
        return len(self._free)

    @property
    def n_retained(self) -> int:
        """Cached-but-unreferenced blocks, reclaimable under pressure."""
        return len(self._retained)

    @property
    def n_used(self) -> int:
        """Blocks with at least one live reference."""
        return self.capacity - len(self._free) - len(self._retained)

    def ref(self, bid: int) -> int:
        return int(self._ref[bid])

    def available(self) -> int:
        """Blocks neither referenced nor promised to an admitted slot
        (retained blocks count: they are reclaimable on demand)."""
        return len(self._free) + len(self._retained) - self.reserved

    def can_reserve(self, n: int) -> bool:
        return self.available() >= n

    # ------------------------------------------------------------ mutation -
    def reserve(self, n: int) -> None:
        if not self.can_reserve(n):
            raise OutOfBlocks(
                f"reserve({n}): only {self.available()} unreserved "
                f"blocks available")
        self.reserved += n
        if self.san is not None:
            self.san.on_reserve(n)

    def release(self, n: int) -> None:
        assert 0 <= n <= self.reserved, \
            f"release({n}) exceeds outstanding reservation {self.reserved}"
        self.reserved -= n
        if self.san is not None:
            self.san.on_release(n)

    def take(self, n: int) -> List[int]:
        """Convert ``n`` reserved blocks into concrete pool block ids,
        each at refcount 1.  Pops the free list first; under pressure
        it reclaims retained blocks oldest-first, unregistering their
        prefix-cache entries via ``on_reclaim``."""
        assert n <= self.reserved, \
            f"take({n}) without reservation (reserved={self.reserved})"
        assert n <= len(self._free) + len(self._retained), \
            "reservation accounting broken: reserved blocks must be free"
        ids = []
        for _ in range(n):
            if self._free:
                bid = self._free.popleft()
            else:
                bid, _ = self._retained.popitem(last=False)  # LRU
                self._pinned.discard(bid)
                if self.on_reclaim is not None:
                    self.on_reclaim(bid)
            if self._ref[bid] != 0:
                raise BlockError(
                    f"take: block {bid} still has refcount "
                    f"{self._ref[bid]}")
            self._ref[bid] = 1
            ids.append(bid)
        self.reserved -= n
        self.peak_used = max(self.peak_used, self.n_used)
        if self.san is not None:
            self.san.on_take(ids)
        return ids

    def share(self, ids: Sequence[int]) -> None:
        """Alias live blocks: refcount+1 each.  Aliasing a block with
        no references is a hard error — the prefix-cache hit path must
        use ``acquire`` so retained blocks are revived instead."""
        for b in ids:
            if self._ref[b] < 1:
                raise BlockError(
                    f"share of unreferenced block {b} (refcount "
                    f"{self._ref[b]})")
            self._ref[b] += 1
        if self.san is not None:
            self.san.on_share(list(ids))

    def acquire(self, ids: Sequence[int]) -> None:
        """Take one reference on each block for a prefix-cache hit:
        live blocks are shared (refcount+1); retained blocks (cached
        content, refcount 0) are revived out of the LRU pool."""
        for b in ids:
            if self._ref[b] >= 1:
                self._ref[b] += 1
            elif b in self._retained:
                del self._retained[b]
                self._ref[b] = 1
            else:
                raise BlockError(
                    f"acquire of free block {b}: prefix cache points "
                    "at reclaimed content")
        self.peak_used = max(self.peak_used, self.n_used)
        if self.san is not None:
            self.san.on_acquire(list(ids))

    def n_would_revive(self, ids: Sequence[int]) -> int:
        """How many of ``ids`` would come out of the retained pool on
        ``acquire`` — admission must budget these against
        ``available()`` before reserving."""
        return sum(1 for b in ids if self._ref[b] == 0)

    def free(self, ids: Sequence[int]) -> None:
        """Drop one reference per id.  Refcount 0 -> free list, or the
        retained LRU pool when pinned (prefix-cached content)."""
        for b in ids:
            if not (self.n_scratch <= b < self.n_blocks):
                raise BlockError(f"free of invalid block id {b}")
            if self._ref[b] < 1:
                raise BlockError(
                    f"double free of block {b} (refcount 0)")
            self._ref[b] -= 1
            if self._ref[b] == 0:
                if b in self._pinned:
                    self._retained[b] = None   # most-recently used end
                    self._retained.move_to_end(b)
                else:
                    self._free.append(b)
        assert len(self._free) + len(self._retained) <= self.capacity, \
            "free-list overflow: refcount accounting broken"
        if self.san is not None:
            self.san.on_free(list(ids))

    # ------------------------------------------------------------- swapping -
    def swap_out(self, ids: Sequence[int]) -> None:
        """Preemption swap-out: drop the SOLE reference on each private
        block whose contents were just copied to host memory, returning
        the block to the free list.  Only unpinned refcount-1 blocks
        may swap — shared (COW prefix) and registered blocks stay
        pool-resident, so swapping one is a hard error.  The sanitizer
        marks the ids swapped-out: a decode-wave gather of one before a
        ``swap_in`` restores fresh blocks is a use-after-swap."""
        for b in ids:
            if not (self.n_scratch <= b < self.n_blocks):
                raise BlockError(f"swap-out of invalid block id {b}")
            if self._ref[b] != 1:
                raise BlockError(
                    f"swap-out of block {b} with refcount "
                    f"{self._ref[b]} (must be the sole reference)")
            if b in self._pinned:
                raise BlockError(
                    f"swap-out of pinned (prefix-cached) block {b} — "
                    "registered blocks stay pool-resident")
            self._ref[b] = 0
            self._free.append(b)
        if self.san is not None:
            self.san.on_swap_out(list(ids))

    def swap_in(self, n: int) -> List[int]:
        """Restore-side allocation: reserve AND take ``n`` fresh blocks
        in one step for the host->device scatter of a swapped-out
        chain.  Raises ``OutOfBlocks`` when the pool cannot cover the
        restore (the caller defers the swap-in to a later tick)."""
        self.reserve(n)
        ids = self.take(n)
        if self.san is not None:
            self.san.on_swap_in(ids)
        return ids

    # -------------------------------------------------------------- pinning -
    def pin(self, bid: int) -> None:
        """Mark ``bid`` as prefix-cached: its content outlives its last
        reference (retained LRU) until reclaimed or unpinned."""
        self._pinned.add(bid)
        if self.san is not None:
            self.san.on_pin(bid)

    def unpin(self, bid: int) -> None:
        """Drop the cache pin; an already-retained block moves straight
        back to the free list."""
        self._pinned.discard(bid)
        if bid in self._retained:
            del self._retained[bid]
            self._free.append(bid)
        if self.san is not None:
            self.san.on_unpin(bid)


# =========================================================================
# Hash-indexed prefix cache over full, immutable prompt blocks
# =========================================================================
_ROOT = -1   # parent id of a prompt's first block


def _ns_bytes(namespace: Optional[str]) -> bytes:
    """Tenant salt: a cached block's KV was computed under a specific
    adapter, so lookups are namespaced per tenant (None = base model)."""
    return b"" if namespace is None \
        else namespace.encode("utf-8") + b"\x00"


def _digest(tokens: np.ndarray, namespace: Optional[str] = None) -> bytes:
    """Content hash of one block's tokens (stable across processes),
    salted by the tenant namespace."""
    return hashlib.blake2b(
        _ns_bytes(namespace)
        + np.ascontiguousarray(tokens, np.int32).tobytes(),
        digest_size=16).digest()


class PrefixCache:
    """Maps ``(parent block, content hash)`` -> pool block holding that
    block's KV, chained so a lookup walks the longest cached
    block-aligned prefix of a prompt.

    Entries verify the full token bytes on lookup (hash collisions
    cannot alias wrong content).  Registration pins the block in the
    allocator; the allocator calls back ``_on_reclaim`` when it evicts
    a retained block under pressure, and the runtime calls
    ``unregister_block`` before writing a registered block in place
    (ring wrap on a refcount-1 block)."""

    def __init__(self, allocator: BlockAllocator) -> None:
        self.alloc = allocator
        self.block_size = allocator.block_size
        allocator.on_reclaim = self._on_reclaim
        # (parent, digest) -> [(token_bytes, bid), ...]  (collision list)
        self._table: Dict[Tuple[int, bytes],
                          List[Tuple[bytes, int]]] = {}
        self._key_of: Dict[int, Tuple[int, bytes, bytes]] = {}
        # parent bid -> registered child bids: entries are keyed by
        # parent BLOCK ID, so dropping a parent must cascade to its
        # children — a recycled parent id re-registered for different
        # content would otherwise resurrect stale chains whose KV was
        # computed under another prefix
        self._children: Dict[int, List[int]] = {}
        self.hits = 0          # blocks served from cache
        self.misses = 0        # full blocks that had to be prefilled
        self.reclaimed = 0     # retained blocks evicted under pressure

    def __len__(self) -> int:
        return len(self._key_of)

    # -------------------------------------------------------------- lookup -
    def match(self, prompt: np.ndarray,
              namespace: Optional[str] = None) -> List[int]:
        """Longest chain of cached blocks covering a block-aligned
        prefix of ``prompt`` — capped so at least ONE prompt token is
        always left to prefill (its logits seed generation).  Pure
        lookup: hit/miss counters are bumped by ``count_admitted`` only
        when an admission actually commits to a (possibly trimmed)
        match, so a backpressured queue head re-matched every tick
        cannot inflate telemetry.  ``namespace`` scopes the lookup to
        one tenant's blocks: KV cached under one adapter never serves
        another tenant's (or the base model's) prompt."""
        bs = self.block_size
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        max_blocks = (len(prompt) - 1) // bs
        out: List[int] = []
        parent = _ROOT
        for i in range(max_blocks):
            chunk = prompt[i * bs:(i + 1) * bs]
            bid = self._lookup(parent, chunk, namespace)
            if bid is None:
                break
            out.append(bid)
            parent = bid
        return out

    def count_admitted(self, prompt: np.ndarray, n_matched: int,
                       namespace: Optional[str] = None) -> None:
        """Record hit/miss telemetry for one admitted request:
        ``n_matched`` blocks were aliased, the rest of the prompt's
        matchable blocks had to be prefilled."""
        max_blocks = (len(np.asarray(prompt).reshape(-1)) - 1) \
            // self.block_size
        self.hits += n_matched
        self.misses += max_blocks - n_matched

    def _lookup(self, parent: int, chunk: np.ndarray,
                namespace: Optional[str] = None) -> Optional[int]:
        entries = self._table.get((parent, _digest(chunk, namespace)))
        if not entries:
            return None
        raw = _ns_bytes(namespace) \
            + np.ascontiguousarray(chunk, np.int32).tobytes()
        for token_bytes, bid in entries:
            if token_bytes == raw:   # collision-proof: verify content
                return bid
        return None

    # -------------------------------------------------------- registration -
    def register(self, prompt: np.ndarray, block_ids: Sequence[int],
                 n_matched: int, namespace: Optional[str] = None) -> None:
        """Register the full prompt blocks of a freshly admitted
        request.  ``block_ids`` is the slot's complete block list
        (matched prefix + fresh suffix); blocks ``n_matched ..
        len(prompt)//bs - 1`` are full, immutable and newly written.
        A block whose key is already mapped (identical prompt admitted
        in the same wave) stays unregistered — the existing entry
        wins."""
        bs = self.block_size
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        n_full = len(prompt) // bs
        parent = block_ids[n_matched - 1] if n_matched > 0 else _ROOT
        for i in range(n_matched, n_full):
            chunk = prompt[i * bs:(i + 1) * bs]
            bid = block_ids[i]
            key = (parent, _digest(chunk, namespace))
            raw = _ns_bytes(namespace) \
                + np.ascontiguousarray(chunk, np.int32).tobytes()
            entries = self._table.setdefault(key, [])
            existing = next((b for tb, b in entries if tb == raw), None)
            if existing is None and bid not in self._key_of:
                entries.append((raw, bid))
                self._key_of[bid] = (key[0], key[1], raw)
                if parent != _ROOT:
                    self._children.setdefault(parent, []).append(bid)
                self.alloc.pin(bid)
            # chain through the canonical holder of this content so a
            # same-wave duplicate keeps registering its deeper blocks
            # under reachable parents
            parent = existing if existing is not None else bid

    # ------------------------------------------------------- invalidation --
    def _drop_entry(self, bid: int) -> None:
        """Remove ``bid``'s table entry AND its whole subtree: child
        entries are keyed by this block's id, and a recycled id
        re-registered for different content would resurrect them as
        stale chains (byte verification cannot catch that — the child
        content matches, its KV context does not)."""
        info = self._key_of.pop(bid, None)
        if info is None:
            return
        parent, digest, _raw = info
        entries = self._table.get((parent, digest))
        if entries:
            entries[:] = [(tb, b) for tb, b in entries if b != bid]
            if not entries:
                del self._table[(parent, digest)]
        if parent != _ROOT:
            kids = self._children.get(parent)
            if kids and bid in kids:
                kids.remove(bid)
        for child in self._children.pop(bid, []):
            self._drop_entry(child)
            self.alloc.unpin(child)   # no longer cache-reachable

    def unregister_block(self, bid: int) -> None:
        """Drop ``bid``'s cache entry and its allocator pin (about to
        be written in place by its sole owner)."""
        self._drop_entry(bid)
        self.alloc.unpin(bid)

    def _on_reclaim(self, bid: int) -> None:
        # the allocator already unpinned/popped the block under
        # pressure; just drop the table entry
        self.reclaimed += 1
        self._drop_entry(bid)

    def is_registered(self, bid: int) -> bool:
        return bid in self._key_of
