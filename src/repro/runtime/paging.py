"""Host-side accounting for the paged KV cache (vLLM-style).

The device side is a global block pool ``[L, n_blocks, block_size, Hkv,
Dh]`` (``Model.init_paged_caches``) plus per-slot block tables; this
module owns which pool blocks are free, which slot holds which blocks,
and whether an admission's worst case fits — the policy half of paging,
kept in plain Python/numpy so the decode program never depends on it.

Reservation semantics (preemption-free admission): at admission the
batcher reserves a request's WORST-CASE block count; blocks are then
taken lazily — prompt blocks at admission, one more each time decode
crosses a block boundary — always against the reservation.  A request
is admitted only if its worst case fits the unreserved pool, so a slot
can never stall mid-decode waiting for a block (no preemption/swap
needed; that is the ROADMAP follow-on).

Block 0 is reserved as the scratch block: inactive decode slots keep
all-zero block tables, so their dead-lane writes land there instead of
corrupting live blocks.
"""
from __future__ import annotations

import collections
from typing import Deque, List, Sequence


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` cache rows."""
    return -(-max(int(n_tokens), 0) // block_size)


class OutOfBlocks(RuntimeError):
    """Raised when an alloc/reserve exceeds the unreserved free pool."""


class BlockAllocator:
    """Free-list allocator over ``n_blocks`` pool blocks.

    ``n_scratch`` leading blocks (default 1: block 0) are never handed
    out.  ``reserve``/``release`` move the admission-time worst-case
    bound; ``take`` converts reservation into concrete block ids;
    ``free`` returns a finished slot's blocks to the pool.
    """

    def __init__(self, n_blocks: int, block_size: int,
                 n_scratch: int = 1):
        if n_blocks <= n_scratch:
            raise ValueError(
                f"n_blocks {n_blocks} must exceed scratch count "
                f"{n_scratch}")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.n_scratch = n_scratch
        self.capacity = n_blocks - n_scratch
        self._free: Deque[int] = collections.deque(
            range(n_scratch, n_blocks))
        self.reserved = 0
        self.peak_used = 0

    # ------------------------------------------------------------ queries --
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.capacity - len(self._free)

    def available(self) -> int:
        """Blocks neither allocated nor promised to an admitted slot."""
        return len(self._free) - self.reserved

    def can_reserve(self, n: int) -> bool:
        return self.available() >= n

    # ------------------------------------------------------------ mutation -
    def reserve(self, n: int) -> None:
        if not self.can_reserve(n):
            raise OutOfBlocks(
                f"reserve({n}): only {self.available()} unreserved "
                f"blocks available")
        self.reserved += n

    def release(self, n: int) -> None:
        assert 0 <= n <= self.reserved, \
            f"release({n}) exceeds outstanding reservation {self.reserved}"
        self.reserved -= n

    def take(self, n: int) -> List[int]:
        """Convert ``n`` reserved blocks into concrete pool block ids."""
        assert n <= self.reserved, \
            f"take({n}) without reservation (reserved={self.reserved})"
        assert n <= len(self._free), \
            "reservation accounting broken: reserved blocks must be free"
        ids = [self._free.popleft() for _ in range(n)]
        self.reserved -= n
        self.peak_used = max(self.peak_used, self.n_used)
        return ids

    def free(self, ids: Sequence[int]) -> None:
        for b in ids:
            assert self.n_scratch <= b < self.n_blocks, \
                f"free of invalid block id {b}"
        self._free.extend(ids)
        assert len(self._free) <= self.capacity, "double free"
