"""Sharded, async, reshardable checkpointing (fault-tolerance substrate).

Format: one directory per step with
  manifest.json     tree structure, shapes, dtypes, step, config hash,
                    and the compression codec used
  <leaf-id>.bin.zst compressed raw bytes per leaf (written from the
                    addressable shards; on restore, any mesh/sharding may
                    be requested — elastic restart after node loss)

zstandard is an optional dependency: when absent the writer falls back
to stdlib zlib, recording the codec in the manifest so checkpoints stay
readable either way (a zstd checkpoint restored without zstandard is a
clear error, not garbage bytes).

The writer runs on a background thread (training never blocks on I/O);
``wait()`` joins before the next save or at shutdown.  Restore validates
shapes/dtypes against the manifest and re-shards via device_put.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:
    import zstandard
except ImportError:               # optional dep: fall back to stdlib zlib
    zstandard = None
import zlib

_FLAG = "_COMPLETE"


def _compressor(codec: str):
    if codec == "zstd":
        cctx = zstandard.ZstdCompressor(level=3)
        return cctx.compress
    return lambda data: zlib.compress(data, 3)


def _decompressor(codec: str):
    if codec == "zstd":
        if zstandard is None:
            raise ModuleNotFoundError(
                "checkpoint was written with zstd compression but "
                "zstandard is not installed")
        dctx = zstandard.ZstdDecompressor()
        return dctx.decompress
    if codec == "zlib":
        return zlib.decompress
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _tree_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def config_hash(obj: Any) -> str:
    return hashlib.sha256(repr(obj).encode()).hexdigest()[:16]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None,
             blocking: bool = False) -> str:
        """Snapshot to host memory synchronously, write asynchronously."""
        self.wait()
        leaves = [(k, np.asarray(v)) for k, v in _tree_paths(tree)]
        treedef = jax.tree_util.tree_structure(tree)
        path = os.path.join(self.directory, f"step_{step:010d}")

        def write():
            try:
                tmp = path + ".tmp"
                os.makedirs(tmp, exist_ok=True)
                codec = "zstd" if zstandard is not None else "zlib"
                manifest = {"step": step, "extra": extra or {},
                            "codec": codec,
                            "treedef": str(treedef), "leaves": {}}
                compress = _compressor(codec)
                for i, (key, arr) in enumerate(leaves):
                    fn = f"leaf_{i:05d}.bin.zst"
                    manifest["leaves"][key] = {
                        "file": fn, "shape": list(arr.shape),
                        "dtype": str(arr.dtype), "index": i}
                    with open(os.path.join(tmp, fn), "wb") as f:
                        f.write(compress(
                            np.ascontiguousarray(arr).tobytes()))
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                with open(os.path.join(tmp, _FLAG), "w") as f:
                    f.write("ok")
                if os.path.exists(path):
                    shutil.rmtree(path)
                os.rename(tmp, path)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()
        return path

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory,
                                       f"step_{s:010d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.directory):
            full = os.path.join(self.directory, d)
            if d.startswith("step_") and \
                    os.path.exists(os.path.join(full, _FLAG)):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``template``.  ``shardings`` may
        be a matching tree of NamedSharding for a *different* mesh than
        the checkpoint was written under (elastic restart)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        # pre-codec checkpoints carry no codec field and were zstd-only
        decompress = _decompressor(manifest.get("codec", "zstd"))
        by_key = manifest["leaves"]
        paths = _tree_paths(template)
        leaves_out = []
        shard_leaves = jax.tree_util.tree_leaves(shardings) \
            if shardings is not None else [None] * len(paths)
        for (key, leaf), shd in zip(paths, shard_leaves):
            meta = by_key.get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            want_shape = tuple(leaf.shape)
            if tuple(meta["shape"]) != want_shape:
                raise ValueError(
                    f"{key}: checkpoint shape {meta['shape']} != "
                    f"template {want_shape}")
            with open(os.path.join(path, meta["file"]), "rb") as f:
                raw = decompress(f.read())
            arr = np.frombuffer(raw, dtype=np.dtype(meta["dtype"])) \
                .reshape(want_shape)
            if str(arr.dtype) != str(jnp.dtype(leaf.dtype)):
                arr = arr.astype(jnp.dtype(leaf.dtype))
            if shd is not None:
                leaves_out.append(jax.device_put(arr, shd))
            else:
                leaves_out.append(jnp.asarray(arr))
        treedef = jax.tree_util.tree_structure(template)
        return jax.tree_util.tree_unflatten(treedef, leaves_out), \
            manifest["extra"]
