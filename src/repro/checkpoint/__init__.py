from repro.checkpoint.checkpointer import Checkpointer, config_hash  # noqa: F401
