"""Collaborative federated LoRA fine-tuning (paper §4.2–4.3).

FedAvg over the adapter matrices (Eq. 5):

  B̄^(t+1) = 1/|K| Σ_k B_k      Ā^(t+1) = 1/|K| Σ_k A_k

plus the model-quality score update (Eq. 6) and per-replica early
stopping (§4.3).  Aggregation is a pytree mean, so the same code path
serves the host-side simulator and — under pjit — lowers to a mean
``all-reduce`` over the (pod, data) mesh axes (DESIGN.md §6).

Note on Eq. 6: taken literally, Q^(t) = Q^(t-1) · ΔF/F^(t-1) contracts
Q toward zero for any relative improvement < 100%.  We implement the
literal rule behind ``literal_eq6=True`` and default to the stabilized
multiplicative form Q·(1 + ΔF/F) which preserves the paper's intent
(quality grows with training progress); §8.1 separately defines served
response quality as 1/CE-loss, which the serving layer uses directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np


def fedavg(adapter_trees: Sequence[Any], weights: Optional[Sequence[float]]
           = None) -> Any:
    """Eq. 5 — (optionally weighted) mean of LoRA pytrees."""
    assert adapter_trees, "fedavg needs at least one participant"
    if weights is None:
        w = np.full(len(adapter_trees), 1.0 / len(adapter_trees))
    else:
        w = np.asarray(weights, dtype=np.float64)
        w = w / w.sum()

    def avg(*leaves):
        out = leaves[0] * w[0]
        for wi, leaf in zip(w[1:], leaves[1:]):
            out = out + wi * leaf
        return out

    return jax.tree.map(avg, *adapter_trees)


def quality_update(q_prev: float, loss_prev: float, loss_now: float, *,
                   literal_eq6: bool = False) -> float:
    """Eq. 6 — model quality score update from FL-round average losses."""
    if loss_prev <= 0:
        return q_prev
    rel = (loss_prev - loss_now) / loss_prev
    if literal_eq6:
        return q_prev * rel
    return max(q_prev * (1.0 + rel), 1e-6)


@dataclasses.dataclass
class EarlyStopper:
    """§4.3 — drop a replica from the cohort when its local loss stops
    improving (patience rounds with < min_delta relative improvement)."""
    patience: int = 2
    min_delta: float = 1e-3

    def __post_init__(self) -> None:
        self.best: float = float("inf")
        self.bad_rounds: int = 0

    def update(self, local_loss: float) -> bool:
        """Returns True if the replica should stop fine-tuning."""
        if local_loss < self.best * (1.0 - self.min_delta):
            self.best = local_loss
            self.bad_rounds = 0
            return False
        self.bad_rounds += 1
        return self.bad_rounds >= self.patience


@dataclasses.dataclass
class FLRoundResult:
    replica_id: str
    adapter: Any
    local_loss: float
    samples: int
    train_time: float = 0.0


class FederatedSession:
    """One FL PEFT process over a cohort of IDLE→COMBINED replicas.

    The Launcher creates a session when ≥ min_cohort IDLE replicas serve
    the same model (§4.2); the member with the highest quality score
    acts as server (global init + aggregation).
    """

    def __init__(self, model_id: str, members: Sequence[str],
                 server: str, global_adapter: Any, *,
                 min_cohort: int = 3) -> None:
        self.model_id = model_id
        self.members: List[str] = list(members)
        self.server = server
        self.global_adapter = global_adapter
        self.min_cohort = min_cohort
        self.round: int = 0
        self.prev_avg_loss: Optional[float] = None
        self.stoppers: Dict[str, EarlyStopper] = {
            m: EarlyStopper() for m in members}
        self.quality: Dict[str, float] = {m: 1.0 for m in members}
        self.history: List[Dict] = []

    def aggregate(self, results: Sequence[FLRoundResult],
                  sample_weighted: bool = True) -> Any:
        """Run Eq. 5 over the round's results and update quality scores
        (Eq. 6).  Returns the new global adapter."""
        weights = [float(r.samples) for r in results] if sample_weighted \
            else None
        self.global_adapter = fedavg([r.adapter for r in results], weights)
        avg_loss = float(np.mean([r.local_loss for r in results]))
        if self.prev_avg_loss is not None:
            for r in results:
                self.quality[r.replica_id] = quality_update(
                    self.quality[r.replica_id], self.prev_avg_loss, avg_loss)
        self.history.append({
            "round": self.round, "avg_loss": avg_loss,
            "members": [r.replica_id for r in results]})
        self.prev_avg_loss = avg_loss
        self.round += 1
        return self.global_adapter

    def early_stops(self, results: Sequence[FLRoundResult]) -> List[str]:
        """§4.3 — members whose local loss plateaued this round."""
        stopped = []
        for r in results:
            if self.stoppers[r.replica_id].update(r.local_loss):
                stopped.append(r.replica_id)
        for rid in stopped:
            if rid in self.members:
                self.members.remove(rid)
        return stopped

    @property
    def alive(self) -> bool:
        # FedAvg is cohort-size agnostic; a session dissolves below 2
        # members (nothing left to aggregate across).
        return len(self.members) >= 2
