"""Inference-Training Coordinator (paper §5).

One Coordinator per FL PEFT session.  Per round:

  1. collect runtime stats from every COMBINED replica
     (T_train, B, p, l and T_infer, b under interference),
  2. fit the two bivariate latency models (Eq. 9–10),
  3. solve (B*, b*) = argmax GOODPUT(B, b*(B)) s.t. the SLO (Eq. 11–12),
  4. push the configuration to the replicas and export (latency model,
     b*) to the Dispatcher for subflow pacing.

Round 0 uses the conservative bootstrap (small B0, large b0, 50 steps)
so queues drain and the models get sample support (§5.2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.goodput import EfficiencyParams, goodput, optimize
from repro.core.interfaces import BatchResult, TrainRoundStats
from repro.core.latency_model import BivariateLatencyModel


@dataclasses.dataclass
class ReplicaPlan:
    """Per-replica configuration for the next round."""
    train_batch: int
    infer_batch: int
    expected_goodput: float = 0.0


@dataclasses.dataclass
class CoordinatorConfig:
    bootstrap_train_batch: int = 4     # B0
    bootstrap_infer_batch: int = 12    # b0 ("relatively large")
    bootstrap_steps: int = 50
    steps_per_round: int = 50
    max_train_batch: int = 64
    max_infer_batch: int = 256
    # a in Eq. 8 — the paper calls it "a scaling constant": it must put
    # a·p_t·l_t on the scale of batch sizes (p_t ~ O(10) gradient-noise
    # scale × l_t ~ O(1e-3) per-iteration loss drop ⇒ a ~ O(500)),
    # otherwise EFFICIENCY ≈ B0/B and the optimizer degenerates to B*=1
    efficiency_scale: float = 500.0


class InferenceTrainingCoordinator:
    """Owns per-replica interference-aware models + batch planning."""

    def __init__(self, session_id: str, replica_ids: Sequence[str],
                 slo: float, cfg: Optional[CoordinatorConfig] = None) -> None:
        self.session_id = session_id
        self.cfg = cfg or CoordinatorConfig()
        self.slo = slo
        self.replicas = list(replica_ids)
        self.round = 0
        self.t_train: Dict[str, BivariateLatencyModel] = {
            r: BivariateLatencyModel() for r in replica_ids}
        self.t_infer: Dict[str, BivariateLatencyModel] = {
            r: BivariateLatencyModel() for r in replica_ids}
        self.eff: Dict[str, EfficiencyParams] = {
            r: EfficiencyParams(scale_a=self.cfg.efficiency_scale,
                                init_batch=self.cfg.bootstrap_train_batch)
            for r in replica_ids}
        self.plans: Dict[str, ReplicaPlan] = {
            r: ReplicaPlan(self.cfg.bootstrap_train_batch,
                           self.cfg.bootstrap_infer_batch)
            for r in replica_ids}

    # ------------------------------------------------------------ telemetry -
    def observe_train(self, stats: TrainRoundStats) -> None:
        """Fold one member's completed round into its latency model +
        efficiency params.  Incremental sessions can complete degenerate
        (0 steps after a mid-round shed, NaN losses when no tick ran) —
        those must not poison the Eq. 9 fit or Eq. 8's l_t."""
        m = self.t_train.get(stats.replica_id)
        if m is None or stats.steps <= 0:
            return
        m.observe(stats.train_batch, stats.infer_batch, stats.avg_step_time)
        e = self.eff[stats.replica_id]
        if math.isfinite(stats.noise_scale):
            e.noise_scale = stats.noise_scale
        if math.isfinite(stats.loss_before) \
                and math.isfinite(stats.loss_after):
            e.loss_reduction = stats.loss_reduction

    def observe_infer(self, result: BatchResult) -> None:
        m = self.t_infer.get(result.replica_id)
        if m is None or result.batch_size <= 0:
            return
        m.observe(result.batch_size, result.train_batch,
                  result.infer_latency)

    # --------------------------------------------------------------- solve --
    def replan(self, latency_budget: Optional[float] = None
               ) -> Dict[str, ReplicaPlan]:
        """Fit models and solve Eq. 11–12 per replica.  ``latency_budget``
        is τ' = τ − T̄_queue (the dispatcher supplies the queue term);
        defaults to the raw SLO."""
        budget = latency_budget if latency_budget is not None else self.slo
        self.round += 1
        for rid in self.replicas:
            tt, ti = self.t_train[rid], self.t_infer[rid]
            if not (tt.fitted and ti.fitted):
                continue  # keep bootstrap plan until models have support
            tt.fit()
            ti.fit()
            big_b, b_star, g = optimize(
                tt, ti, self.eff[rid], budget,
                train_batches=range(1, self.cfg.max_train_batch + 1),
                infer_cap=self.cfg.max_infer_batch)
            self.plans[rid] = ReplicaPlan(big_b, b_star, g)
        return dict(self.plans)

    # ------------------------------------------------------------- exports --
    def plan_for(self, replica_id: str) -> ReplicaPlan:
        return self.plans[replica_id]

    def infer_model_for(self, replica_id: str) -> BivariateLatencyModel:
        return self.t_infer[replica_id]

    def drop_replica(self, replica_id: str) -> None:
        """Early-stopped / failed member leaves the session."""
        if replica_id in self.replicas:
            self.replicas.remove(replica_id)
        self.plans.pop(replica_id, None)

    @property
    def steps_per_round(self) -> int:
        return self.cfg.bootstrap_steps if self.round == 0 \
            else self.cfg.steps_per_round
