"""Compiled step functions — including the paper's core mechanism as a
single XLA program: ``combined_step`` executes a LoRA training step AND
an inference batch over ONE shared copy of the base weights (DESIGN.md
§2: the TPU-native form of CoLLM's model-sharing / spatial multiplexing).

All steps take and return explicit pytrees so they jit/pjit cleanly and
the dry-run can lower them with ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model, build
from repro.optim.adamw import AdamW, AdamWState, global_norm


@dataclasses.dataclass(frozen=True)
class Engine:
    """Step factory for one architecture."""
    model: Model
    optimizer: AdamW

    # ----------------------------------------------------------- training --
    def train_step(self, params: Any, lora: Any, opt_state: AdamWState,
                   batch: Any,
                   *, skip_masked_blocks: bool = False,
                   ce_chunk: int = 512, grad_accum: int = 1,
                   train_tokens: int = 0
                   ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
        """LoRA-only gradient step: base weights frozen (PEFT).

        ``grad_accum`` > 1 splits the global batch into microbatches
        scanned sequentially with f32 gradient accumulation — the
        standard memory lever for the large train cells (activations
        live per-microbatch; LoRA grads are tiny so the accumulator is
        nearly free).  The per-microbatch |g|² is also what the
        gradient-noise-scale estimator (Eq. 8's p_t) consumes.

        ``train_tokens`` > 0 caps the step at roughly that many train
        tokens by slicing whole batch rows (compile-time static, so
        each cap compiles once): the token-budget scheduler's lever for
        shrinking a microbatch into the tick's leftover SLO slack
        instead of skipping training outright.  0 = full batch.
        """
        if train_tokens > 0:
            ref = batch.get("tokens", batch.get("embeds"))
            b, s = int(ref.shape[0]), int(ref.shape[1])
            rows = max(1, min(b, train_tokens // max(s, 1)))
            if rows < b:
                batch = jax.tree.map(lambda x: x[:rows], batch)
                if grad_accum > 1 and rows % grad_accum:
                    grad_accum = 1
        def loss_fn(lora_, microbatch):
            loss, metrics = self.model.forward_loss(
                params, lora_, microbatch, ce_chunk=ce_chunk,
                skip_masked_blocks=skip_masked_blocks)
            return loss, metrics

        if grad_accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(lora, batch)
            micro_sqnorm = global_norm(grads) ** 2
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def body(carry, mb):
                g_acc, l_acc, sq_acc = carry
                (loss, _), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(lora, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / grad_accum,
                    g_acc, grads)
                sq = global_norm(grads) ** 2
                return (g_acc, l_acc + loss / grad_accum,
                        sq_acc + sq / grad_accum), None

            zeros = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), lora)
            (grads, loss, micro_sqnorm), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0.0), jnp.float32(0.0)), micro)
            metrics = {"ce_loss": loss}

        new_lora, new_opt, opt_metrics = self.optimizer.update(
            grads, opt_state, lora)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        # per-microbatch grad sqnorm feeds the noise-scale estimator
        metrics["micro_grad_sqnorm"] = micro_sqnorm
        metrics["grad_sqnorm"] = jnp.square(metrics["grad_norm"])
        return new_lora, new_opt, metrics

    # ------------------------------------------------------------ serving --
    def prefill_step(self, params: Any, lora: Any,
                     batch: Any) -> Tuple[jax.Array, Any]:
        return self.model.prefill(params, lora, batch)

    def decode_step(self, params: Any, lora: Any, caches: Any,
                    token: jax.Array,
                    pos: jax.Array) -> Tuple[jax.Array, Any]:
        return self.model.decode_step(params, lora, caches, token, pos)

    def encoder_serve_step(self, params: Any, lora: Any,
                           batch: Any) -> jax.Array:
        """Encoder-only 'serving': full-sequence frame classification."""
        hidden, _, _ = self.model.hidden_states(params, lora, batch)
        return hidden @ params["lm_head"]

    # ------------------------------------------------- the paper's fusion --
    def combined_step(self, params: Any, lora: Any, opt_state: AdamWState,
                      train_batch: Any, caches: Any, token: jax.Array,
                      pos: jax.Array, *,
                      serve_lora: Any = None,
                      attn_backend: Optional[str] = None,
                      grad_accum: int = 1,
                      train_tokens: int = 0,
                      serve_adapter_idx: Any = None
                      ) -> Tuple[Any, AdamWState, jax.Array, Any,
                                 Dict[str, jax.Array]]:
        """One fused program: LoRA train step + decode batch, sharing the
        HBM-resident base weights.  XLA schedules both DAGs; the returned
        logits come from the *pre-update* adapter (within-step snapshot
        isolation — matching the paper's subprocess snapshot semantics).

        ``serve_lora`` splits the adapters: decode reads it (the
        *published* snapshot) while the optimizer trains ``lora`` (the
        *shadow* tree) — shadow-adapter double buffering, so a whole
        round of training never perturbs in-flight generation.  Omitted,
        decode uses the training adapter (the pre-PR-5 behaviour).
        ``serve_adapter_idx`` [B] int32 makes ``serve_lora`` a STACKED
        multi-tenant tree (leaves [L, A, din, r]) with per-row slot
        selection — the AdapterRegistry decode wave.
        """
        logits, new_caches = self.model.decode_step(
            params, lora if serve_lora is None else serve_lora,
            caches, token, pos, attn_backend=attn_backend,
            adapter_idx=serve_adapter_idx)
        new_lora, new_opt, metrics = self.train_step(
            params, lora, opt_state, train_batch, grad_accum=grad_accum,
            train_tokens=train_tokens)
        return new_lora, new_opt, logits, new_caches, metrics

    def combined_step_paged(self, params: Any, lora: Any,
                            opt_state: AdamWState, train_batch: Any,
                            caches: Any, token: jax.Array,
                            pos: jax.Array, block_tables: jax.Array,
                            *, ring_len: int = 0,
                            serve_lora: Any = None,
                            attn_backend: Optional[str] = None,
                            grad_accum: int = 1,
                            train_tokens: int = 0,
                            serve_adapter_idx: Any = None
                            ) -> Tuple[Any, AdamWState, jax.Array, Any,
                                       Dict[str, jax.Array]]:
        """``combined_step`` over the paged KV pool: LoRA train step +
        block-table decode tick fused into one program (same pre-update
        snapshot semantics, ``serve_lora`` shadow split, and
        ``serve_adapter_idx`` multi-tenant row selection)."""
        logits, new_caches = self.model.decode_step_paged(
            params, lora if serve_lora is None else serve_lora,
            caches, token, pos, block_tables,
            ring_len=ring_len, attn_backend=attn_backend,
            adapter_idx=serve_adapter_idx)
        new_lora, new_opt, metrics = self.train_step(
            params, lora, opt_state, train_batch, grad_accum=grad_accum,
            train_tokens=train_tokens)
        return new_lora, new_opt, logits, new_caches, metrics

    def combined_prefill_step(self, params: Any, lora: Any,
                              opt_state: AdamWState, train_batch: Any,
                              infer_batch: Any
                              ) -> Tuple[Any, AdamWState, jax.Array,
                                         Any, Dict[str, jax.Array]]:
        """Fused train + prefill variant (used when the co-located
        inference work is prompt processing rather than decode)."""
        logits, caches = self.model.prefill(params, lora, infer_batch)
        new_lora, new_opt, metrics = self.train_step(
            params, lora, opt_state, train_batch)
        return new_lora, new_opt, logits, caches, metrics


def make_engine(cfg: ModelConfig, lr: float = 1e-4,
                weight_decay: float = 0.0) -> Engine:
    return Engine(model=build(cfg),
                  optimizer=AdamW(lr=lr, weight_decay=weight_decay))
