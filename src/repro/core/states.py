"""Replica state management (paper §3 Fig. 7, §4.1).

Three system states — SERVING, IDLE, COMBINED — with the transition
conditions of Eq. 1–4:

  SERVING → IDLE      EWMA utilization AND EWMA queue length both under
                      the cluster α-quantile thresholds (Eq. 1), with
                      U_switch capped by the constant bound U^L = 0.25.
  IDLE → SERVING      unselected by the Launcher for T' consecutive
                      decisions, or promoted by the Dispatcher under
                      load (overload mitigation §6.2).
  IDLE → COMBINED     selected into an FL PEFT cohort (§4.2).
  COMBINED → SERVING  early-stopped (§4.3) or fine-tuning suspended
                      under saturation.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Dict, List, Optional, Sequence

import numpy as np


class ReplicaState(str, enum.Enum):
    SERVING = "serving"
    IDLE = "idle"
    COMBINED = "combined"


@dataclasses.dataclass
class EWMAWindow:
    """Exponentially-weighted moving average over a sliding window of T
    steps with time-decay weights ω_{t'} (Eq. 2)."""
    window: int = 12            # T
    decay: float = 0.35         # λ

    def __post_init__(self) -> None:
        self._values: List[float] = []

    def observe(self, value: float) -> None:
        self._values.append(float(value))
        if len(self._values) > self.window:
            self._values = self._values[-self.window:]

    @property
    def value(self) -> float:
        if not self._values:
            return 0.0
        n = len(self._values)
        # ω_{t'} ∝ exp(-λ (t - t')), normalized over the window
        w = np.exp(-self.decay * np.arange(n - 1, -1, -1, dtype=np.float64))
        w /= w.sum()
        return float(np.dot(w, np.asarray(self._values)))

    def reset(self) -> None:
        self._values.clear()


@dataclasses.dataclass
class StatePolicy:
    """Transition thresholds (Eq. 1–4)."""
    quantile: float = 0.25          # α-quantile across the cluster
    util_lower_bound: float = 0.25  # U^L
    window: int = 12                # T (EWMA window)
    decay: float = 0.35             # λ
    rollback_rounds: int = 3        # T' (IDLE → SERVING if unselected)

    # below this, the whole cluster counts as idle and the quantile gate
    # (Eq. 3) is floored — otherwise identical near-zero EWMAs tie and
    # Eq. 1's strict inequality can never fire (degenerate-trough case).
    idle_floor: float = 0.02

    def thresholds(self, utils: Sequence[float], queues: Sequence[float]
                   ) -> tuple:
        """U_switch (Eq. 3) and q_switch (Eq. 4) from cluster EWMAs."""
        if not utils:
            return self.util_lower_bound, 0.0
        u_q = float(np.quantile(np.asarray(utils), self.quantile))
        q_q = float(np.quantile(np.asarray(queues), self.quantile))
        u_switch = min(max(u_q, self.idle_floor), self.util_lower_bound)
        return u_switch, q_q


@dataclasses.dataclass
class ReplicaStateTracker:
    """Per-replica state + EWMA telemetry, owned by the cluster manager."""
    replica_id: str
    policy: StatePolicy
    state: ReplicaState = ReplicaState.SERVING

    def __post_init__(self) -> None:
        self.util_ewma = EWMAWindow(self.policy.window, self.policy.decay)
        self.queue_ewma = EWMAWindow(self.policy.window, self.policy.decay)
        self.unselected_rounds = 0
        self.state_since: float = 0.0

    def observe(self, utilization: float, queue_len: float) -> None:
        self.util_ewma.observe(utilization)
        self.queue_ewma.observe(queue_len)

    def should_idle(self, u_switch: float, q_switch: float) -> bool:
        """Eq. 1: Ũ < U_switch and q̃ ≤ q_switch (≤ so the empty-queue
        cluster state — everyone at q̃ = 0 — can still idle)."""
        if self.state is not ReplicaState.SERVING:
            return False
        return (self.util_ewma.value < u_switch
                and self.queue_ewma.value <= q_switch)


class ClusterStateManager:
    """Evaluates Eq. 1–4 across the cluster each monitoring tick and owns
    every replica's state variable."""

    def __init__(self, policy: Optional[StatePolicy] = None) -> None:
        self.policy = policy or StatePolicy()
        self.trackers: Dict[str, ReplicaStateTracker] = {}

    # -- registry ------------------------------------------------------------
    def register(self, replica_id: str,
                 state: ReplicaState = ReplicaState.SERVING
                 ) -> ReplicaStateTracker:
        t = ReplicaStateTracker(replica_id, self.policy, state)
        self.trackers[replica_id] = t
        return t

    def remove(self, replica_id: str) -> None:
        self.trackers.pop(replica_id, None)

    def state_of(self, replica_id: str) -> ReplicaState:
        return self.trackers[replica_id].state

    def replicas_in(self, state: ReplicaState) -> List[str]:
        return [r for r, t in self.trackers.items() if t.state is state]

    # -- telemetry + transitions ----------------------------------------------
    def observe(self, replica_id: str, utilization: float,
                queue_len: float) -> None:
        self.trackers[replica_id].observe(utilization, queue_len)

    def evaluate_idle_transitions(self, now: float) -> List[str]:
        """SERVING → IDLE per Eq. 1–4.  Returns newly-idled replica ids.
        At least one replica is always kept SERVING per model pool — the
        dispatcher needs a target (paper keeps serving capacity alive via
        the q-quantile construction; we make the floor explicit)."""
        serving = self.replicas_in(ReplicaState.SERVING)
        if len(serving) <= 1:
            return []
        utils = [self.trackers[r].util_ewma.value for r in self.trackers]
        queues = [self.trackers[r].queue_ewma.value for r in self.trackers]
        u_sw, q_sw = self.policy.thresholds(utils, queues)
        newly_idle = []
        for rid in serving:
            if len(serving) - len(newly_idle) <= 1:
                break
            if self.trackers[rid].should_idle(u_sw, q_sw):
                self.transition(rid, ReplicaState.IDLE, now)
                newly_idle.append(rid)
        return newly_idle

    def transition(self, replica_id: str, state: ReplicaState,
                   now: float) -> None:
        t = self.trackers[replica_id]
        t.state = state
        t.state_since = now
        t.unselected_rounds = 0
        if state is ReplicaState.SERVING:
            # fresh telemetry after a role change
            t.util_ewma.reset()
            t.queue_ewma.reset()

    def tick_unselected(self, selected_ids: Sequence[str], now: float
                        ) -> List[str]:
        """Launcher decision round: IDLE replicas not selected for T'
        consecutive rounds revert to SERVING.  Returns reverted ids."""
        reverted = []
        for rid in self.replicas_in(ReplicaState.IDLE):
            t = self.trackers[rid]
            if rid in selected_ids:
                t.unselected_rounds = 0
                continue
            t.unselected_rounds += 1
            if t.unselected_rounds >= self.policy.rollback_rounds:
                self.transition(rid, ReplicaState.SERVING, now)
                reverted.append(rid)
        return reverted

    def promote_idle(self, now: float) -> Optional[str]:
        """Dispatcher overload mitigation: IDLE → SERVING immediately."""
        idle = self.replicas_in(ReplicaState.IDLE)
        if not idle:
            return None
        rid = idle[0]
        self.transition(rid, ReplicaState.SERVING, now)
        return rid
