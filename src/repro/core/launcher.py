"""Fine-tune Task Launcher (paper §4).

Watches IDLE replicas; when ≥ ``min_cohort`` IDLE replicas serve the same
model it opens a FederatedSession (server = highest quality score),
transitions members to COMBINED and creates an Inference-Training
Coordinator for the session.  Rounds run asynchronously against the
cluster clock: member training time is billed by the replica (the
simulator advances its busy timeline; live replicas actually step), and
aggregation fires when the slowest member finishes (stragglers are
early-stopped by §4.3 or shed by the cohort-size check).

Load surges suspend sessions (§8.2: "CoLLM temporarily halts fine-tuning
to prioritize inference") via ``suspend_for_model``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.coordinator import (
    CoordinatorConfig, InferenceTrainingCoordinator,
)
from repro.core.federated import FederatedSession, FLRoundResult
from repro.core.interfaces import ReplicaHandle, TrainRoundStats
from repro.core.states import ClusterStateManager, ReplicaState


@dataclasses.dataclass
class LauncherConfig:
    min_cohort: int = 3
    slo: float = 0.5
    coordinator: CoordinatorConfig = dataclasses.field(
        default_factory=CoordinatorConfig)
    max_rounds: int = 1000
    decision_interval: float = 5.0   # launcher decision cadence (T' counts
                                     # these decisions, not control ticks)


@dataclasses.dataclass
class ActiveSession:
    session: FederatedSession
    coordinator: InferenceTrainingCoordinator
    round_done_at: float
    pending: List[FLRoundResult] = dataclasses.field(default_factory=list)


class FineTuneTaskLauncher:
    _ids = itertools.count()

    def __init__(self, cfg: LauncherConfig,
                 replicas: Dict[str, ReplicaHandle],
                 states: ClusterStateManager,
                 global_adapters: Dict[str, Any],
                 on_adapter_update: Callable[[str, Any, int], None]
                 = lambda model_id, adapter, version: None):
        self.cfg = cfg
        self.replicas = replicas
        self.states = states
        self.global_adapters = global_adapters   # model_id -> adapter tree
        self.on_adapter_update = on_adapter_update
        # τ' provider for Eq. 12 — wired to dispatcher queue telemetry by
        # the cluster controller; defaults to the raw SLO.
        self.budget_fn: Callable[[], float] = lambda: self.cfg.slo
        self.sessions: Dict[str, ActiveSession] = {}
        self.adapter_versions: Dict[str, int] = {}
        self.completed_rounds = 0
        self._next_decision = 0.0

    # ------------------------------------------------------------ helpers --
    def _idle_by_model(self) -> Dict[str, List[str]]:
        out: Dict[str, List[str]] = {}
        for rid in self.states.replicas_in(ReplicaState.IDLE):
            model = self.replicas[rid].model_id
            out.setdefault(model, []).append(rid)
        return out

    def session_for(self, replica_id: str) -> Optional[ActiveSession]:
        for a in self.sessions.values():
            if replica_id in a.session.members:
                return a
        return None

    # -------------------------------------------------------------- launch --
    def maybe_launch(self, now: float) -> List[str]:
        """§4.2 — open sessions for models with ≥ min_cohort IDLE
        replicas.  Returns ids of all replicas selected this decision."""
        selected: List[str] = []
        in_session = {m for a in self.sessions.values()
                      for m in a.session.members}
        for model_id, idle in self._idle_by_model().items():
            idle = [r for r in idle if r not in in_session]
            if len(idle) < self.cfg.min_cohort:
                continue
            # server = member with the highest quality score
            server = max(idle,
                         key=lambda r: self.replicas[r].quality_score(now))
            adapter = self.global_adapters.get(model_id)
            if adapter is None:
                adapter = self.replicas[server].get_adapter()
                self.global_adapters[model_id] = adapter
            session = FederatedSession(model_id, idle, server, adapter,
                                       min_cohort=self.cfg.min_cohort)
            coord = InferenceTrainingCoordinator(
                f"fl-{next(self._ids)}", idle, self.cfg.slo,
                self.cfg.coordinator)
            active = ActiveSession(session, coord, round_done_at=now)
            self.sessions[coord.session_id] = active
            for rid in idle:
                self.states.transition(rid, ReplicaState.COMBINED, now)
            self._start_round(active, now)
            selected.extend(idle)
        # T' rollback for IDLE replicas that keep being passed over
        self.states.tick_unselected(selected, now)
        return selected

    # --------------------------------------------------------------- rounds -
    def _start_round(self, active: ActiveSession, now: float) -> None:
        sess, coord = active.session, active.coordinator
        version = self.adapter_versions.get(sess.model_id, 0)
        active.pending = []
        done = now
        for rid in list(sess.members):
            handle = self.replicas[rid]
            handle.set_adapter(sess.global_adapter, version)
            plan = coord.plan_for(rid)
            stats = handle.train_round(plan.train_batch, plan.infer_batch,
                                       coord.steps_per_round, now)
            coord.observe_train(stats)
            active.pending.append(FLRoundResult(
                replica_id=rid, adapter=handle.get_adapter(),
                local_loss=stats.loss_after, samples=stats.samples,
                train_time=stats.steps * stats.avg_step_time))
            done = max(done, now + stats.steps * stats.avg_step_time)
        active.round_done_at = done

    def _finish_round(self, active: ActiveSession, now: float) -> None:
        sess, coord = active.session, active.coordinator
        new_global = sess.aggregate(active.pending)
        version = self.adapter_versions.get(sess.model_id, 0) + 1
        self.adapter_versions[sess.model_id] = version
        self.global_adapters[sess.model_id] = new_global
        self.on_adapter_update(sess.model_id, new_global, version)
        # model sharing: COMBINED members serve with the fresh adapter
        # immediately (the paper's continuous-adaptation mechanism)
        for rid in list(sess.members):
            self.replicas[rid].set_adapter(new_global, version)
        stopped = sess.early_stops(active.pending)
        for rid in stopped:
            coord.drop_replica(rid)
            self.states.transition(rid, ReplicaState.SERVING, now)
        self.completed_rounds += 1
        if not sess.alive or sess.round >= self.cfg.max_rounds:
            self._dissolve(active, now)
            return
        coord.replan(self.budget_fn())
        self._start_round(active, now)

    def _dissolve(self, active: ActiveSession, now: float) -> None:
        for rid in list(active.session.members):
            self.states.transition(rid, ReplicaState.SERVING, now)
        self.sessions.pop(active.coordinator.session_id, None)

    def suspend_for_model(self, model_id: str, now: float) -> int:
        """Load surge: halt fine-tuning for a model, release replicas."""
        n = 0
        for sid in list(self.sessions):
            a = self.sessions[sid]
            if a.session.model_id == model_id:
                self._dissolve(a, now)
                n += 1
        return n

    # ------------------------------------------------------------ the loop -
    def on_tick(self, now: float) -> None:
        for sid in list(self.sessions):
            active = self.sessions.get(sid)
            if active and now >= active.round_done_at and active.pending:
                self._finish_round(active, now)
        if now >= self._next_decision:
            self.maybe_launch(now)
            self._next_decision = now + self.cfg.decision_interval
