"""Fine-tune Task Launcher (paper §4).

Watches IDLE replicas; when ≥ ``min_cohort`` IDLE replicas serve the same
model it opens a FederatedSession (server = highest quality score),
transitions members to COMBINED and creates an Inference-Training
Coordinator for the session.

Rounds are NON-BLOCKING: ``_start_round`` begins an incremental train
session on every member (``ReplicaHandle.begin_round`` — live replicas
advance one fused combined_step per fabric tick, the simulator bills its
analytic timeline) and ``_maybe_finish_round`` POLLS session progress on
every launcher tick instead of calling ``train_round`` synchronously.
Members complete asynchronously: each finished member's stats feed the
Coordinator and its trained shadow is published locally
(``publish_adapter`` — its own round boundary); aggregation fires when
the SLOWEST member finishes and pushes the merged adapter to every
member (stragglers are early-stopped by §4.3 or shed by the cohort-size
check).

Load surges suspend sessions (§8.2: "CoLLM temporarily halts fine-tuning
to prioritize inference") via ``suspend_for_model``; suspended members
discard their shadow state (``abort_round``) and keep serving the last
PUBLISHED adapter.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core.coordinator import (
    CoordinatorConfig, InferenceTrainingCoordinator,
)
from repro.core.federated import FederatedSession, FLRoundResult
from repro.core.interfaces import ReplicaHandle
from repro.core.states import ClusterStateManager, ReplicaState


@dataclasses.dataclass
class LauncherConfig:
    min_cohort: int = 3
    slo: float = 0.5
    coordinator: CoordinatorConfig = dataclasses.field(
        default_factory=CoordinatorConfig)
    max_rounds: int = 1000
    decision_interval: float = 5.0   # launcher decision cadence (T' counts
                                     # these decisions, not control ticks)


@dataclasses.dataclass
class ActiveSession:
    session: FederatedSession
    coordinator: InferenceTrainingCoordinator
    round_started_at: float
    pending: List[FLRoundResult] = dataclasses.field(default_factory=list)
    # members whose incremental session has not completed this round
    in_flight: List[str] = dataclasses.field(default_factory=list)


class FineTuneTaskLauncher:
    _ids = itertools.count()

    def __init__(self, cfg: LauncherConfig,
                 replicas: Dict[str, ReplicaHandle],
                 states: ClusterStateManager,
                 global_adapters: Dict[str, Any],
                 on_adapter_update: Callable[[str, Any, int], None]
                 = lambda model_id, adapter, version: None) -> None:
        self.cfg = cfg
        self.replicas = replicas
        self.states = states
        self.global_adapters = global_adapters   # model_id -> adapter tree
        self.on_adapter_update = on_adapter_update
        # τ' provider for Eq. 12 — wired to dispatcher queue telemetry by
        # the cluster controller; defaults to the raw SLO.
        self.budget_fn: Callable[[], float] = lambda: self.cfg.slo
        self.sessions: Dict[str, ActiveSession] = {}
        self.adapter_versions: Dict[str, int] = {}
        self.completed_rounds = 0
        # aggregation log: model_id / round / version / avg member loss
        # per completed round — quality-progression telemetry for the
        # fabric summary and benchmarks
        self.round_history: List[Dict[str, Any]] = []
        self._next_decision = 0.0

    # ------------------------------------------------------------ helpers --
    def _idle_by_model(self) -> Dict[str, List[str]]:
        out: Dict[str, List[str]] = {}
        for rid in self.states.replicas_in(ReplicaState.IDLE):
            model = self.replicas[rid].model_id
            out.setdefault(model, []).append(rid)
        return out

    def session_for(self, replica_id: str) -> Optional[ActiveSession]:
        for a in self.sessions.values():
            if replica_id in a.session.members:
                return a
        return None

    # -------------------------------------------------------------- launch --
    def maybe_launch(self, now: float) -> List[str]:
        """§4.2 — open sessions for models with ≥ min_cohort IDLE
        replicas.  Returns ids of all replicas selected this decision."""
        selected: List[str] = []
        in_session = {m for a in self.sessions.values()
                      for m in a.session.members}
        for model_id, idle in self._idle_by_model().items():
            idle = [r for r in idle if r not in in_session]
            if len(idle) < self.cfg.min_cohort:
                continue
            # server = member with the highest quality score
            server = max(idle,
                         key=lambda r: self.replicas[r].quality_score(now))
            adapter = self.global_adapters.get(model_id)
            if adapter is None:
                adapter = self.replicas[server].get_adapter()
                self.global_adapters[model_id] = adapter
            session = FederatedSession(model_id, idle, server, adapter,
                                       min_cohort=self.cfg.min_cohort)
            coord = InferenceTrainingCoordinator(
                f"fl-{next(self._ids)}", idle, self.cfg.slo,
                self.cfg.coordinator)
            active = ActiveSession(session, coord, round_started_at=now)
            self.sessions[coord.session_id] = active
            for rid in idle:
                self.states.transition(rid, ReplicaState.COMBINED, now)
            self._start_round(active, now)
            selected.extend(idle)
        # T' rollback for IDLE replicas that keep being passed over
        self.states.tick_unselected(selected, now)
        return selected

    # --------------------------------------------------------------- rounds -
    def _start_round(self, active: ActiveSession, now: float) -> None:
        """Begin an incremental session on every member — no member
        blocks the caller; the fabric/simulator advances them and
        ``_maybe_finish_round`` polls."""
        sess, coord = active.session, active.coordinator
        version = self.adapter_versions.get(sess.model_id, 0)
        active.pending = []
        active.in_flight = list(sess.members)
        active.round_started_at = now
        for rid in active.in_flight:
            handle = self.replicas[rid]
            handle.set_adapter(sess.global_adapter, version)
            plan = coord.plan_for(rid)
            handle.begin_round(plan.train_batch, plan.infer_batch,
                               coord.steps_per_round, now)

    def _maybe_finish_round(self, active: ActiveSession,
                            now: float) -> None:
        """Poll member sessions: collect stats and publish each member's
        trained shadow AS IT COMPLETES (rounds stay asynchronous across
        replicas); aggregate once the slowest member is done."""
        sess, coord = active.session, active.coordinator
        for rid in list(active.in_flight):
            if rid not in sess.members or rid not in self.replicas:
                # shed mid-round (failure / overload release): its
                # result never lands; the cohort aggregates without it
                active.in_flight.remove(rid)
                continue
            handle = self.replicas[rid]
            if handle.round_progress(now) < 1.0:
                continue
            stats = handle.finish_round(now)
            coord.observe_train(stats)
            # member round boundary: serve the local update until the
            # merged global arrives (continuous adaptation, §3)
            handle.publish_adapter()
            active.in_flight.remove(rid)
            active.pending.append(FLRoundResult(
                replica_id=rid, adapter=handle.get_adapter(),
                local_loss=stats.loss_after, samples=stats.samples,
                train_time=stats.steps * stats.avg_step_time))
        if active.in_flight:
            return
        if not active.pending:
            # every member left mid-round — nothing to aggregate
            self._dissolve(active, now)
            return
        self._finish_round(active, now)

    def _finish_round(self, active: ActiveSession, now: float) -> None:
        sess, coord = active.session, active.coordinator
        new_global = sess.aggregate(active.pending)
        version = self.adapter_versions.get(sess.model_id, 0) + 1
        self.adapter_versions[sess.model_id] = version
        self.global_adapters[sess.model_id] = new_global
        self.on_adapter_update(sess.model_id, new_global, version)
        # model sharing: COMBINED members serve with the fresh adapter
        # immediately (the paper's continuous-adaptation mechanism)
        for rid in list(sess.members):
            if rid in self.replicas:
                self.replicas[rid].set_adapter(new_global, version)
        # reuse the session's own row so the round label matches
        # FederatedSession.history (aggregate() has already advanced
        # sess.round past the round it just closed)
        self.round_history.append({
            "model_id": sess.model_id,
            "round": sess.history[-1]["round"],
            "version": version,
            "avg_loss": sess.history[-1]["avg_loss"],
            "members": len(active.pending), "finished_at": now})
        stopped = sess.early_stops(active.pending)
        for rid in stopped:
            coord.drop_replica(rid)
            self.states.transition(rid, ReplicaState.SERVING, now)
        self.completed_rounds += 1
        if not sess.alive or sess.round >= self.cfg.max_rounds:
            self._dissolve(active, now)
            return
        coord.replan(self.budget_fn())
        self._start_round(active, now)

    def _dissolve(self, active: ActiveSession, now: float) -> None:
        """End a session (early-stop cascade, cohort collapse, or §8.2
        suspension).  Members still mid-round discard their shadow state
        — serving stays on the last published adapter."""
        for rid in list(active.session.members):
            handle = self.replicas.get(rid)
            if handle is not None and rid in active.in_flight \
                    and hasattr(handle, "abort_round"):
                handle.abort_round(now)
            self.states.transition(rid, ReplicaState.SERVING, now)
        active.in_flight = []
        self.sessions.pop(active.coordinator.session_id, None)

    def suspend_for_model(self, model_id: str, now: float) -> int:
        """Load surge: halt fine-tuning for a model, release replicas."""
        n = 0
        for sid in list(self.sessions):
            a = self.sessions[sid]
            if a.session.model_id == model_id:
                self._dissolve(a, now)
                n += 1
        return n

    # ------------------------------------------------------------ the loop -
    def on_tick(self, now: float) -> None:
        for sid in list(self.sessions):
            active = self.sessions.get(sid)
            if active is not None:
                self._maybe_finish_round(active, now)
        if now >= self._next_decision:
            self.maybe_launch(now)
            self._next_decision = now + self.cfg.decision_interval
