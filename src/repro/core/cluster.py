"""Cluster controller: wires states + launcher + coordinators +
dispatchers into one control plane (paper Fig. 6).

The controller is clock-agnostic: ``tick(now)`` is driven either by the
discrete-event simulator (paper-scale experiments) or by a wall-clock
loop around live JAX replicas (examples/).
"""
from __future__ import annotations

import collections.abc
import dataclasses
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

from repro.core.dispatcher import DispatcherConfig, SubflowDispatcher
from repro.core.interfaces import BatchResult, ReplicaHandle, Request
from repro.core.latency_model import BivariateLatencyModel
from repro.core.launcher import FineTuneTaskLauncher, LauncherConfig
from repro.core.states import ClusterStateManager, ReplicaState, StatePolicy


class StreamReplicaView(collections.abc.Mapping):
    """Live, read-only view of the cluster registry filtered to one
    stream's model.  Dispatchers hold THIS instead of a dict snapshot,
    so ``add_replica`` / ``remove_replica`` join/leave every existing
    stream dispatcher immediately — the old one-time ``dict(...)``
    snapshot meant late-added replicas never received traffic and
    removed ones lingered in ``d.replicas``."""

    def __init__(self, registry: Dict[str, ReplicaHandle], model_id: str) -> None:
        self._registry = registry
        self._model_id = model_id

    def __getitem__(self, rid: str) -> ReplicaHandle:
        h = self._registry[rid]
        if h.model_id != self._model_id:
            raise KeyError(rid)
        return h

    def __iter__(self) -> Iterator[str]:
        return (rid for rid, h in self._registry.items()
                if h.model_id == self._model_id)

    def __len__(self) -> int:
        return sum(1 for _ in self)


@dataclasses.dataclass
class ClusterConfig:
    slo: float = 0.5
    monitor_interval: float = 1.0
    state_policy: StatePolicy = dataclasses.field(default_factory=StatePolicy)
    dispatcher: DispatcherConfig = dataclasses.field(
        default_factory=DispatcherConfig)
    launcher: LauncherConfig = dataclasses.field(
        default_factory=LauncherConfig)
    enable_finetuning: bool = True     # False -> plain SLO-aware serving


class ClusterController:
    def __init__(self, cfg: ClusterConfig) -> None:
        self.cfg = cfg
        cfg.dispatcher.slo = cfg.slo
        cfg.launcher.slo = cfg.slo
        self.replicas: Dict[str, ReplicaHandle] = {}
        self.states = ClusterStateManager(cfg.state_policy)
        self.global_adapters: Dict[str, Any] = {}
        self.launcher = FineTuneTaskLauncher(
            cfg.launcher, self.replicas, self.states, self.global_adapters)
        self.launcher.budget_fn = self._latency_budget
        self.dispatchers: Dict[str, SubflowDispatcher] = {}
        self._next_monitor = 0.0
        # optional runtime.fault.RetryPolicy: when set, every request a
        # dying replica hands back is charged one retry (+ one failure)
        # before re-queueing; budget-exhausted / poison requests are
        # terminally rejected instead of requeued
        self.retry_policy = None

    def _latency_budget(self) -> float:
        """τ' = (τ − T̄_queue) × headroom for the Coordinator's Eq. 12.
        The 0.9 headroom absorbs latency-model noise so b* doesn't sit
        exactly on the SLO boundary (half of noisy batches would miss)."""
        tq = max((d.avg_queue_latency() for d in self.dispatchers.values()),
                 default=0.0)
        return max(self.cfg.slo - tq, 0.1 * self.cfg.slo) * 0.9

    # ------------------------------------------------------------ registry -
    def add_replica(self, handle: ReplicaHandle,
                    state: ReplicaState = ReplicaState.SERVING) -> None:
        self.replicas[handle.replica_id] = handle
        self.states.register(handle.replica_id, state)

    def remove_replica(self, replica_id: str, now: float) -> None:
        """Elastic scale-down / failure: drop the replica everywhere and
        requeue its accepted-but-unfinished requests on the surviving
        pool (failover — no request is lost).  In-session members are
        handled by the session's cohort check."""
        handle = self.replicas.get(replica_id)
        active = self.launcher.session_for(replica_id)
        if active is not None:
            if replica_id in active.session.members:
                active.session.members.remove(replica_id)
            active.coordinator.drop_replica(replica_id)
        self.states.remove(replica_id)
        self.replicas.pop(replica_id, None)
        # failover AFTER the registry drop (requeued requests must only
        # ever be re-placed on survivors) but BEFORE the dispatcher
        # cleanup: the drain emits BatchResults for already-finished
        # generations, which would otherwise resurrect latency-model
        # entries for the dead replica
        if handle is not None and hasattr(handle, "drain_pending"):
            drained = handle.drain_pending(now)
            if self.retry_policy is not None:
                # the replica DIED with these accepted: charge the
                # retry budget + failure count; poison / exhausted
                # requests drop out here with a terminal status
                drained = self.retry_policy.filter_requeue(
                    drained, now, replica_died=True)
            by_stream: Dict[str, List[Request]] = {}
            for req in drained:
                by_stream.setdefault(req.stream_id, []).append(req)
            for sid, reqs in by_stream.items():
                self.dispatcher_for(sid).requeue(reqs)
        for d in self.dispatchers.values():
            d.subflows.pop(replica_id, None)
            d.latency_models.pop(replica_id, None)

    # ---------------------------------------------------------- dispatching -
    def dispatcher_for(self, stream_id: str) -> SubflowDispatcher:
        d = self.dispatchers.get(stream_id)
        if d is None:
            d = SubflowDispatcher(
                stream_id, self.cfg.dispatcher,
                replicas=self._stream_replicas(stream_id),
                state_of=self.states.state_of,
                promote_idle=self._promote_idle,
                combined_plan=self._combined_plan)
            self.dispatchers[stream_id] = d
        return d

    def _stream_replicas(self, stream_id: str) -> StreamReplicaView:
        """Serviceable replicas: those with the stream's model deployed —
        as a LIVE view over the registry, shared with the dispatcher.
        stream_id convention: "<model_id>" or "<model_id>/<slo-class>"."""
        return StreamReplicaView(self.replicas, stream_id.split("/")[0])

    def submit_request(self, req: Request) -> None:
        self.dispatcher_for(req.stream_id).submit(req)

    def on_batch_result(self, result: BatchResult, stream_id: str) -> None:
        d = self.dispatchers.get(stream_id)
        if d is not None:
            d.on_batch_result(result)
        active = self.launcher.session_for(result.replica_id)
        if active is not None:
            active.coordinator.observe_infer(result)

    # ------------------------------------------------------------ callbacks -
    def _promote_idle(self, now: float) -> Optional[str]:
        rid = self.states.promote_idle(now)
        if rid is None and self.cfg.enable_finetuning:
            # no IDLE spare: release a COMBINED replica from fine-tuning
            for active in list(self.launcher.sessions.values()):
                if active.session.members:
                    victim = active.session.members[0]
                    active.session.members.remove(victim)
                    active.coordinator.drop_replica(victim)
                    handle = self.replicas.get(victim)
                    if handle is not None \
                            and hasattr(handle, "abort_round"):
                        # mid-round release: the victim sheds its shadow
                        # state and serves the last published adapter
                        handle.abort_round(now)
                    if not active.session.alive:
                        self.launcher._dissolve(active, now)
                    self.states.transition(victim, ReplicaState.SERVING, now)
                    return victim
        return rid

    def _combined_plan(self, rid: str
                       ) -> Optional[Tuple[int, BivariateLatencyModel]]:
        active = self.launcher.session_for(rid)
        if active is None:
            return None
        plan = active.coordinator.plans.get(rid)
        if plan is None:
            return None
        return plan.infer_batch, active.coordinator.infer_model_for(rid)

    # ------------------------------------------------------------ the loop -
    def tick(self, now: float) -> None:
        if now >= self._next_monitor:
            for rid, h in self.replicas.items():
                self.states.observe(rid, h.utilization(now),
                                    h.queue_length(now))
            if self.cfg.enable_finetuning:
                self.states.evaluate_idle_transitions(now)
            self._next_monitor = now + self.cfg.monitor_interval
        if self.cfg.enable_finetuning:
            self.launcher.on_tick(now)
        for d in self.dispatchers.values():
            d.on_tick(now)
