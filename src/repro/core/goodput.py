"""Training goodput (paper §5.1, extending Pollux) and the constrained
(B*, b*) optimization (Eq. 11–12).

  GOODPUT_t(B, b) = THROUGHPUT(B, b) × EFFICIENCY_t(B)
  THROUGHPUT      = B / T_train(B, b)                        (Eq. 7)
  EFFICIENCY_t(B) = (a·p_t·l_t + B0) / (a·p_t·l_t + B)       (Eq. 8)

p_t is the gradient-noise scale, l_t the average per-iteration loss
reduction; both come from Coordinator telemetry.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.core.latency_model import BivariateLatencyModel


@dataclasses.dataclass
class EfficiencyParams:
    scale_a: float = 1.0       # a in Eq. 8
    init_batch: int = 4        # B0 in Eq. 8
    noise_scale: float = 1.0   # p_t
    loss_reduction: float = 0.1  # l_t


def efficiency(train_batch: float, p: EfficiencyParams) -> float:
    apl = p.scale_a * max(p.noise_scale, 0.0) * max(p.loss_reduction, 0.0)
    return (apl + p.init_batch) / (apl + max(train_batch, 1e-9))


def throughput(train_batch: float, infer_batch: float,
               t_train: BivariateLatencyModel) -> float:
    lat = t_train.predict(train_batch, infer_batch)
    if lat <= 1e-9:
        return 0.0
    return train_batch / lat


def goodput(train_batch: float, infer_batch: float,
            t_train: BivariateLatencyModel, p: EfficiencyParams) -> float:
    return throughput(train_batch, infer_batch, t_train) \
        * efficiency(train_batch, p)


def optimize(t_train: BivariateLatencyModel,
             t_infer: BivariateLatencyModel,
             p: EfficiencyParams, latency_budget: float, *,
             train_batches: Sequence[int] = tuple(range(1, 65)),
             infer_cap: int = 256) -> Tuple[int, int, float]:
    """Grid-search (B*, b*) = argmax_B GOODPUT(B, b*(B))   (Eq. 11).

    For each candidate B, b*(B) is the largest inference batch whose
    predicted latency under interference stays within the budget
    (Eq. 12); replicas must keep serving, so B with b*(B) == 0 are
    rejected unless nothing else is feasible.
    """
    best: Tuple[int, int, float] = (0, 0, -1.0)
    for big_b in train_batches:
        b_star = t_infer.max_x1(latency_budget, big_b, floor=0,
                                cap=infer_cap)
        if b_star <= 0:
            continue
        g = goodput(big_b, b_star, t_train, p)
        if g > best[2]:
            best = (int(big_b), int(b_star), float(g))
    if best[2] < 0:  # nothing feasible: train minimally, serve minimally
        return 1, 1, goodput(1, 1, t_train, p)
    return best
