"""The control-plane ↔ data-plane seam.

CoLLM's components (Launcher / Coordinator / Dispatcher) operate on this
protocol only; ``runtime.replica`` provides two implementations:
``SimReplica`` (discrete-event, analytic latency surfaces — the paper's
testbed proxy) and ``LiveReplica`` (real JAX steps on reduced models).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Sequence, runtime_checkable


@dataclasses.dataclass
class Request:
    """One inference request of a stream (paper §6.1)."""
    request_id: int
    stream_id: str              # requests sharing (model, SLO) form a stream
    arrival: float              # a_r
    deadline: float             # d_r
    tokens: int = 128           # output length (token-level goodput §8.1)
    dispatched: bool = False
    dispatch_time: Optional[float] = None   # when a subflow picked it up
    completed_at: Optional[float] = None
    quality: float = 0.0        # response quality when served (1/CE)
    # live serving: concrete prompt token ids ([P] int32).  None on the
    # simulator path (analytic latencies never look at content); live
    # replicas draw from their data distribution when absent.  The
    # dispatcher also reads it for prefix-cache affinity routing.
    prompt: Optional[Any] = None
    # multi-tenant serving: the registered adapter this request's tokens
    # flow through (None = base model).  The dispatcher prefers replicas
    # where the adapter is already device-resident (adapter affinity).
    adapter_id: Optional[str] = None
    # sampling configuration, threaded through to the decode tick
    # (temperature <= 0 is exact greedy — the default)
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    # filled by live replicas on completion: the generated token ids
    # (the multi-replica equivalence gates compare these bit-for-bit)
    output_tokens: Optional[List[int]] = None
    # --- fault-tolerance lifecycle (runtime/fault.py RetryPolicy) ---
    # retries: re-admissions after a failover/quarantine drain handed
    # the request back; failures: how many of those drains were replica
    # DEATHS with this request accepted there (the poison-request
    # signal: a request that kills every replica it lands on must stop
    # being requeued).  ``not_before`` is the exponential-backoff gate —
    # the dispatcher skips the request until the clock passes it.  The
    # SLO clock (arrival/deadline) is NEVER touched by a retry: a
    # re-admitted request keeps its original deadline.
    retries: int = 0
    failures: int = 0
    not_before: float = 0.0
    # "pending" until served or terminally rejected; "failed" is a
    # TERMINAL verdict (retry budget exhausted, poison request, missed
    # deadline) — the fabric loop stops waiting on failed requests
    status: str = "pending"
    failed_reason: Optional[str] = None

    @property
    def slo_met(self) -> bool:
        return self.completed_at is not None \
            and self.completed_at <= self.deadline

    @property
    def terminal(self) -> bool:
        """Served or terminally rejected — either way the control plane
        owes this request nothing further."""
        return self.completed_at is not None or self.status == "failed"


def deadline_slack(deadline: float, now: float) -> float:
    """Remaining SLO slack d_r - now (Eq. 13c's feasibility margin).

    Negative means the deadline has already passed.  Shared by the
    dispatcher's feasibility shedding and the batcher's chunked-prefill
    scheduler so the two rank urgency identically."""
    return deadline - now


def slack_order(items: Sequence[Any], now: float,
                key: Any = None) -> List[Any]:
    """``items`` sorted most-urgent-first by deadline slack.

    ``key`` extracts the deadline from an item (default: its
    ``deadline`` attribute).  Ties keep the input (FCFS) order —
    ``sorted`` is stable."""
    get = key if key is not None else (lambda it: it.deadline)
    return sorted(items, key=lambda it: deadline_slack(get(it), now))


@dataclasses.dataclass
class BatchResult:
    """Completion record for a dispatched batch."""
    replica_id: str
    batch_size: int
    infer_latency: float        # T_infer (processing only)
    total_latency: float        # ℓ = T_infer + T_queue
    queue_latency: float
    finished_at: float
    quality: float              # replica model quality at serve time
    tokens: int
    train_batch: int = 0        # co-running training batch (0 = none)


@dataclasses.dataclass
class TrainRoundStats:
    """Telemetry from one local FL training round (Coordinator inputs)."""
    replica_id: str
    steps: int
    train_batch: int
    infer_batch: int
    avg_step_time: float        # T_train per iteration
    loss_before: float
    loss_after: float
    noise_scale: float          # p_t
    samples: int

    @property
    def loss_reduction(self) -> float:
        """l_t — average per-iteration loss reduction."""
        return max(self.loss_before - self.loss_after, 0.0) \
            / max(self.steps, 1)


@dataclasses.dataclass
class ReplicaPressure:
    """Runtime pressure a replica exports for placement-aware routing.

    ``SimReplica`` fills the slot/queue fields from its event queue;
    ``LiveReplica`` reads them off the continuous batcher + block
    allocator (free pool blocks, reservations, prefix-cache occupancy).
    A contiguous (non-paged) replica reports ``pool_blocks == 0`` and
    full block headroom — admission there is gated by slots only.
    """
    queue_len: int = 0          # accepted but unfinished requests
    pending: int = 0            # admission-queue requests (not ingested)
    active_slots: int = 0
    total_slots: int = 0
    free_blocks: int = 0        # unreserved + unreferenced pool blocks
    reserved_blocks: int = 0    # admission-time worst-case reservations
    pool_blocks: int = 0        # allocator capacity (0 = contiguous)
    cached_blocks: int = 0      # prefix-cache retained/registered blocks
    # max requests one dispatcher fire should hand over right now
    # (None = unbounded; live replicas report their slot-wave headroom
    # so one fire never swallows a whole trace while peers sit idle)
    admit_capacity: Optional[int] = None
    # multi-tenant serving: adapter ids currently DEVICE-resident on
    # this replica's AdapterRegistry — the dispatcher routes a tenant's
    # requests here to skip the host->device adapter load (empty on
    # single-adapter replicas and the simulator)
    resident_adapters: tuple = ()
    # oversubscribed KV pool: the replica's configured oversubscription
    # fraction (0 = preemption-free worst-case reservation) and how
    # many requests it currently holds preempted off-device — a
    # non-zero count means the pool is thrashing and new work should
    # route elsewhere
    oversubscribe: float = 0.0
    preempted: int = 0

    @property
    def slot_headroom(self) -> float:
        if self.total_slots <= 0:
            return 0.0
        return (self.total_slots - self.active_slots) / self.total_slots

    @property
    def block_headroom(self) -> float:
        if self.pool_blocks <= 0:
            return 1.0              # contiguous: blocks never gate
        return self.free_blocks / self.pool_blocks

    def headroom(self) -> float:
        """Scalar placement score: how much more work this replica can
        absorb right now.  Pool headroom dominates (an exhausted pool
        backpressures admission outright), slots break ties, and a deep
        per-replica queue discounts both.  ``queue_len`` already counts
        admission-queue requests, so ``pending`` is not re-added."""
        h = min(self.block_headroom, 1.0) * (0.5 + 0.5 * self.slot_headroom)
        h /= 1.0 + self.queue_len / max(self.total_slots, 1)
        # a thrashing oversubscribed pool (requests parked off-device)
        # discounts hard: every parked request will reclaim capacity
        # the free-block count is still advertising
        return h / (1.0 + self.preempted)


@runtime_checkable
class ReplicaHandle(Protocol):
    """What the CoLLM control plane needs from a replica."""
    replica_id: str
    model_id: str

    # ---- serving -----------------------------------------------------------
    def submit_batch(self, requests: Sequence[Request], now: float) -> None:
        """Enqueue a batch for execution (completion is reported through
        the event loop / completion callbacks)."""
        ...

    def queue_length(self, now: float) -> int: ...

    def outstanding_batches(self, now: float) -> int:
        """Submitted-but-unfinished batches (the dispatcher's in-flight
        backpressure unit — §2.3 double buffering)."""
        ...

    def utilization(self, now: float) -> float:
        """Busy fraction over the last monitoring interval (the TPU/JAX
        stand-in for nvidia-smi SM utilization — DESIGN.md §2)."""
        ...

    # ---- placement signals -------------------------------------------------
    def pressure(self, now: float) -> ReplicaPressure:
        """Runtime pressure snapshot for placement-aware routing."""
        ...

    def prefix_affinity(self, prompt: Any,
                        adapter_id: Optional[str] = None) -> int:
        """Prompt tokens this replica could serve from its prefix cache
        (0 when it has no cache or no match) — the dispatcher routes
        matching requests here to convert prefill into cache hits.
        ``adapter_id`` scopes the lookup to that tenant's cached blocks
        (cached KV is adapter-specific)."""
        ...

    # ---- elasticity / failover ---------------------------------------------
    def reclaim_queued(self, max_n: int, now: float) -> List[Request]:
        """Hand back up to ``max_n`` admission-queue requests that have
        not started executing (micro-cycle rebalancing)."""
        ...

    def drain_pending(self, now: float) -> List[Request]:
        """Failover: stop serving, free all runtime resources, and
        return every accepted-but-unfinished request so the control
        plane can requeue it on a survivor."""
        ...

    # ---- fine-tuning -------------------------------------------------------
    def set_adapter(self, adapter: Any, version: int) -> None:
        """Publish ``adapter`` as the SERVED snapshot immediately (round
        boundaries / deployment only) and discard any staged shadow."""
        ...

    def get_adapter(self) -> Any: ...

    def train_round(self, train_batch: int, infer_batch: int, steps: int,
                    now: float) -> TrainRoundStats:
        """Run one local FL round in COMBINED mode to completion — the
        blocking convenience over the incremental session surface below
        (begin → driven ticks → finish → publish)."""
        ...

    # ---- incremental train sessions ----------------------------------------
    # The non-blocking round surface: the Launcher begins a round, the
    # fabric/simulator advances it (live replicas train one fused
    # combined_step per pump_once tick, interleaved with serving), and
    # the Launcher POLLS progress instead of blocking on train_round —
    # no round ever monopolizes the device.
    def begin_round(self, train_batch: int, infer_batch: int, steps: int,
                    now: float) -> None:
        """Start one local FL round as an incremental session.  Live
        replicas stage a SHADOW copy of the published adapter for the
        optimizer to train; serving keeps reading the published snapshot
        untouched for the whole round."""
        ...

    def round_progress(self, now: float) -> float:
        """Fraction of the active round completed in [0, 1]; 1.0 when no
        session is active."""
        ...

    def finish_round(self, now: float) -> TrainRoundStats:
        """Close the completed session and return its measured stats
        (Coordinator inputs: T_train, losses, noise scale p_t)."""
        ...

    def publish_adapter(self) -> int:
        """Atomically swap the trained shadow into the published slot
        (round boundaries only); returns the served adapter version."""
        ...

    def abort_round(self, now: float) -> None:
        """§8.2 suspension: discard the session + shadow state; the
        served adapter stays at the last published version."""
        ...

    # ---- quality -----------------------------------------------------------
    def quality_score(self, now: float) -> float:
        """Served response quality = 1 / CE-loss (paper §8.1)."""
        ...
