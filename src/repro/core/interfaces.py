"""The control-plane ↔ data-plane seam.

CoLLM's components (Launcher / Coordinator / Dispatcher) operate on this
protocol only; ``runtime.replica`` provides two implementations:
``SimReplica`` (discrete-event, analytic latency surfaces — the paper's
testbed proxy) and ``LiveReplica`` (real JAX steps on reduced models).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Sequence, runtime_checkable


@dataclasses.dataclass
class Request:
    """One inference request of a stream (paper §6.1)."""
    request_id: int
    stream_id: str              # requests sharing (model, SLO) form a stream
    arrival: float              # a_r
    deadline: float             # d_r
    tokens: int = 128           # output length (token-level goodput §8.1)
    dispatched: bool = False
    dispatch_time: Optional[float] = None   # when a subflow picked it up
    completed_at: Optional[float] = None
    quality: float = 0.0        # response quality when served (1/CE)

    @property
    def slo_met(self) -> bool:
        return self.completed_at is not None \
            and self.completed_at <= self.deadline


@dataclasses.dataclass
class BatchResult:
    """Completion record for a dispatched batch."""
    replica_id: str
    batch_size: int
    infer_latency: float        # T_infer (processing only)
    total_latency: float        # ℓ = T_infer + T_queue
    queue_latency: float
    finished_at: float
    quality: float              # replica model quality at serve time
    tokens: int
    train_batch: int = 0        # co-running training batch (0 = none)


@dataclasses.dataclass
class TrainRoundStats:
    """Telemetry from one local FL training round (Coordinator inputs)."""
    replica_id: str
    steps: int
    train_batch: int
    infer_batch: int
    avg_step_time: float        # T_train per iteration
    loss_before: float
    loss_after: float
    noise_scale: float          # p_t
    samples: int

    @property
    def loss_reduction(self) -> float:
        """l_t — average per-iteration loss reduction."""
        return max(self.loss_before - self.loss_after, 0.0) \
            / max(self.steps, 1)


@runtime_checkable
class ReplicaHandle(Protocol):
    """What the CoLLM control plane needs from a replica."""
    replica_id: str
    model_id: str

    # ---- serving -----------------------------------------------------------
    def submit_batch(self, requests: Sequence[Request], now: float) -> None:
        """Enqueue a batch for execution (completion is reported through
        the event loop / completion callbacks)."""
        ...

    def queue_length(self, now: float) -> int: ...

    def utilization(self, now: float) -> float:
        """Busy fraction over the last monitoring interval (the TPU/JAX
        stand-in for nvidia-smi SM utilization — DESIGN.md §2)."""
        ...

    # ---- fine-tuning -------------------------------------------------------
    def set_adapter(self, adapter: Any, version: int) -> None: ...

    def get_adapter(self) -> Any: ...

    def train_round(self, train_batch: int, infer_batch: int, steps: int,
                    now: float) -> TrainRoundStats:
        """Run one local FL round in COMBINED mode (concurrent with
        serving — the fused combined_step on live replicas)."""
        ...

    # ---- quality -----------------------------------------------------------
    def quality_score(self, now: float) -> float:
        """Served response quality = 1 / CE-loss (paper §8.1)."""
        ...
