"""Subflow-based Request Dispatcher (paper §6).

Transforms the bursty arrival stream into per-replica *subflows*, each
pacing batched requests at the replica's Ideal Serving Mode (§2.3:
t(b*) = τ', b* = λ·τ').  Two-phase control:

  macro-cycle (T_fit):    refit the exclusive latency model T(b)=αb+β
                          from served batches (Eq. 14), derive the
                          execution budget τ' = τ − T̄_queue (Eq. 15) and
                          the batch bound b_max = ⌊(τ'−β)/α⌋ (Eq. 16);
                          COMBINED replicas take b_max = b* from the
                          Coordinator and pace with the bivariate model
                          (Eq. 10).  Overload mitigation: T̄_queue ≥ τ−β
                          promotes an IDLE replica and resets T̄_queue
                          to 0.1τ.
  micro-cycle (T_adjust): per-subflow quality-aware reallocation using
                          unsaturation u_i (Eq. 17) and priority
                          Q_i·(1+u_i) (Eq. 18–19), with smoothing
                          bounds, plus queued-request rebalancing:
                          admission-queue work reclaimed from
                          overloaded replicas when a peer is starved.

Placement-aware firing: due subflows drain the stream queue in replica
*headroom* order (``ReplicaHandle.pressure`` — free pool blocks, free
slots, queue depth; least-loaded fallback), each fire is clamped to the
replica's slot-wave ``admit_capacity``, and a request whose prompt
matches a replica's registered prefix-cache chains
(``prefix_affinity``) is routed there so its prefill becomes a cache
hit.

Deviation note: the paper's smoothing range [min(0.5b,2), max(1.5b,b_max)]
has a vacuous upper bound whenever b_max > 1.5b; we use
[max(1, 0.5·b_prev), min(ceil(1.5·b_prev)+1, b_max)] which enforces the
stated intent ("prevent abrupt shifts") in both directions.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.interfaces import (
    BatchResult, ReplicaHandle, ReplicaPressure, Request, deadline_slack,
)
from repro.core.latency_model import BivariateLatencyModel, LinearLatencyModel
from repro.core.states import ReplicaState


@dataclasses.dataclass
class Subflow:
    replica_id: str
    stream_id: str
    batch_size: int = 4            # b_i
    interval: float = 0.25         # I_i
    next_fire: float = 0.0
    b_max: int = 64
    history: Deque[Tuple[int, int]] = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=64))  # (target, got)

    def unsaturation(self) -> float:
        """Eq. 17 — mean underfill fraction over the micro window."""
        if not self.history:
            return 0.0
        vals = [(t - g) / t for t, g in self.history if t > 0]
        return sum(vals) / max(len(vals), 1)


@dataclasses.dataclass
class DispatcherConfig:
    slo: float = 0.5               # τ (0.5 s per request, §8.1)
    t_fit: float = 10.0            # macro-cycle period
    t_adjust: float = 2.0          # micro-cycle period
    queue_window: int = 64         # samples for T̄_queue
    default_interval: float = 0.25
    min_batch: int = 1
    max_batch: int = 64
    bootstrap_b_max: int = 8       # cap until the latency model has fit
    in_flight_limit: int = 1       # batches outstanding per replica
    overload_check: float = 1.0    # seconds between backlog checks


class SubflowDispatcher:
    """One dispatcher per request stream (same model + same SLO)."""

    def __init__(self, stream_id: str, cfg: DispatcherConfig,
                 replicas: Dict[str, ReplicaHandle],
                 state_of: Callable[[str], ReplicaState],
                 promote_idle: Callable[[float], Optional[str]],
                 combined_plan: Callable[
                     [str], Optional[Tuple[int, BivariateLatencyModel]]]
                 = lambda rid: None) -> None:
        self.stream_id = stream_id
        self.cfg = cfg
        self.replicas = replicas
        self.state_of = state_of
        self.promote_idle = promote_idle
        self.combined_plan = combined_plan

        self.queue: Deque[Request] = collections.deque()
        self.subflows: Dict[str, Subflow] = {}
        # quarantined stragglers: rid -> suspension end; suspended
        # replicas keep their subflow/latency state but receive no
        # traffic until the clock passes the mark
        self.suspended: Dict[str, float] = {}
        self.latency_models: Dict[str, LinearLatencyModel] = {}
        self.queue_lat: Deque[float] = collections.deque(
            maxlen=cfg.queue_window)
        self._queue_lat_reset: Optional[float] = None
        self.next_fit = 0.0
        self.next_adjust = 0.0
        self.next_overload_check = 0.0
        # accounting
        self.dispatched = 0
        self.dropped = 0
        self.overload_promotions = 0
        self.affinity_routed = 0       # requests placed by prefix affinity
        self.adapter_routed = 0        # requests placed by adapter residency
        self.rebalanced = 0            # requests reclaimed + requeued

    # ---------------------------------------------------------- ingestion --
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def requeue(self, requests: Sequence[Request]) -> None:
        """Return requests to the FRONT of the stream queue, preserving
        their order — failover re-queue and micro-cycle rebalancing hand
        back the oldest waiting work, which must not lose its place."""
        for r in reversed(list(requests)):
            r.dispatched = False
            r.dispatch_time = None
            self.queue.appendleft(r)

    def queue_depth(self) -> int:
        return len(self.queue)

    # ----------------------------------------------------------- eligibility
    def suspend_replica(self, replica_id: str, until: float) -> None:
        """Quarantine: exclude a replica from routing until ``until``
        (straggler cooldown).  State/subflow survive — quarantine is a
        traffic decision, not membership."""
        self.suspended[replica_id] = max(
            self.suspended.get(replica_id, 0.0), until)

    def _active_replicas(self, now: float) -> List[str]:
        return [rid for rid in self.replicas
                if self.state_of(rid) in (ReplicaState.SERVING,
                                          ReplicaState.COMBINED)
                and self.suspended.get(rid, 0.0) <= now]

    def _ensure_subflow(self, rid: str, now: float) -> Subflow:
        sf = self.subflows.get(rid)
        if sf is None:
            sf = Subflow(replica_id=rid, stream_id=self.stream_id,
                         interval=self.cfg.default_interval,
                         next_fire=now, b_max=self.cfg.bootstrap_b_max)
            self.subflows[rid] = sf
            self.latency_models.setdefault(rid, LinearLatencyModel())
        return sf

    # ------------------------------------------------------------- telemetry
    def on_batch_result(self, result: BatchResult) -> None:
        """Completion feedback: feeds Eq. 14 fits and T̄_queue."""
        m = self.latency_models.setdefault(result.replica_id,
                                           LinearLatencyModel())
        if result.train_batch == 0:
            m.observe(result.batch_size, result.infer_latency)
        self.queue_lat.append(result.queue_latency)

    def avg_queue_latency(self) -> float:
        if self._queue_lat_reset is not None:
            return self._queue_lat_reset
        if not self.queue_lat:
            return 0.0
        return sum(self.queue_lat) / len(self.queue_lat)

    # ------------------------------------------------------------ the loop -
    def on_tick(self, now: float) -> None:
        if now >= self.next_fit:
            self.macro_cycle(now)
            self.next_fit = now + self.cfg.t_fit
        if now >= self.next_adjust:
            self.micro_cycle(now)
            self.next_adjust = now + self.cfg.t_adjust
        if now >= self.next_overload_check:
            self._overload_pressure(now)
            self.next_overload_check = now + self.cfg.overload_check
        self._fire_due_subflows(now)
        self._expire_requests(now)

    def _overload_pressure(self, now: float) -> None:
        """Fast-path overload mitigation (§6.2): when the stream queue
        holds more than ~one SLO period of the active capacity, promote
        an IDLE (or, via the controller fallback, release a COMBINED)
        replica immediately rather than waiting for the macro cycle."""
        active = self._active_replicas(now)
        capacity = sum(self._ensure_subflow(r, now).b_max for r in active)
        if len(self.queue) > max(capacity, 1):
            promoted = self.promote_idle(now)
            if promoted is not None:
                self.overload_promotions += 1
                self._ensure_subflow(promoted, now)

    # -------------------------------------------------------- subflow firing
    def _pressure_of(self, rid: str, now: float
                     ) -> Optional[ReplicaPressure]:
        handle = self.replicas[rid]
        return handle.pressure(now) if hasattr(handle, "pressure") \
            else None

    def _headroom(self, rid: str, now: float,
                  pressure: Optional[ReplicaPressure]) -> float:
        """Placement score for routing order: runtime pressure when the
        replica exports it (free pool blocks / slots / queue depth),
        least-loaded fallback for handles without pressure signals."""
        if pressure is not None:
            return pressure.headroom()
        return 1.0 / (1.0 + self.replicas[rid].queue_length(now))

    def _select_batch(self, rid: str, target: int, now: float,
                      pred: float,
                      pressure: Optional[ReplicaPressure] = None
                      ) -> List[Request]:
        """Pull up to ``target`` feasible requests from the stream queue
        for ``rid``.  Placement-aware: a request whose prompt matches
        the replica's registered prefix-cache chains jumps the scan
        window (its prefill becomes a cache hit *on this replica*), and
        so does a request whose ``adapter_id`` is already DEVICE-
        resident on the replica's AdapterRegistry (admission skips the
        host->device adapter load); everything else stays FCFS.
        Scanned requests that cannot meet their deadline are shed
        (Eq. 13c)."""
        if not self.queue:
            return []
        handle = self.replicas[rid]
        q = list(self.queue)
        order: Sequence[int] = range(len(q))
        prefix_hits: set = set()
        adapter_hits: set = set()
        resident = set(pressure.resident_adapters) \
            if pressure is not None else set()
        probe_prefix = hasattr(handle, "prefix_affinity")
        if probe_prefix or resident:
            lookahead = min(len(q), max(4 * target, 16))
            for i in range(lookahead):
                if probe_prefix and q[i].prompt is not None \
                        and handle.prefix_affinity(
                            q[i].prompt,
                            adapter_id=q[i].adapter_id) > 0:
                    prefix_hits.add(i)
                elif q[i].adapter_id is not None \
                        and q[i].adapter_id in resident:
                    adapter_hits.add(i)
            if prefix_hits or adapter_hits:
                # prefix hits outrank adapter hits: a cached prefix
                # saves prefill compute, residency only a weight load
                hits = sorted(prefix_hits) \
                    + sorted(adapter_hits - prefix_hits)
                hit_set = set(hits)
                order = hits + [i for i in range(len(q))
                                if i not in hit_set]
        batch: List[Request] = []
        taken: set = set()
        for i in order:
            if len(batch) >= target:
                break
            r = q[i]
            if r.not_before > now:
                # retry backoff gate: the request stays queued (keeps
                # its place) but is not dispatchable yet
                continue
            if deadline_slack(r.deadline, now) < pred:
                self._shed(r)
                taken.add(i)
                continue
            r.dispatched = True
            r.dispatch_time = now
            batch.append(r)
            taken.add(i)
            if i in prefix_hits:
                self.affinity_routed += 1
            elif i in adapter_hits:
                self.adapter_routed += 1
        if taken:
            self.queue = collections.deque(
                q[i] for i in range(len(q)) if i not in taken)
        return batch

    def _fire_due_subflows(self, now: float) -> None:
        due: List[str] = []
        for rid in self._active_replicas(now):
            sf = self._ensure_subflow(rid, now)
            if now < sf.next_fire:
                continue
            # Ideal Serving Mode backpressure: at most ``in_flight_limit``
            # batches outstanding (double buffering) — pacing must match
            # the processing envelope, never stack backlog (§2.3).
            handle = self.replicas[rid]
            outstanding = handle.outstanding_batches(now) \
                if hasattr(handle, "outstanding_batches") \
                else handle.queue_length(now)
            if outstanding >= self.cfg.in_flight_limit:
                # "at most in_flight_limit outstanding": firing now
                # would make outstanding+1 — with the default limit of
                # 1 the old ``>`` stacked a third batch behind two
                sf.next_fire = now + min(sf.interval, 0.05)
                continue
            due.append(rid)
        # placement-aware routing: due replicas drain the stream queue
        # in headroom order — pool/slot headroom first, least-loaded as
        # the fallback — so the queue head lands where admission will
        # not backpressure it
        pressures = {rid: self._pressure_of(rid, now) for rid in due}
        if len(due) > 1:
            due.sort(key=lambda r: -self._headroom(r, now, pressures[r]))
        for rid in due:
            sf = self.subflows[rid]
            target = max(self.cfg.min_batch,
                         min(sf.batch_size, sf.b_max))
            p = pressures[rid]
            if p is not None and p.admit_capacity is not None:
                # a live replica's fire is capped at its slot-wave
                # headroom: never hand one replica more than it can
                # start on while peers sit idle
                if p.admit_capacity < 1:
                    sf.next_fire = now + min(sf.interval, 0.05)
                    continue
                target = min(target, p.admit_capacity)
            if p is not None and p.preempted > 0:
                # thrashing oversubscribed pool: requests are parked
                # off-device waiting for capacity — feeding full fires
                # here only deepens the swap churn, so halve the hand
                # per parked request (floor 1 keeps the subflow alive)
                target = max(1, target // (1 + p.preempted))
            # feasibility shedding (Eq. 13c): a request whose deadline
            # cannot be met by this batch contributes nothing — drop it
            # rather than burn capacity serving it late.
            m = self.latency_models[rid]
            pred = m.predict(target) if m.fitted else 0.0
            had_demand = bool(self.queue)
            batch = self._select_batch(rid, target, now, pred,
                                       pressure=p)
            if had_demand:
                # Eq. 17's u_i measures the replica's unsaturation, not
                # the stream's: an empty queue at fire time says nothing
                # about capacity, and recording (target, 0) would inflate
                # u_i and skew micro-cycle priorities toward idle streams
                sf.history.append((target, len(batch)))
            if batch:
                self.replicas[rid].submit_batch(batch, now)
                self.dispatched += len(batch)
            # pace at the replica's processing envelope: I = α·b_actual+β
            b_eff = max(len(batch), 1)
            interval = m.predict(b_eff) if m.fitted \
                else self.cfg.default_interval
            sf.interval = max(min(interval, self.cfg.slo), 1e-3)
            sf.next_fire = now + sf.interval

    def _shed(self, req: Request) -> None:
        """Deadline shed (Eq. 13c): the drop is TERMINAL — stamping the
        status lets the fabric's run loop stop waiting on a request
        that will never complete."""
        req.status = "failed"
        req.failed_reason = "deadline"
        self.dropped += 1

    def _expire_requests(self, now: float) -> None:
        """Requests past their deadline cannot contribute (Eq. 13c) —
        count and drop so they stop occupying capacity."""
        while self.queue and deadline_slack(self.queue[0].deadline, now) < 0:
            self._shed(self.queue.popleft())

    # ------------------------------------------------------------ macro ----
    def macro_cycle(self, now: float) -> None:
        self._queue_lat_reset = None
        tq = self.avg_queue_latency()
        budget = self.cfg.slo - tq                      # Eq. 15
        # stream-level overload mitigation (Eq. 15 margin exhausted):
        # T̄_queue ≥ τ − β ⇒ activate extra capacity, reset T̄_queue := 0.1τ
        betas = [m.beta for m in self.latency_models.values() if m.fitted]
        beta_ref = min(betas) if betas else 0.0
        if tq >= self.cfg.slo - beta_ref and tq > 0:
            promoted = self.promote_idle(now)
            if promoted is not None:
                self.overload_promotions += 1
                self._ensure_subflow(promoted, now)
                self._queue_lat_reset = 0.1 * self.cfg.slo
                # drop the pre-promotion samples too: once the override
                # expires (next macro cycle) a stale window would read
                # as the SAME overload and re-promote immediately —
                # T̄_queue must be re-measured with the new capacity
                self.queue_lat.clear()
                budget = self.cfg.slo - self.avg_queue_latency()
        for rid in self._active_replicas(now):
            sf = self._ensure_subflow(rid, now)
            plan = self.combined_plan(rid) \
                if self.state_of(rid) is ReplicaState.COMBINED else None
            if plan is not None:
                b_star, bivar = plan
                b_cap = int(b_star)
                # until the bivariate model has sample support (bootstrap
                # round), respect the exclusive-model SLO bound so the
                # conservative-start property of §5.2 actually holds
                m0 = self.latency_models[rid]
                if not bivar.fitted and m0.fitted:
                    b_cap = min(b_cap, m0.max_batch(
                        max(budget, 0.05) * 0.9, floor=self.cfg.min_batch,
                        cap=self.cfg.max_batch))
                sf.b_max = max(self.cfg.min_batch,
                               min(b_cap, self.cfg.max_batch))
                # pace with the interference model (Eq. 10)
                train_b = getattr(self.replicas[rid], "train_batch", 0)
                sf.interval = max(
                    min(bivar.predict(sf.batch_size, train_b),
                        self.cfg.slo), 1e-3) if bivar.fitted \
                    else sf.interval
                continue
            m = self.latency_models[rid]
            m.fit()
            if m.fitted:
                sf.b_max = m.max_batch(max(budget, 0.05),
                                       floor=self.cfg.min_batch,
                                       cap=self.cfg.max_batch)
            else:
                sf.b_max = self.cfg.bootstrap_b_max

    # ------------------------------------------------------------ micro ----
    def micro_cycle(self, now: float) -> None:
        active = self._active_replicas(now)
        if not active:
            return
        flows = [self._ensure_subflow(rid, now) for rid in active]
        total_cap = sum(sf.b_max for sf in flows)
        prios = []
        for rid, sf in zip(active, flows):
            q = max(self.replicas[rid].quality_score(now), 1e-6)
            prios.append(q * (1.0 + sf.unsaturation()))      # Eq. 18
        psum = sum(prios) or 1.0
        for sf, p in zip(flows, prios):
            raw = total_cap * p / psum                       # Eq. 19
            prev = sf.batch_size
            lo = max(self.cfg.min_batch, int(0.5 * prev))
            hi = max(lo, min(int(math.ceil(1.5 * prev)) + 1, sf.b_max))
            sf.batch_size = int(min(max(raw, lo), hi))
        self._rebalance_queued(active, flows, now)

    def _rebalance_queued(self, active: List[str], flows: List[Subflow],
                          now: float) -> None:
        """Micro-cycle request rebalancing: when any active replica is
        starved (empty admission queue, free slots) while another holds
        more queued work than its next batch can absorb, the excess is
        reclaimed back to the stream queue — the next fires re-place it
        by headroom, so a routing mistake never strands requests behind
        one slow replica."""
        if len(active) < 2:
            return
        pressures = {rid: self._pressure_of(rid, now) for rid in active}
        starved = any(p is not None and p.pending == 0
                      and p.slot_headroom > 0.0
                      for p in pressures.values())
        if not starved:
            return
        for rid, sf in zip(active, flows):
            p = pressures[rid]
            h = self.replicas[rid]
            if p is None or not hasattr(h, "reclaim_queued"):
                continue
            excess = p.pending - sf.batch_size
            if excess > 0:
                back = h.reclaim_queued(excess, now)
                if back:
                    self.requeue(back)
                    self.rebalanced += len(back)
