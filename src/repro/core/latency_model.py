"""Latency models (paper §2.2, §5.2, §6.2).

* ``LinearLatencyModel``   — T_infer(b) = α·b + β                (Eq. 14)
* ``BivariateLatencyModel``— T(B, b) = α·x₁ + β·x₂ + γ           (Eq. 9/10)

Both are ordinary least squares with a tiny ridge term for stability,
maintain bounded sample windows, and report R² — the paper's own
diagnostic for interference-induced model degradation (0.994 → 0.758 in
Fig. 4b, reproduced by benchmarks/latency_model_fit.py).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Optional, Sequence, Tuple

import numpy as np


def _r2(y: np.ndarray, yhat: np.ndarray) -> float:
    ss_res = float(np.sum((y - yhat) ** 2))
    ss_tot = float(np.sum((y - np.mean(y)) ** 2))
    if ss_tot <= 1e-12:
        return 1.0 if ss_res <= 1e-12 else 0.0
    return 1.0 - ss_res / ss_tot


@dataclasses.dataclass
class LinearLatencyModel:
    """T(b) = alpha * b + beta."""
    alpha: float = 0.0
    beta: float = 0.0
    r2: float = 0.0
    max_samples: int = 512
    ridge: float = 1e-6

    def __post_init__(self) -> None:
        self._samples: Deque[Tuple[float, float]] = collections.deque(
            maxlen=self.max_samples)

    @property
    def fitted(self) -> bool:
        return len(self._samples) >= 2

    def observe(self, batch_size: float, latency: float) -> None:
        self._samples.append((float(batch_size), float(latency)))

    def fit(self) -> Tuple[float, float]:
        if not self.fitted:
            return self.alpha, self.beta
        arr = np.asarray(self._samples, dtype=np.float64)
        x, y = arr[:, 0], arr[:, 1]
        a = np.stack([x, np.ones_like(x)], axis=1)
        ata = a.T @ a + self.ridge * np.eye(2)
        coef = np.linalg.solve(ata, a.T @ y)
        self.alpha, self.beta = float(coef[0]), float(coef[1])
        self.r2 = _r2(y, a @ coef)
        return self.alpha, self.beta

    def predict(self, batch_size: float) -> float:
        return self.alpha * float(batch_size) + self.beta

    def max_batch(self, budget: float, floor: int = 1,
                  cap: int = 4096) -> int:
        """b_max = ⌊(τ' − β)/α⌋   (Eq. 16)."""
        if self.alpha <= 1e-9:
            return cap
        return int(max(floor, min(cap, (budget - self.beta) // self.alpha)))


@dataclasses.dataclass
class BivariateLatencyModel:
    """T(x1, x2) = alpha*x1 + beta*x2 + gamma   (Eq. 9/10).

    For T_infer: x1 = inference batch b, x2 = co-running training batch B.
    For T_train: x1 = training batch B, x2 = co-running inference batch b.
    """
    alpha: float = 0.0
    beta: float = 0.0
    gamma: float = 0.0
    r2: float = 0.0
    max_samples: int = 512
    ridge: float = 1e-6

    def __post_init__(self) -> None:
        self._samples: Deque[Tuple[float, float, float]] = collections.deque(
            maxlen=self.max_samples)

    @property
    def fitted(self) -> bool:
        return len(self._samples) >= 3

    def observe(self, x1: float, x2: float, latency: float) -> None:
        self._samples.append((float(x1), float(x2), float(latency)))

    def fit(self) -> Tuple[float, float, float]:
        if not self.fitted:
            return self.alpha, self.beta, self.gamma
        arr = np.asarray(self._samples, dtype=np.float64)
        x1, x2, y = arr[:, 0], arr[:, 1], arr[:, 2]
        a = np.stack([x1, x2, np.ones_like(x1)], axis=1)
        ata = a.T @ a + self.ridge * np.eye(3)
        coef = np.linalg.solve(ata, a.T @ y)
        self.alpha, self.beta, self.gamma = map(float, coef)
        self.r2 = _r2(y, a @ coef)
        return self.alpha, self.beta, self.gamma

    def predict(self, x1: float, x2: float) -> float:
        return self.alpha * x1 + self.beta * x2 + self.gamma

    def max_x1(self, budget: float, x2: float, floor: int = 0,
               cap: int = 4096) -> int:
        """max x1 with T(x1, x2) <= budget   (Eq. 12)."""
        if self.alpha <= 1e-9:
            return cap
        return int(max(floor,
                       min(cap, (budget - self.beta * x2 - self.gamma)
                           // self.alpha)))
