"""CoLLM core: the paper's contribution.

  states        replica state machine (SERVING/IDLE/COMBINED, Eq. 1-4)
  launcher      Fine-tune Task Launcher + FL PEFT sessions (§4)
  coordinator   Inference-Training Coordinator (§5, Eq. 7-12)
  dispatcher    subflow-based request dispatcher (§6, Eq. 14-19)
  federated     LoRA FedAvg + quality scores + early stopping (§4.2-4.3)
  latency_model uni/bivariate interference-aware latency models (§2.2)
  goodput       Pollux-extended training goodput (§5.1)
  engine        fused combined_step — model sharing as one XLA program
  cluster       controller wiring everything (Fig. 6)
"""
from repro.core.interfaces import (  # noqa: F401
    BatchResult, ReplicaHandle, Request, TrainRoundStats,
)
from repro.core.states import ClusterStateManager, ReplicaState, StatePolicy  # noqa: F401
