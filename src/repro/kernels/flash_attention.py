"""Flash attention (forward) Pallas TPU kernel with GQA, causal masking,
sliding windows, and causal block skipping.

TPU adaptation of the FlashAttention insight: instead of warp-level
softmax reductions, tiles are sized for VMEM residency and the MXU
(q/k blocks are multiples of 128), with the online-softmax running
statistics (m, l) and the output accumulator held in VMEM scratch across
the KV-block loop (innermost grid axis).  Fully-masked (q, kv) block
pairs are skipped with ``pl.when`` — on TPU this prunes the compute but
the (sequential) grid still visits the block, so the win is ~2x FLOPs,
not launch overhead as on GPU.

Layout: q [B, H, Sq, D]; k, v [B, Hkv, Skv, D]; GQA maps head h to KV
head h // (H // Hkv) in the index maps.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            kv_steps: int, sq: int, skv: int):
    i = pl.program_id(2)       # q block
    j = pl.program_id(3)       # kv block

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level mask decisions (static per (i, j) at runtime)
    q_lo = i * bq
    q_hi = q_lo + bq - 1
    k_lo = j * bk
    k_hi = k_lo + bk - 1
    live = jnp.bool_(True)
    if causal:
        live &= k_lo <= q_hi                     # not entirely above diagonal
    if window > 0:
        live &= (q_lo - k_hi) < window           # not entirely too old

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # [bq, D]
        k = k_ref[0, 0].astype(jnp.float32)      # [bk, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < skv                        # kv padding
        mask &= qpos < sq                        # q padding
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0, 0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None, bq: int = 256,
                    bk: int = 256, interpret: bool = False) -> jax.Array:
    """q: [B,H,Sq,D]; k,v: [B,Hkv,Skv,D] -> [B,H,Sq,D]."""
    bsz, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq = min(bq, sq)
    bk = min(bk, skv)
    nq = -(-sq // bq)
    nk = -(-skv // bk)
    qp = nq * bq - sq
    kp = nk * bk - skv
    if qp:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, qp), (0, 0)))
    if kp:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kp), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kp), (0, 0)))

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk,
        kv_steps=nk, sq=sq, skv=skv)
    out = pl.pallas_call(
        kernel,
        grid=(bsz, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b, hh, i, j: (b, hh, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, hh, i, j: (b, hh // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b, hh, i, j: (b, hh // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b, hh, i, j: (b, hh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, nq * bq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denom
            pltpu.VMEM((bq, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
