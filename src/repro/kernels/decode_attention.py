"""Batched decode attention Pallas kernel: one query token per sequence
against a (possibly partially-filled) KV cache.

Decode attention is memory-bound (the whole KV cache streams HBM->VMEM
once per step, arithmetic intensity ~1 FLOP/byte), so the kernel's job is
to keep the streaming dense: KV blocks are walked with the online-softmax
accumulator in VMEM, and blocks entirely beyond ``kv_len`` are skipped
via ``pl.when`` so a short cache in a long buffer doesn't pay for the
empty tail.

Layout: q [B, H, D]; caches [B, Hkv, S, D]; kv_len [B] int32 (per-batch
valid length — ragged batches from the CoLLM dispatcher's subflows).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, bk: int, kv_steps: int, g: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0]
    k_lo = j * bk

    @pl.when(k_lo < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # [G, D]
        k = k_ref[0, 0].astype(jnp.float32)           # [bk, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
        mask = kpos < kv_len
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0, 0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bk", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, scale: Optional[float] = None,
                     bk: int = 512, interpret: bool = False) -> jax.Array:
    """q: [B,H,D]; caches: [B,Hkv,S,D]; kv_len: [B] -> [B,H,D].

    Grid (B, Hkv, S/bk); the G=H/Hkv query heads sharing a KV head ride
    in the same block so the cache is streamed once per KV head.
    """
    bsz, h, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bk = min(bk, s)
    nk = -(-s // bk)
    kp = nk * bk - s
    if kp:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, kp), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, kp), (0, 0)))
    qg = q.reshape(bsz, hkv, g, d)

    kernel = functools.partial(_kernel, scale=scale, bk=bk, kv_steps=nk, g=g)
    out = pl.pallas_call(
        kernel,
        grid=(bsz, hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, hh, j: (b,)),
            pl.BlockSpec((1, 1, g, d), lambda b, hh, j: (b, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, hh, j: (b, hh, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, hh, j: (b, hh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b, hh, j: (b, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(bsz, h, d)
