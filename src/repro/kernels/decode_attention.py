"""Batched decode attention Pallas kernels: one query token per sequence
against a (possibly partially-filled) KV cache.

Decode attention is memory-bound (the whole KV cache streams HBM->VMEM
once per step, arithmetic intensity ~1 FLOP/byte), so the kernels' job is
to keep the streaming dense: KV blocks are walked with the online-softmax
accumulator in VMEM, and blocks entirely beyond ``kv_len`` are skipped
via ``pl.when`` so a short cache in a long buffer doesn't pay for the
empty tail.

Two variants:

``decode_attention``        contiguous caches [B, Hkv, S, D]; grid
                            (B, Hkv, S/bk), one KV head per program.
``paged_decode_attention``  vLLM-style paged caches: a global block pool
                            [n_blocks, block_size, Hkv, D] shared by all
                            sequences, walked through per-sequence block
                            tables [B, NB] (scalar-prefetched to SMEM so
                            the index map can DMA the right block).  A
                            contiguous cache is the special case
                            ``tables[b, j] = b * NB + j`` — which is
                            exactly how ``models.layers.attention_decode``
                            dispatches here without a layout change.

kv_len [B] int32 is the per-sequence valid length (ragged decode slots
from the continuous batcher / CoLLM dispatcher subflows).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, bk: int, kv_steps: int, g: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[0]
    k_lo = j * bk

    @pl.when(k_lo < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # [G, D]
        k = k_ref[0, 0].astype(jnp.float32)           # [bk, D]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
        mask = kpos < kv_len
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0, 0],
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == kv_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bk", "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, scale: Optional[float] = None,
                     bk: int = 512, interpret: bool = False) -> jax.Array:
    """q: [B,H,D]; caches: [B,Hkv,S,D]; kv_len: [B] -> [B,H,D].

    Grid (B, Hkv, S/bk); the G=H/Hkv query heads sharing a KV head ride
    in the same block so the cache is streamed once per KV head.
    """
    bsz, h, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bk = min(bk, s)
    nk = -(-s // bk)
    kp = nk * bk - s
    if kp:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, kp), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, kp), (0, 0)))
    qg = q.reshape(bsz, hkv, g, d)

    kernel = functools.partial(_kernel, scale=scale, bk=bk, kv_steps=nk, g=g)
    out = pl.pallas_call(
        kernel,
        grid=(bsz, hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, hh, j: (b,)),
            pl.BlockSpec((1, 1, g, d), lambda b, hh, j: (b, hh, 0, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, hh, j: (b, hh, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b, hh, j: (b, hh, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda b, hh, j: (b, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qg, k_cache, v_cache)
    return out.reshape(bsz, h, d)


# =========================================================================
# Paged variant: block-table walk over a global block pool
# =========================================================================
def _paged_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *,
                  scale: float, bs: int, nb_steps: int, hkv: int, g: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = len_ref[b]
    k_lo = j * bs

    @pl.when(k_lo < kv_len)
    def _compute():
        k = k_ref[0]                                  # [bs, Hkv, D]
        v = v_ref[0]
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (g, bs), 1)
        mask = kpos < kv_len
        # all KV heads of the block are resident: the block streams from
        # HBM once per sequence, not once per head (the unrolled head
        # loop below reuses it from VMEM)
        for hh in range(hkv):
            qh = q_ref[0, hh].astype(jnp.float32)     # [G, D]
            kh = k[:, hh, :].astype(jnp.float32)      # [bs, D]
            s = jnp.dot(qh, kh.T,
                        preferred_element_type=jnp.float32) * scale
            s = jnp.where(mask, s, NEG_INF)
            m_prev = m_ref[hh]
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
            p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
            corr = jnp.exp(m_prev - m_new)
            l_ref[hh] = l_ref[hh] * corr + jnp.sum(p, axis=1)
            acc_ref[hh] = acc_ref[hh] * corr[:, None] + jnp.dot(
                p.astype(v.dtype), v[:, hh, :],
                preferred_element_type=jnp.float32)
            m_ref[hh] = m_new

    @pl.when(j == nb_steps - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decode_attention(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           kv_len: jax.Array, *,
                           scale: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """q: [B,H,D]; pools: [n_blocks, block_size, Hkv, D]; block_tables:
    [B, NB] int32; kv_len: [B] -> [B,H,D].

    Grid (B, NB): program (b, j) walks logical block j of sequence b by
    DMA-ing pool block ``block_tables[b, j]`` (scalar-prefetch index
    map), with the same online-softmax accumulator as the contiguous
    kernel.  Table entries past a sequence's last live block must be
    valid pool indices (the runtime points them at reserved scratch
    block 0); ``pl.when`` skips their compute via ``kv_len``.
    """
    bsz, h, d = q.shape
    bs, hkv = k_pool.shape[1], k_pool.shape[2]
    nb = block_tables.shape[1]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(bsz, hkv, g, d)

    kernel = functools.partial(_paged_kernel, scale=scale, bs=bs,
                               nb_steps=nb, hkv=hkv, g=g)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bsz, nb),
        in_specs=[
            pl.BlockSpec((1, hkv, g, d),
                         lambda b, j, tbl, lens: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d),
                         lambda b, j, tbl, lens: (tbl[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, hkv, d),
                         lambda b, j, tbl, lens: (tbl[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hkv, g, d),
                               lambda b, j, tbl, lens: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hkv, g), jnp.float32),
            pltpu.VMEM((hkv, g), jnp.float32),
            pltpu.VMEM((hkv, g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), kv_len.astype(jnp.int32),
      qg, k_pool, v_pool)
    return out.reshape(bsz, h, d)
