"""Mamba2 SSD chunked-scan Pallas kernel.

TPU adaptation of the SSD (state-space duality) algorithm: per (batch,
head) the sequence is processed in chunks; the intra-chunk term is a
decay-masked [Q,Q] quadratic form (MXU-friendly — Q is a multiple of
128), and the running state [P,N] lives in VMEM scratch across the
chunk loop (innermost grid axis), so state passing never round-trips
HBM.  This replaces the GPU version's warp-parallel chunk scan with a
sequential-grid + VMEM-resident-state formulation.

Layouts (pre-transposed by ops.py):
  x   [B, H, S, P]   dt [B, H, S]   a [H]
  bmat/cmat [B, S, N]  (single B/C group, shared across heads)
Returns y [B, H, S, P] and the final state [B, H, P, N].
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fin_ref, state_ref, *,
            q: int, chunks: int, s_valid: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)        # [Q]
    a = a_ref[0]                                 # scalar decay (negative)
    bm = b_ref[0].astype(jnp.float32)            # [Q, N]
    cm = c_ref[0].astype(jnp.float32)            # [Q, N]

    # zero padded tail (keeps state exact when S % Q != 0)
    pos = c * q + jax.lax.iota(jnp.int32, q)
    valid = (pos < s_valid).astype(jnp.float32)
    dt = dt * valid

    da = dt * a                                  # [Q]  (<= 0)
    cum = jnp.cumsum(da)                         # inclusive
    seg = cum[q - 1]

    # intra-chunk quadratic term
    diff = cum[:, None] - cum[None, :]           # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)  # [Q,Q]
    scores = cb * lmat * dt[None, :]
    y = jnp.dot(scores, x, preferred_element_type=jnp.float32)  # [Q, P]

    # inter-chunk contribution from carried state
    state = state_ref[...]                       # [P, N]
    y += jnp.exp(cum)[:, None] * jnp.dot(
        cm, state.T, preferred_element_type=jnp.float32)

    # state update: state' = exp(seg)*state + sum_j exp(seg-cum_j)*dt_j*x_j B_j^T
    w = jnp.exp(seg - cum) * dt                  # [Q]
    inject = jnp.dot((x * w[:, None]).T, bm,
                     preferred_element_type=jnp.float32)        # [P, N]
    state_ref[...] = jnp.exp(seg) * state + inject

    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(c == chunks - 1)
    def _finish():
        fin_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
             cmat: jax.Array, *, chunk: int = 256,
             interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: [B,H,S,P]; dt: [B,H,S]; a: [H]; bmat/cmat: [B,S,N]."""
    bsz, h, s, p = x.shape
    n = bmat.shape[-1]
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))

    kernel = functools.partial(_kernel, q=q, chunks=nc, s_valid=s)
    y, fin = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, p), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, q), lambda b, hh, c: (b, hh, c)),
            pl.BlockSpec((1,), lambda b, hh, c: (hh,)),
            pl.BlockSpec((1, q, n), lambda b, hh, c: (b, c, 0)),
            pl.BlockSpec((1, q, n), lambda b, hh, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, p), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, hh, c: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, nc * q, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a.astype(jnp.float32), bmat, cmat)
    return y[:, :, :s], fin
