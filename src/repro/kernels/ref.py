"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are validated
against (tests sweep shapes/dtypes and assert_allclose kernel-vs-ref).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def lora_matmul(x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                scaling: float) -> jax.Array:
    """y = x @ W + scaling * (x @ A) @ B, accumulated in f32."""
    xf = x.astype(jnp.float32)
    base = xf @ w.astype(jnp.float32)
    low = (xf @ a.astype(jnp.float32)) @ b.astype(jnp.float32)
    return (base + scaling * low).astype(x.dtype)


def segmented_lora_matmul(x: jax.Array, w: jax.Array, a_stack: jax.Array,
                          b_stack: jax.Array, adapter_idx: jax.Array,
                          scaling: float) -> jax.Array:
    """Per-row multi-adapter LoRA: row i applies adapter ``adapter_idx[i]``
    from stacked ``a_stack: [A,K,r]`` / ``b_stack: [A,r,N]``; rows with
    ``adapter_idx < 0`` return the pure base product bitwise (the select
    happens AFTER the einsum, so garbage — even NaN — in unused adapter
    slots never leaks into disabled rows)."""
    xf = x.astype(jnp.float32)
    base = xf @ w.astype(jnp.float32)
    n_adapters = a_stack.shape[0]
    valid = adapter_idx >= 0
    idx = jnp.clip(adapter_idx, 0, n_adapters - 1)
    a_sel = jnp.take(a_stack, idx, axis=0).astype(jnp.float32)  # [M,K,r]
    b_sel = jnp.take(b_stack, idx, axis=0).astype(jnp.float32)  # [M,r,N]
    xa = jnp.einsum("mk,mkr->mr", xf, a_sel)
    low = jnp.einsum("mr,mrn->mn", xa, b_sel)
    y = base + scaling * low
    return jnp.where(valid[:, None], y, base).astype(x.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    scale: Optional[float] = None) -> jax.Array:
    """Dense softmax attention.  q: [B,H,Sq,D]; k,v: [B,Hkv,Skv,D] (GQA)."""
    bsz, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(bsz, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return o.reshape(bsz, h, sq, d).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     kv_len: jax.Array, *, scale: Optional[float] = None
                     ) -> jax.Array:
    """Single-token attention.  q: [B,H,D]; caches: [B,Hkv,S,D];
    kv_len: [B] int32."""
    bsz, h, d = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(bsz, hkv, g, d).astype(jnp.float32)
    sc = jnp.einsum("bhgd,bhkd->bhgk", qg,
                    k_cache.astype(jnp.float32)) * scale
    mask = jnp.arange(s)[None, :] < kv_len[:, None]          # [B,S]
    sc = jnp.where(mask[:, None, None], sc, -jnp.inf)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(bsz, h, d).astype(q.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bmat: jax.Array,
             cmat: jax.Array, init_state: Optional[jax.Array] = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Sequential (non-chunked) SSD recurrence — the slow exact oracle.

    x: [B,S,H,P]; dt: [B,S,H]; a: [H] (negative); bmat/cmat: [B,S,N].
    Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = bmat.shape[-1]
    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(state, inputs):
        xt, dtt, bt, ct = inputs
        decay = jnp.exp(dtt * a[None, :])[:, :, None, None]
        inject = jnp.einsum("bh,bn,bhp->bhpn", dtt, bt, xt)
        state = state * decay + inject
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    xs = (x.swapaxes(0, 1).astype(jnp.float32),
          dt.swapaxes(0, 1).astype(jnp.float32),
          bmat.swapaxes(0, 1).astype(jnp.float32),
          cmat.swapaxes(0, 1).astype(jnp.float32))
    final, ys = jax.lax.scan(step, init_state, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), final
