"""Fused LoRA matmul Pallas kernel: y = x @ W + scaling * (x @ A) @ B.

This is the hot spot of CoLLM's unified PEFT interface — every adapter-
bearing projection in both the training and the inference path runs this
contraction.  Fusing the low-rank bypass into the base matmul's K-loop
reads ``x`` from VMEM once for both products (the unfused form streams
``x`` from HBM twice) and keeps the rank-r intermediate entirely in a
VMEM scratch accumulator.

Tiling: grid (M/bm, N/bn, K/bk), K innermost so the f32 accumulators
persist across the contraction.  MXU-aligned tiles (multiples of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, xa_ref, *,
            scaling: float, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(x, a_ref[...],
                           preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        low = jnp.dot(xa_ref[...].astype(b_ref.dtype), b_ref[...],
                      preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scaling * low).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scaling", "bm", "bn", "bk",
                                             "interpret"))
def lora_matmul(x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                scaling: float, *, bm: int = 128, bn: int = 128,
                bk: int = 512, interpret: bool = False) -> jax.Array:
    """x: [M,K]; w: [K,N]; a: [K,r]; b: [r,N] -> [M,N].

    M, N, K must be divisible by the block sizes (ops.py pads otherwise).
    """
    m, k = x.shape
    n = w.shape[1]
    r = a.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    kernel = functools.partial(_kernel, scaling=scaling, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, r), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((r, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),   # base accumulator
            pltpu.VMEM((bm, r), jnp.float32),    # x @ A accumulator
        ],
        interpret=interpret,
    )(x, w, a, b)
