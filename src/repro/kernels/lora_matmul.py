"""Fused LoRA matmul Pallas kernel: y = x @ W + scaling * (x @ A) @ B.

This is the hot spot of CoLLM's unified PEFT interface — every adapter-
bearing projection in both the training and the inference path runs this
contraction.  Fusing the low-rank bypass into the base matmul's K-loop
reads ``x`` from VMEM once for both products (the unfused form streams
``x`` from HBM twice) and keeps the rank-r intermediate entirely in a
VMEM scratch accumulator.

Tiling: grid (M/bm, N/bn, K/bk), K innermost so the f32 accumulators
persist across the contraction.  MXU-aligned tiles (multiples of 128).

``segmented_lora_matmul`` is the multi-tenant form: every row of ``x``
carries an ``adapter_idx`` into stacked per-adapter A/B tensors, so one
decode wave mixes tenants without unbatching.  The stacks are laid out
concatenated along the rank axis (``a_cat: [K, A*r]``,
``b_cat: [A*r, N]``) and each row's bypass is isolated by masking the
``x @ A`` intermediate to its adapter's rank segment before the B
contraction — rows with ``adapter_idx < 0`` match no segment and come
out as the pure base matmul.  A scalar-prefetched per-M-tile occupancy
vector (same idiom as ``paged_decode_attention``'s block tables) lets
tiles whose rows are ALL disabled skip the low-rank work entirely
instead of multiplying by zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, xa_ref, *,
            scaling: float, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)
    xa_ref[...] += jnp.dot(x, a_ref[...],
                           preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        low = jnp.dot(xa_ref[...].astype(b_ref.dtype), b_ref[...],
                      preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scaling * low).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scaling", "bm", "bn", "bk",
                                             "interpret"))
def lora_matmul(x: jax.Array, w: jax.Array, a: jax.Array, b: jax.Array,
                scaling: float, *, bm: int = 128, bn: int = 128,
                bk: int = 512, interpret: bool = False) -> jax.Array:
    """x: [M,K]; w: [K,N]; a: [K,r]; b: [r,N] -> [M,N].

    M, N, K must be divisible by the block sizes (ops.py pads otherwise).
    """
    m, k = x.shape
    n = w.shape[1]
    r = a.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    kernel = functools.partial(_kernel, scaling=scaling, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, r), lambda i, j, kk: (kk, 0)),
            pl.BlockSpec((r, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),   # base accumulator
            pltpu.VMEM((bm, r), jnp.float32),    # x @ A accumulator
        ],
        interpret=interpret,
    )(x, w, a, b)


def _seg_kernel(any_ref, idx_ref, x_ref, w_ref, a_ref, b_ref, o_ref,
                acc_ref, xa_ref, *, scaling: float, k_steps: int,
                rank: int):
    i = pl.program_id(0)
    kk = pl.program_id(2)
    have = any_ref[i] != 0           # any live adapter row in this M tile?

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xa_ref[...] = jnp.zeros_like(xa_ref)

    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(have)
    def _lowrank():
        xa_ref[...] += jnp.dot(x, a_ref[...],
                               preferred_element_type=jnp.float32)

    # all-disabled tiles never touched A/B: emit the base product as-is
    @pl.when((kk == k_steps - 1) & jnp.logical_not(have))
    def _finish_base():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)

    @pl.when((kk == k_steps - 1) & have)
    def _finish_segmented():
        bm, ar = xa_ref.shape
        # column c of the concatenated rank axis belongs to adapter c//r;
        # keep only each row's own segment (rows with idx < 0 match none)
        seg = jax.lax.broadcasted_iota(jnp.int32, (bm, ar), 1) // rank
        mask = idx_ref[...] == seg
        xa_m = jnp.where(mask, xa_ref[...], 0.0)
        low = jnp.dot(xa_m.astype(b_ref.dtype), b_ref[...],
                      preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scaling * low).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scaling", "rank", "bm",
                                             "bn", "bk", "interpret"))
def segmented_lora_matmul(x: jax.Array, w: jax.Array, a_cat: jax.Array,
                          b_cat: jax.Array, adapter_idx: jax.Array,
                          scaling: float, *, rank: int, bm: int = 128,
                          bn: int = 128, bk: int = 512,
                          interpret: bool = False) -> jax.Array:
    """x: [M,K]; w: [K,N]; a_cat: [K,A*r]; b_cat: [A*r,N];
    adapter_idx: [M] int32 (row's adapter slot, < 0 = base only).

    M, N, K must be divisible by the block sizes (ops.py pads; padded
    rows carry adapter_idx = -1 so they add no low-rank work).
    """
    m, k = x.shape
    n = w.shape[1]
    ar = a_cat.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    tile_any = (adapter_idx.reshape(m // bm, bm) >= 0).any(
        axis=1).astype(jnp.int32)
    kernel = functools.partial(_seg_kernel, scaling=scaling,
                               k_steps=k_steps, rank=rank)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j, kk, any_ref: (i, 0)),
            pl.BlockSpec((bm, bk), lambda i, j, kk, any_ref: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk, any_ref: (kk, j)),
            pl.BlockSpec((bk, ar), lambda i, j, kk, any_ref: (kk, 0)),
            pl.BlockSpec((ar, bn), lambda i, j, kk, any_ref: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, any_ref: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),   # base accumulator
            pltpu.VMEM((bm, ar), jnp.float32),   # x @ A_cat accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(tile_any, adapter_idx.reshape(m, 1), x, w, a_cat, b_cat)
