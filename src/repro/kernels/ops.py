"""jit'd public wrappers around the Pallas kernels.

Dispatch policy:
  * on TPU backends the compiled Pallas kernel runs natively;
  * on CPU (this container, and any smoke test) the kernel body runs in
    ``interpret=True`` mode when ``force_kernel`` is set, otherwise the
    pure-jnp oracle from ``ref.py`` executes — interpret mode is
    correctness-equivalent but orders of magnitude slower, so tests opt
    in explicitly and production code paths stay fast.

The wrappers also handle padding to MXU-aligned block multiples so
callers never need to care about divisibility.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode_kernel
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.lora_matmul import lora_matmul as _lora_kernel
from repro.kernels.lora_matmul import \
    segmented_lora_matmul as _seg_lora_kernel
from repro.kernels.ssd_scan import ssd_scan as _ssd_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def lora_matmul(x, w, a, b, scaling: float, *,
                force_kernel: bool = False, block: int = 128):
    """y = x @ W + scaling (x@A)@B with leading batch dims on x."""
    if not (_on_tpu() or force_kernel):
        return ref.lora_matmul(x, w, a, b, scaling)
    lead = x.shape[:-1]
    m = 1
    for dim in lead:
        m *= dim
    k, n = w.shape
    x2 = x.reshape(m, k)
    mp, kp, np_ = _round_up(m, block), _round_up(k, block), _round_up(n, block)
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    ap = jnp.pad(a, ((0, kp - k), (0, 0)))
    bp = jnp.pad(b, ((0, 0), (0, np_ - n)))
    y = _lora_kernel(x2, wp, ap, bp, scaling, bm=block, bn=block,
                     bk=max(block, 512 if kp % 512 == 0 else block),
                     interpret=not _on_tpu())
    return y[:m, :n].reshape(lead + (n,))


def segmented_lora_matmul(x, w, a_stack, b_stack, adapter_idx,
                          scaling: float, *, force_kernel: bool = False,
                          block: int = 128):
    """Multi-tenant LoRA: row i of ``x`` applies adapter
    ``adapter_idx[i]`` from stacked ``a_stack: [A,K,r]`` /
    ``b_stack: [A,r,N]`` (idx < 0 = base only).  Leading batch dims on
    ``x`` mirror ``adapter_idx``'s shape."""
    lead = x.shape[:-1]
    m = 1
    for dim in lead:
        m *= dim
    k, n = w.shape
    x2 = x.reshape(m, k)
    idx = adapter_idx.reshape(m).astype(jnp.int32)
    if not (_on_tpu() or force_kernel):
        y = ref.segmented_lora_matmul(x2, w, a_stack, b_stack, idx,
                                      scaling)
        return y.reshape(lead + (n,))
    n_adapters, _, r = a_stack.shape
    ar = n_adapters * r
    # concatenate the stacks along the rank axis for the kernel layout
    a_cat = a_stack.transpose(1, 0, 2).reshape(k, ar)
    b_cat = b_stack.reshape(ar, n)
    mp, kp, np_ = _round_up(m, block), _round_up(k, block), _round_up(n, block)
    x2 = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    ap = jnp.pad(a_cat, ((0, kp - k), (0, 0)))
    bp = jnp.pad(b_cat, ((0, 0), (0, np_ - n)))
    idx_p = jnp.pad(idx, (0, mp - m), constant_values=-1)
    y = _seg_lora_kernel(x2, wp, ap, bp, idx_p, scaling, rank=r,
                         bm=block, bn=block,
                         bk=max(block, 512 if kp % 512 == 0 else block),
                         interpret=not _on_tpu())
    return y[:m, :n].reshape(lead + (n,))


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: Optional[float] = None,
                    force_kernel: bool = False):
    """q: [B,H,Sq,D]; k,v: [B,Hkv,Skv,D]."""
    if not (_on_tpu() or force_kernel):
        return ref.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale)
    return _flash_kernel(q, k, v, causal=causal, window=window, scale=scale,
                         interpret=not _on_tpu())


def decode_attention(q, k_cache, v_cache, kv_len, *,
                     scale: Optional[float] = None,
                     force_kernel: bool = False):
    """q: [B,H,D]; caches: [B,Hkv,S,D]; kv_len: [B]."""
    if not (_on_tpu() or force_kernel):
        return ref.decode_attention(q, k_cache, v_cache, kv_len, scale=scale)
    return _decode_kernel(q, k_cache, v_cache, kv_len, scale=scale,
                          interpret=not _on_tpu())


def ssd_scan(x, dt, a, bmat, cmat, *, chunk: int = 256,
             force_kernel: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: [B,H,S,P]; dt: [B,H,S]; a: [H]; bmat/cmat: [B,S,N]."""
    if not (_on_tpu() or force_kernel):
        y, fin = ref.ssd_scan(x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1),
                              a, bmat, cmat)
        return y.transpose(0, 2, 1, 3), fin
    return _ssd_kernel(x, dt, a, bmat, cmat, chunk=chunk,
                       interpret=not _on_tpu())
