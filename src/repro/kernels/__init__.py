"""Pallas TPU kernels for CoLLM's compute hot spots:

  lora_matmul       — fused base + low-rank adapter contraction (the
                      unified PEFT interface both tasks share)
  flash_attention   — prefill attention (GQA, causal, sliding window)
  decode_attention  — batched single-token attention over KV caches
  ssd_scan          — Mamba2 SSD chunked scan (long_500k cells)

Each has a pure-jnp oracle in ``ref.py``; ``ops.py`` is the dispatching
public surface (TPU -> compiled kernel, CPU -> oracle / interpret mode).
"""
from repro.kernels import ops, ref  # noqa: F401
