"""Production mesh + sharding-rule selection (dry-run deliverable).

``make_production_mesh`` builds the assigned meshes:
  single-pod:  (16, 16)        axes ("data", "model")      — 256 chips
  multi-pod:   (2, 16, 16)     axes ("pod", "data", "model") — 512 chips

``rules_for`` adapts the logical-axis rule table per architecture ×
step-kind: archs whose head counts don't divide the model axis fall back
to sequence sharding for attention balance; GQA caches too big for
batch-sharding alone shard their sequence dim; training enables
sequence-parallel residual activations (Megatron-SP style) so the
remat-saved carries stay O(tokens/device).

``param_spec``/``batch_spec`` map parameter/input trees to
PartitionSpecs by tree path — the single source of truth the dry-run,
the trainer, and elastic restore all share.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import Family, ModelConfig, ShapeCell
from repro.models.sharding import (
    RULES_TP_FSDP, ShardingRules, _filter_spec,
)


def make_mesh_compat(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """jax.make_mesh across jax versions: older releases have neither
    the ``axis_types`` kwarg nor ``jax.sharding.AxisType`` (Auto is
    their only behavior), newer ones default to Auto."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def rules_for(cfg: ModelConfig, mesh: Mesh, kind: str,
              base: Optional[ShardingRules] = None) -> ShardingRules:
    """Pick the rule table for (arch × step kind) on this mesh."""
    rules = base or RULES_TP_FSDP
    model_n = mesh.shape.get("model", 1)
    upd = {}
    if kind == "train":
        # sequence-parallel residual stream: remat-saved carries shard
        # over the model axis instead of being replicated across it
        upd["act_seq"] = "model"
    if cfg.n_heads % model_n != 0:
        # 25/40-head archs: heads can't split the model axis — balance
        # attention by sharding the query sequence dim instead
        upd["heads"] = None
        upd["kv_heads"] = None
        upd["q_seq"] = "model"
    if cfg.n_kv_heads % model_n != 0:
        # GQA caches too big for batch sharding alone (llama3-class
        # decode_32k is ~550 GB): shard the cache sequence dim
        upd["kv_seq"] = "model"
    if cfg.family is Family.MOE:
        if cfg.moe_shard == "ep" and cfg.n_experts % model_n == 0:
            upd["experts"] = "model"
            upd["expert_ff"] = None
        else:  # grok: 8 experts on a 16-way axis -> per-expert ff TP
            upd["experts"] = None
            upd["expert_ff"] = "model"
    return dataclasses.replace(rules, **upd)


# --------------------------------------------------------------------------
# path -> logical axes for every parameter in the model tree
# --------------------------------------------------------------------------
_PARAM_TABLE = [
    # (path regex, logical axes EXCLUDING stacked leading dims)
    (r"embed$", ("vocab", "w_embed")),
    (r"lm_head$", ("w_embed", "vocab")),
    (r"final_norm$", ()),
    (r"attn/w[qkv]$", ("w_embed", "heads")),
    (r"attn/wo$", ("heads", "w_embed")),
    (r"attn/b[qkv]$", ("heads",)),
    (r"attn/[qk]_norm$", ()),
    (r"mlp/w[gu]$", ("w_embed", "ff")),
    (r"mlp/wd$", ("ff", "w_embed")),
    (r"moe/router$", ("w_embed", None)),
    (r"moe/w[gu]$", ("experts", "w_embed", "expert_ff")),
    (r"moe/wd$", ("experts", "expert_ff", "w_embed")),
    (r"ssm/in_proj$", ("w_embed", "ssm_inner")),
    (r"ssm/out_proj$", ("ssm_inner", "w_embed")),
    (r"ssm/conv_w$", (None, "ssm_inner")),
    (r"ssm/conv_b$", ("ssm_inner",)),
    (r"ssm/(A_log|D_skip|dt_bias)$", ()),
    (r"ssm/norm$", ("ssm_inner",)),
    (r"ln[12]$", ()),
    (r"gate_(attn|mlp)$", ()),
    # LoRA adapters + optimizer state over them: tiny, replicated
    (r"(^|/)(a|b)$", None),
]


def _leading(path: str, cfg: ModelConfig) -> int:
    if path.startswith("blocks/"):
        return 2 if cfg.family is Family.VLM else 1
    if path.startswith("cross/"):
        return 1
    return 0


def logical_axes_for(path: str, ndim: int, cfg: ModelConfig
                     ) -> Tuple[Optional[str], ...]:
    lead = _leading(path, cfg)
    for pat, axes in _PARAM_TABLE:
        if re.search(pat, path):
            if axes is None:
                return (None,) * ndim
            out = (None,) * lead + tuple(axes)
            if len(out) < ndim:            # defensive: pad with None
                out = out + (None,) * (ndim - len(out))
            return out[:ndim]
    return (None,) * ndim


def _resolve(rules: ShardingRules, names, shape, mesh: Mesh) -> P:
    spec = rules.resolve(*names)
    spec = _filter_spec(spec, mesh, shape)
    # drop duplicate mesh-axis usage across dims (illegal in XLA)
    seen = set()
    out = []
    for entry in spec:
        axes = entry if isinstance(entry, tuple) else (
            (entry,) if entry else ())
        kept = tuple(a for a in axes if a not in seen)
        seen.update(kept)
        out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def param_shardings(tree: Any, cfg: ModelConfig, mesh: Mesh,
                    rules: ShardingRules) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        names = logical_axes_for(key, leaf.ndim, cfg)
        out.append(NamedSharding(mesh,
                                 _resolve(rules, names, leaf.shape, mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------- batches --
_BATCH_TABLE = [
    (r"tokens$|labels$|mask$|token$", ("batch", None)),
    (r"embeds$|vision$", ("batch", None, None)),
    (r"pos$", ()),
    # caches (leading dims added below by _leading-style logic)
    (r"kv/[01]$", ("kv_batch", "kv_seq", "kv_heads", None)),
    (r"cross_kv/[01]$", ("kv_batch", None, "kv_heads", None)),
    (r"ssm/conv$", ("kv_batch", None, "ssm_inner")),
    (r"ssm/state$", ("kv_batch", "ssm_heads", None, None)),
]


def batch_shardings(tree: Any, cfg: ModelConfig, mesh: Mesh,
                    rules: ShardingRules) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        names: Tuple[Optional[str], ...] = (None,) * leaf.ndim
        for pat, axes in _BATCH_TABLE:
            if re.search(pat, key):
                lead = leaf.ndim - len(axes)
                names = (None,) * max(lead, 0) + tuple(axes)
                names = names[:leaf.ndim]
                break
        out.append(NamedSharding(mesh,
                                 _resolve(rules, names, leaf.shape, mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)
