"""Compiled-HLO analysis: collective-traffic extraction + roofline terms.

``collective_bytes`` parses the post-SPMD optimized HLO (per-device
module) and sums the byte sizes of every collective op, bucketed by op
kind.  ``roofline`` combines them with cost_analysis FLOPs/bytes and the
TPU v5e hardware constants into the three assignment-mandated terms.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

# TPU v5e per-chip constants (assignment-specified)
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (~3 usable links/chip v5e)
ICI_LINKS = 3

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nbytes
    return total


def slice_overcount(hlo_text: str) -> int:
    """HLOCostAnalysis books the FULL operand of every (dynamic-)slice
    and dynamic-update-slice, but the physical traffic is only the
    slice/slot (in-place DUS, windowed reads).  Returns the per-device
    byte overcount to subtract:

      slice:  counted operand+output = full+slice; true ≈ 2·slice
              ⇒ overcount = full − slice
      DUS:    counted 2·full+update;   true ≈ 2·update
              ⇒ overcount = 2·(full − update)
    """
    over = 0
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        m = re.search(r"\sdynamic-update-slice\(", rhs)
        s = re.search(r"\s(dynamic-slice|slice)\(", rhs)
        if m:
            out_bytes = _shape_bytes(rhs.split("dynamic-update-slice")[0])
            # update operand type appears inside the parens (2nd operand)
            inner = rhs.split("dynamic-update-slice(", 1)[1]
            shapes = _SHAPE_RE.findall(inner)
            upd = 0
            if len(shapes) >= 2:
                d, dims = shapes[1]
                nb = _DTYPE_BYTES.get(d, 0)
                n = 1
                for x in dims.split(","):
                    if x:
                        n *= int(x)
                upd = n * nb
            over += max(2 * (out_bytes - upd), 0)
        elif s:
            op = s.group(1)
            out_bytes = _shape_bytes(rhs.split(op + "(")[0])
            inner = rhs.split(op + "(", 1)[1]
            shapes = _SHAPE_RE.findall(inner)
            full = 0
            if shapes:
                d, dims = shapes[0]
                nb = _DTYPE_BYTES.get(d, 0)
                n = 1
                for x in dims.split(","):
                    if x:
                        n *= int(x)
                full = n * nb
            over += max(full - out_bytes, 0)
    return over


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (output-type sums;
    ``-start`` async forms counted once, ``-done`` skipped)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        for kind in _COLLECTIVES:
            # match the opcode (not fused callees): " all-reduce(" etc.
            if re.search(rf"\s{kind}(-start)?\(", rhs):
                # the output type annotation precedes the opcode
                prefix = rhs.split(f"{kind}", 1)[0]
                nbytes = _shape_bytes(prefix)
                out[kind] += nbytes
                out["count"] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    n_devices: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def as_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "n_devices": self.n_devices,
        }


def roofline(flops_per_device: float, bytes_per_device: float,
             coll_bytes_per_device: float, n_devices: int) -> RooflineTerms:
    """The three terms, in seconds, for one step on one device (the SPMD
    program is identical across devices, so per-device == per-chip)."""
    return RooflineTerms(
        compute_s=flops_per_device / PEAK_FLOPS,
        memory_s=bytes_per_device / HBM_BW,
        collective_s=coll_bytes_per_device / (ICI_BW * ICI_LINKS),
        flops_per_device=flops_per_device,
        bytes_per_device=bytes_per_device,
        coll_bytes_per_device=coll_bytes_per_device,
        n_devices=n_devices,
    )


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS: 6·N·D for dense training (N = active params,
    D = tokens); 2·N·D for inference-style forward passes; decode is per
    generated token over the batch."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the KV cache
    tokens = cell.global_batch
    attn = 0.0
    if cfg.has_attention:
        kv_len = cell.seq_len if cfg.sliding_window == 0 \
            else min(cell.seq_len, cfg.sliding_window)
        attn = (4.0 * cfg.n_heads * cfg.head_dim * kv_len) \
            * cfg.n_layers * cell.global_batch
    return 2.0 * n_active * tokens + attn
