"""Training driver (deliverable b/e): LoRA fine-tuning with
checkpoint/restart fault tolerance, NaN guards, and optional elastic
restore onto a different mesh.

Reduced configs run end-to-end on CPU (this container); full configs
target the production mesh (same code path — pjit re-lowers per mesh).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt /tmp/ck
  ... --restore            # resume from the latest checkpoint
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset
from repro.optim.grad_noise import NoiseScaleEMA


def run_training(arch: str, *, smoke: bool = True, steps: int = 100,
                 batch: int = 8, seq: int = 64,
                 ckpt_dir: Optional[str] = None, restore: bool = False,
                 ckpt_every: int = 25, lr: float = 3e-3,
                 seed: int = 0, log_every: int = 10,
                 inject_nan_at: int = -1, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.scaled()
    engine = make_engine(cfg, lr=lr)
    model = engine.model
    key = jax.random.key(seed)
    params = model.init(key)
    lora = model.init_lora(jax.random.key(seed + 1))
    opt_state = engine.optimizer.init(lora)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=seq, seed=seed)
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if ckpt and restore:
        lat = ckpt.latest_step()
        if lat is not None:
            (lora, opt_state), extra = ckpt.restore(
                jax.eval_shape(lambda: (lora, opt_state)))
            start_step = lat
            if verbose:
                print(f"restored step {lat}")

    jit_step = jax.jit(engine.train_step, donate_argnums=(1, 2))

    def _snap(tree):
        # the rollback snapshot must own its buffers: jit_step DONATES
        # lora/opt_state, so an aliasing snapshot would hold deleted
        # device memory on any backend that honors donation
        return jax.tree.map(jnp.copy, tree)

    noise = NoiseScaleEMA()
    losses = []
    last_good = (_snap(lora), _snap(opt_state), start_step)
    t0 = time.time()
    step = start_step
    while step < steps:
        b = {k: jnp.asarray(v) for k, v in data.batch(batch).items()}
        if cfg.family.value == "vlm":
            b["vision"] = jnp.zeros((batch, cfg.vision_tokens, cfg.d_model),
                                    jnp.float32)
        if cfg.encoder_only:
            b["embeds"] = jax.random.normal(
                jax.random.key(step), (batch, seq, cfg.d_model))
        new_lora, new_opt, metrics = jit_step(params, lora, opt_state, b)
        loss = float(metrics["ce_loss"])
        if inject_nan_at == step:
            loss = float("nan")   # fault-injection hook for tests
        if not np.isfinite(loss):
            # fault tolerance: roll back to the last good state
            if verbose:
                print(f"step {step}: non-finite loss; restoring "
                      f"step {last_good[2]}")
            lora, opt_state, step = last_good
            if ckpt:
                lat = ckpt.latest_step()
                if lat is not None:
                    (lora, opt_state), _ = ckpt.restore(
                        jax.eval_shape(lambda: (lora, opt_state)))
                    step = lat
            inject_nan_at = -1
            continue
        lora, opt_state = new_lora, new_opt
        losses.append(loss)
        step += 1
        if ckpt and step % ckpt_every == 0:
            ckpt.save(step, (lora, opt_state),
                      extra={"arch": arch, "loss": loss})
            last_good = (_snap(lora), _snap(opt_state), step)
        if verbose and step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"{(time.time() - t0) / max(step - start_step, 1):.3f}"
                  f" s/step")
    if ckpt:
        ckpt.save(steps, (lora, opt_state), extra={"arch": arch})
        ckpt.wait()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "lora": lora, "steps": step}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()
    out = run_training(args.arch, smoke=args.smoke, steps=args.steps,
                       batch=args.batch, seq=args.seq,
                       ckpt_dir=args.ckpt, restore=args.restore,
                       lr=args.lr)
    fl = out["final_loss"]
    # final_loss is None when a restore lands at step >= --steps (no
    # new step runs, so there is no loss to report)
    print(f"done: {out['steps']} steps, final loss "
          + (f"{fl:.4f}" if fl is not None else "n/a (already complete)"))


if __name__ == "__main__":
    main()
