import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

__doc__ = """Perf-iteration harness (§Perf of EXPERIMENTS.md).

Re-lowers one (arch × shape) cell with a named change applied, prints
the before/after roofline terms against the saved baseline JSON, and
appends a structured entry to results/perf_log.jsonl.

  python -m repro.launch.perf --arch llama3-8b --shape decode_32k \
      --change rules=tp_only --hypothesis "..."

Changes (comma-separate to stack):
  rules=tp_only|fsdp_heavy      sharding-rule preset swap
  block_kv=<int>                attention KV block size
  grad_accum=<int>              train microbatching
  remat=none|full|dots          activation checkpointing policy
  skip_masked=1                 causal block skipping (triangular scan)
  batch_data_only=1             activations batch-shard over data only
"""

import argparse
import dataclasses
import json
import time
from typing import Dict

from repro.configs.base import ALL_SHAPES
from repro.configs.registry import ARCH_IDS
from repro.launch.dryrun import RESULTS_DIR, lower_cell
from repro.models.sharding import (
    RULES_FSDP_HEAVY, RULES_TP_FSDP, RULES_TP_ONLY,
)

PERF_LOG = os.path.join(os.path.dirname(RESULTS_DIR), "perf_log.jsonl")

PRESETS = {"tp_fsdp": RULES_TP_FSDP, "tp_only": RULES_TP_ONLY,
           "fsdp_heavy": RULES_FSDP_HEAVY}


def parse_changes(spec: str) -> Dict:
    out: Dict = {}
    if not spec:
        return out
    for part in spec.split(","):
        k, _, v = part.partition("=")
        out[k.strip()] = v.strip()
    return out


def apply_changes(changes: Dict) -> Dict:
    kw: Dict = {}
    rules = None
    for k, v in changes.items():
        if k == "rules":
            rules = PRESETS[v]
        elif k == "block_kv":
            kw["block_kv"] = int(v)
        elif k == "grad_accum":
            kw["grad_accum"] = int(v)
        elif k == "remat":
            kw["remat"] = v
        elif k == "skip_masked":
            kw["skip_masked_blocks"] = bool(int(v))
        elif k == "unroll_layers":
            kw["unroll_layers"] = bool(int(v))
        elif k == "kv8":
            kw["kv_cache_dtype"] = "float8_e4m3fn"
        elif k == "prefill_chunks":
            kw["prefill_chunks"] = int(v)
        elif k == "batch_data_only":
            base = rules or RULES_TP_FSDP
            rules = dataclasses.replace(base, batch="data",
                                        kv_batch="data")
        else:
            raise ValueError(f"unknown change {k!r}")
    if rules is not None:
        kw["rules_override"] = rules
    return kw


def baseline_record(arch: str, shape: str, mesh: str = "16-16") -> Dict:
    path = os.path.join(RESULTS_DIR,
                        f"{arch.replace('.', '_')}_{shape}_{mesh}.json")
    with open(path) as f:
        return json.load(f)


def compare(base: Dict, new: Dict) -> Dict:
    out = {}
    for term in ("compute_s", "memory_s", "collective_s"):
        b, n = base["roofline"][term], new["roofline"][term]
        out[term] = {"before": b, "after": n,
                     "delta_pct": (n - b) / max(b, 1e-12) * 100}
    out["bound_before"] = base["roofline"]["dominant"]
    out["bound_after"] = new["roofline"]["dominant"]
    out["peak_mem_gib"] = {
        "before": base["memory"]["peak_device_bytes"] / 2 ** 30,
        "after": new["memory"]["peak_device_bytes"] / 2 ** 30}
    b_t = max(base["roofline"][t] for t in
              ("compute_s", "memory_s", "collective_s"))
    n_t = max(new["roofline"][t] for t in
              ("compute_s", "memory_s", "collective_s"))
    out["bound_time_speedup"] = b_t / max(n_t, 1e-12)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--shape", choices=[c.name for c in ALL_SHAPES],
                    required=True)
    ap.add_argument("--change", default="", help="see module docstring")
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()

    cell = next(c for c in ALL_SHAPES if c.name == args.shape)
    changes = parse_changes(args.change)
    kw = apply_changes(changes)
    tag = args.tag if args.tag is not None else \
        args.change.replace("=", "").replace(",", "_") or "rerun"

    base = baseline_record(args.arch, args.shape)
    new = lower_cell(args.arch, cell, tag=tag, **kw)
    cmp = compare(base, new)

    print("\n=== perf iteration ===")
    if args.hypothesis:
        print(f"hypothesis: {args.hypothesis}")
    print(f"change: {args.change or '(none)'}")
    for term in ("compute_s", "memory_s", "collective_s"):
        c = cmp[term]
        print(f"  {term:14s} {c['before'] * 1e3:10.2f} -> "
              f"{c['after'] * 1e3:10.2f} ms  ({c['delta_pct']:+6.1f}%)")
    print(f"  bound: {cmp['bound_before']} -> {cmp['bound_after']}; "
          f"bound-time speedup {cmp['bound_time_speedup']:.2f}x; peak mem "
          f"{cmp['peak_mem_gib']['before']:.1f} -> "
          f"{cmp['peak_mem_gib']['after']:.1f} GiB")

    entry = {"ts": time.time(), "arch": args.arch, "shape": args.shape,
             "change": args.change, "hypothesis": args.hypothesis,
             "comparison": cmp, "tag": tag}
    with open(PERF_LOG, "a") as f:
        f.write(json.dumps(entry) + "\n")


if __name__ == "__main__":
    main()
