"""Serving driver (deliverable b): batched prefill + decode with KV
caches, optionally co-executing LoRA fine-tuning via the fused
``combined_step`` — the paper's model-sharing mechanism live.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 16 --prompt-len 32 --gen 16
  ... --combined     # fine-tune while serving (one XLA program)
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset


def run_serving(arch: str, *, smoke: bool = True, n_requests: int = 16,
                prompt_len: int = 32, gen_tokens: int = 16,
                batch_size: int = 8, combined: bool = False,
                train_batch: int = 4, seed: int = 0,
                verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.scaled()
    assert cfg.has_decode, f"{arch} is encoder-only; no decode serving"
    engine = make_engine(cfg, lr=3e-3)
    model = engine.model
    key = jax.random.key(seed)
    params = model.init(key)
    lora = model.init_lora(jax.random.key(seed + 1))
    opt_state = engine.optimizer.init(lora)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=prompt_len, seed=seed)

    jit_prefill = jax.jit(model.prefill)
    jit_decode = jax.jit(model.decode_step, donate_argnums=(2,))
    jit_combined = jax.jit(engine.combined_step, donate_argnums=(2, 4))

    total_tokens = 0
    latencies = []
    train_losses = []
    rng = np.random.default_rng(seed)
    n_batches = -(-n_requests // batch_size)
    for bi in range(n_batches):
        bsz = min(batch_size, n_requests - bi * batch_size)
        prompts = data.sample_tokens(bsz)[:, :prompt_len]
        batch = {"tokens": jnp.asarray(prompts)}
        if cfg.family.value == "vlm":
            batch["vision"] = jnp.zeros(
                (bsz, cfg.vision_tokens, cfg.d_model), jnp.float32)
        t0 = time.perf_counter()
        # prefill into a cache sized for prompt + generation
        caches = model.init_caches(bsz, prompt_len + gen_tokens)
        logits = None
        tok = jnp.asarray(prompts[:, :1])
        for pos in range(prompt_len):          # teacher-forced warm fill
            tok = jnp.asarray(prompts[:, pos:pos + 1])
            if combined:
                tb = {k: jnp.asarray(v)
                      for k, v in data.batch(train_batch).items()}
                if cfg.family.value == "vlm":
                    tb["vision"] = jnp.zeros(
                        (train_batch, cfg.vision_tokens, cfg.d_model),
                        jnp.float32)
                lora, opt_state, logits, caches, metrics = jit_combined(
                    params, lora, opt_state, tb, caches, tok,
                    jnp.int32(pos))
                train_losses.append(float(metrics["ce_loss"]))
            else:
                logits, caches = jit_decode(params, lora, caches, tok,
                                            jnp.int32(pos))
        # greedy generation
        for g in range(gen_tokens):
            tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
            logits, caches = jit_decode(params, lora, caches, tok,
                                        jnp.int32(prompt_len + g))
            total_tokens += bsz
        latencies.append(time.perf_counter() - t0)
        if verbose:
            print(f"batch {bi}: {bsz} reqs, {latencies[-1]:.3f}s"
                  + (f", train loss {train_losses[-1]:.3f}"
                     if train_losses else ""))
    out = {
        "tokens_generated": total_tokens,
        "mean_batch_latency": float(np.mean(latencies)),
        "throughput_tok_s": total_tokens / max(sum(latencies), 1e-9),
        "train_losses": train_losses,
    }
    if verbose:
        print(f"served {total_tokens} tokens, "
              f"{out['throughput_tok_s']:.1f} tok/s"
              + (f"; co-trained {len(train_losses)} steps "
                 f"(loss {train_losses[0]:.3f} -> {train_losses[-1]:.3f})"
                 if train_losses else ""))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--combined", action="store_true")
    args = ap.parse_args()
    run_serving(args.arch, n_requests=args.requests,
                prompt_len=args.prompt_len, gen_tokens=args.gen,
                batch_size=args.batch, combined=args.combined)


if __name__ == "__main__":
    main()
