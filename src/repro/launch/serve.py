"""Serving driver (deliverable b): continuous-batching decode runtime —
prompts run through real ``model.prefill`` (one XLA program, no
per-token warm fill), finished sequences are evicted and new requests
admitted mid-flight, and ``--combined`` co-runs LoRA fine-tuning via the
fused ``combined_step`` on every decode tick — the paper's
model-sharing mechanism live.

``--replicas N`` (N > 1) serves the same trace through the
multi-replica fabric instead: one ``ClusterController`` routes
dispatcher subflows across N ``ContinuousBatcher``-backed live
replicas with placement-aware admission (pool headroom + prefix-cache
affinity) and per-replica admission queues; the summary aggregates
per-replica and cluster-total ``ServeStats``.

``--combined --replicas N`` is the paper's headline co-execution live:
the launcher cohorts the replicas into an FL PEFT session over the
SAME fabric — each replica advances an incremental train session one
fused ``combined_step`` per fabric tick (training its SHADOW adapter
while decode reads the published snapshot), the coordinator replans
per-replica train/infer splits between rounds, and aggregation
publishes the merged adapter to every member at round boundaries only.
``--rounds`` sets how many FL rounds to drive, ``--steps-per-round``
their length; within a round, greedy serving output is bit-identical
to serve-only.

Sampling: ``--temperature`` (> 0 enables stochastic decoding; 0 =
greedy, the default), filtered by ``--top-k`` / ``--top-p``, seeded
per request from ``--seed`` so runs are reproducible.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --requests 16 --prompt-len 32 --gen 16
  ... --combined     # fine-tune while serving (one XLA program)
  ... --paged --block-size 16 --n-blocks 64   # paged KV cache (block
                     # tables; memory scales with live tokens)
  ... --paged --prefix-cache   # share identical prompt prefixes
                     # copy-on-write over the paged pool
  ... --replicas 2   # dispatcher-routed pool of live replicas
  ... --replicas 2 --combined --rounds 2   # FL fine-tuning co-executed
                     # over the live fabric (shadow-adapter publishing)
  ... --adapters 3   # multi-LoRA multi-tenant serving: requests tagged
                     # round-robin across 3 registered tenants, decoded
                     # through the batched segmented LoRA paths
  ... --chunked-prefill 16 --tpot-target 0.004   # token-level
                     # co-scheduling: prompts prefill in 16-token chunks
                     # riding the decode wave, each tick budgeted to the
                     # decode TPOT SLO (leftover slack admits train work)
  ... --paged --n-blocks 48 --oversubscribe 0.9   # oversubscribed KV
                     # pool: reserve near-term need only, preempt on
                     # exhaustion (host swap or drop + re-prefill),
                     # greedy output bit-identical to never-preempted
  ... --temperature 0.8 --top-k 40 --top-p 0.95   # sampled decoding
  ... --replicas 2 --chaos --chaos-crashes 1 --chaos-stalls 1
                     # seeded fault injection against the fabric:
                     # crashes/stalls/OOMs/NaN-rounds on a deterministic
                     # schedule; the run prints failover + retry telemetry
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset
from repro.runtime.serving_loop import ContinuousBatcher, GenRequest


def _make_injector(n_replicas: int, chaos: dict):
    """Build a seeded FaultInjector over the fabric's replica ids from
    the --chaos-* knobs."""
    from repro.runtime.fault import FaultInjector
    plan = FaultInjector.random_plan(
        [f"r{i}" for i in range(n_replicas)],
        seed=chaos.get("seed", 0),
        horizon=chaos.get("horizon", 5.0),
        n_crashes=chaos.get("crashes", 1),
        n_stalls=chaos.get("stalls", 1),
        n_ooms=chaos.get("ooms", 0),
        n_nan_rounds=chaos.get("nan_rounds", 0))
    return FaultInjector(plan)


def _print_fault_telemetry(out: dict) -> None:
    ft = out.get("fault_tolerance")
    if not ft:
        return
    print(f"  chaos: {len(ft['injected'])} faults injected, "
          f"{ft['failovers']} failovers, {ft['quarantines']} quarantines, "
          f"{ft['retried_requests']} retries, "
          f"{ft['rejected_requests']} rejected, "
          f"{ft['nan_publishes_blocked']} NaN publishes blocked; "
          f"{out.get('failed_requests', 0)} requests failed")


def run_serving(arch: str, *, smoke: bool = True, n_requests: int = 16,
                prompt_len: int = 32, gen_tokens: int = 16,
                batch_size: int = 8, combined: bool = False,
                train_batch: int = 4, seed: int = 0,
                paged: bool = False, block_size: int = 16,
                n_blocks: int = 0, prefix_cache: bool = False,
                temperature: float = 0.0, top_k: int = 0,
                top_p: float = 1.0, n_adapters: int = 0,
                prefill_chunk: int = 0, tpot_target: float = 0.0,
                oversubscribe: float = 0.0, swap: bool = True,
                verbose: bool = True) -> dict:
    """Serve ``n_requests`` prompts on a ``batch_size``-slot continuous
    batcher; returns throughput + (combined mode) train losses.

    ``n_adapters > 0`` registers that many tenants on an
    ``AdapterRegistry`` and assigns requests round-robin: one decode
    wave then mixes tenants through the batched segmented LoRA paths.
    In combined mode training still steps the co-train tree in place,
    but decode reads the registry's published tenant copies — the
    single-batcher analogue of shadow buffering."""
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.scaled()
    assert cfg.has_decode, f"{arch} is encoder-only; no decode serving"
    engine = make_engine(cfg, lr=3e-3)
    model = engine.model
    params = model.init(jax.random.key(seed))
    registry = None
    if n_adapters > 0:
        from repro.runtime.fabric import make_tenant_adapters
        from repro.runtime.serving_loop import AdapterRegistry
        tenant_trees = make_tenant_adapters(model, n_adapters,
                                            seed=seed + 1)
        registry = AdapterRegistry(model, capacity=n_adapters)
        for t, tree in enumerate(tenant_trees):
            registry.register(f"tenant{t}", tree)
        lora = tenant_trees[0]
    else:
        lora = model.init_lora(jax.random.key(seed + 1))
    opt_state = engine.optimizer.init(lora)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=prompt_len, seed=seed)

    batcher = ContinuousBatcher(
        engine, params, lora, n_slots=batch_size,
        max_seq=prompt_len + gen_tokens, prompt_pad=prompt_len,
        opt_state=opt_state, paged=paged, block_size=block_size,
        n_blocks=n_blocks or None, prefix_cache=prefix_cache,
        adapters=registry, prefill_chunk=prefill_chunk,
        tpot_target=tpot_target, oversubscribe=oversubscribe,
        swap=swap)
    prompts = data.sample_tokens(n_requests)[:, :prompt_len]
    requests = [GenRequest(request_id=i, prompt=prompts[i],
                           max_new_tokens=gen_tokens,
                           adapter_id=f"tenant{i % n_adapters}"
                           if n_adapters > 0 else None,
                           temperature=temperature, top_k=top_k,
                           top_p=top_p, seed=seed + i)
                for i in range(n_requests)]

    def train_fn():
        import jax.numpy as jnp
        return {k: jnp.asarray(v) for k, v in data.batch(train_batch).items()}

    stats = batcher.run(requests, train_data_fn=train_fn if combined
                        else None)
    # completion time since run start (all requests arrive at t=0, so
    # later admission waves legitimately include queueing time)
    per_req = [r.finished_at for r in requests
               if r.finished_at is not None]
    out = {
        "tokens_generated": stats.generated_tokens,
        "prefill_tokens": stats.prefill_tokens,
        "decode_steps": stats.decode_steps,
        "mean_completion_s": float(np.mean(per_req)) if per_req else 0.0,
        "throughput_tok_s": stats.throughput(),
        "train_losses": batcher.train_losses,
        "cache_bytes": batcher.cache_bytes(),
    }
    if paged:
        out["peak_used_blocks"] = batcher.allocator.peak_used
        out["pool_blocks"] = batcher.allocator.capacity
    if oversubscribe > 0:
        out["preemptions"] = stats.preemptions
        out["swap_out_blocks"] = stats.swap_out_blocks
        out["swap_in_blocks"] = stats.swap_in_blocks
        out["reprefill_tokens"] = stats.reprefill_tokens
    if prefix_cache:
        out["cached_prefix_tokens"] = stats.cached_prefix_tokens
        out["prefix_cache_hits"] = batcher.prefix_cache.hits
    if registry is not None:
        out["adapter_requests"] = dict(stats.adapter_requests)
        out["adapter_hits"] = registry.hits
        out["adapter_loads"] = registry.loads
        out["adapter_evictions"] = registry.evictions
    if verbose:
        print(f"served {stats.finished}/{n_requests} requests, "
              f"{stats.generated_tokens} tokens in {stats.decode_steps} "
              f"decode steps, {out['throughput_tok_s']:.1f} tok/s"
              + (f" (sampled, T={temperature:g})" if temperature > 0
                 else "")
              + (f"; {stats.cached_prefix_tokens} prompt tokens served "
                 "from the prefix cache" if prefix_cache else "")
              + (f"; co-trained {stats.train_steps} fused steps "
                 f"(loss {batcher.train_losses[0]:.3f} -> "
                 f"{batcher.train_losses[-1]:.3f})"
                 if batcher.train_losses else "")
              + (f"; {n_adapters} tenants "
                 f"{dict(sorted(stats.adapter_requests.items()))}"
                 if registry is not None else "")
              + (f"; {stats.preemptions} preemptions "
                 f"({stats.swap_out_blocks} blocks swapped, "
                 f"{stats.reprefill_tokens} tokens re-prefilled)"
                 if oversubscribe > 0 else ""))
    return out


def run_multi_replica_serving(
        arch: str, *, n_replicas: int = 2, smoke: bool = True,
        n_requests: int = 16, prompt_len: int = 32, gen_tokens: int = 16,
        batch_size: int = 4, seed: int = 0, paged: bool = False,
        block_size: int = 16, n_blocks: int = 0,
        prefix_cache: bool = False, temperature: float = 0.0,
        top_k: int = 0, top_p: float = 1.0, n_adapters: int = 0,
        prefill_chunk: int = 0, tpot_target: float = 0.0,
        oversubscribe: float = 0.0, swap: bool = True,
        chaos: dict = None, verbose: bool = True) -> dict:
    """Serve ``n_requests`` prompts through the dispatcher-routed
    multi-replica fabric; returns the aggregate cluster summary.
    ``n_adapters > 0`` registers that many LoRA tenants on every
    replica and tags requests round-robin, exercising adapter-affinity
    routing and the batched segmented decode paths.  ``chaos`` (a dict
    of seed/horizon/crashes/stalls/ooms/nan_rounds) arms a seeded
    ``FaultInjector`` against the pool."""
    from repro.core.interfaces import Request
    from repro.runtime.fabric import FabricConfig, build_fabric

    fcfg = FabricConfig(prefill_chunk=prefill_chunk,
                        tpot_target=tpot_target,
                        oversubscribe=oversubscribe, swap=swap)
    injector = _make_injector(n_replicas, chaos) if chaos else None
    fabric, cfg = build_fabric(
        arch, n_replicas, smoke=smoke, n_slots=batch_size,
        prompt_len=prompt_len, gen_tokens=gen_tokens, paged=paged,
        block_size=block_size, n_blocks=n_blocks or None,
        prefix_cache=prefix_cache, seed=seed, n_adapters=n_adapters,
        cfg=fcfg, injector=injector)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=prompt_len, seed=seed)
    prompts = data.sample_tokens(n_requests)[:, :prompt_len]
    stream = cfg.name
    requests = [Request(request_id=i, stream_id=stream, arrival=0.0,
                        deadline=1e9, tokens=gen_tokens,
                        prompt=prompts[i].astype(np.int32),
                        adapter_id=f"tenant{i % n_adapters}"
                        if n_adapters > 0 else None,
                        temperature=temperature, top_k=top_k,
                        top_p=top_p, seed=seed + i)
                for i in range(n_requests)]
    out = fabric.run(requests)
    out["completed"] = sum(1 for r in requests
                           if r.completed_at is not None)
    if verbose:
        c = out["cluster"]
        print(f"fabric served {out['completed']}/{n_requests} requests "
              f"on {c['n_replicas']} replicas: "
              f"{c['generated_tokens']} tokens, "
              f"aggregate {c['throughput_sum_tok_s']:.1f} tok/s "
              f"({c['throughput_wall_tok_s']:.1f} on the shared device)")
        if n_adapters > 0 and c.get("adapters"):
            parts = ", ".join(f"{aid}: {a['requests']}"
                              for aid, a in c["adapters"].items())
            routed = sum(d["adapter_routed"]
                         for d in out["dispatchers"].values())
            print(f"  tenants ({routed} adapter-affinity routed): "
                  f"{parts}")
        for rid, row in out["replicas"].items():
            print(f"  {rid}: {row['finished']} finished, "
                  f"{row['generated_tokens']} tokens, "
                  f"{row['throughput_tok_s']:.1f} tok/s")
        if chaos:
            _print_fault_telemetry(out)
    return out


def run_combined_fabric_serving(
        arch: str, *, n_replicas: int = 2, smoke: bool = True,
        n_requests: int = 16, prompt_len: int = 32, gen_tokens: int = 16,
        batch_size: int = 4, seed: int = 0, paged: bool = False,
        block_size: int = 16, n_blocks: int = 0,
        prefix_cache: bool = False, train_batch: int = 4,
        rounds: int = 2, steps_per_round: int = 4, train_pool: int = 8,
        temperature: float = 0.0, top_k: int = 0, top_p: float = 1.0,
        n_adapters: int = 0, timeout: float = 300.0,
        prefill_chunk: int = 0, tpot_target: float = 0.0,
        oversubscribe: float = 0.0, swap: bool = True,
        chaos: dict = None, verbose: bool = True) -> dict:
    """Live co-execution: serve the trace through the multi-replica
    fabric WHILE the launcher drives incremental FL train sessions over
    the same replicas.  ``train_pool`` fixes the fine-tuning corpus to
    that many batches cycled epoch-style (finite finetuning set; loss
    falls visibly across rounds), 0 streams fresh batches.  Returns the
    aggregate cluster summary plus the launcher's per-round
    loss/version history."""
    from repro.core.interfaces import Request
    from repro.runtime.fabric import FabricConfig, build_fabric

    fcfg = FabricConfig(
        enable_finetuning=True, train_batch=train_batch,
        bootstrap_steps=steps_per_round, steps_per_round=steps_per_round,
        min_cohort=min(2, n_replicas),
        prefill_chunk=prefill_chunk, tpot_target=tpot_target,
        oversubscribe=oversubscribe, swap=swap)
    injector = _make_injector(n_replicas, chaos) if chaos else None
    fabric, cfg = build_fabric(
        arch, n_replicas, smoke=smoke, n_slots=batch_size,
        prompt_len=prompt_len, gen_tokens=gen_tokens, paged=paged,
        block_size=block_size, n_blocks=n_blocks or None,
        prefix_cache=prefix_cache, seed=seed, train_pool=train_pool,
        n_adapters=n_adapters, cfg=fcfg, injector=injector)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=prompt_len, seed=seed)
    prompts = data.sample_tokens(n_requests)[:, :prompt_len]
    stream = cfg.name
    requests = [Request(request_id=i, stream_id=stream, arrival=0.0,
                        deadline=1e9, tokens=gen_tokens,
                        prompt=prompts[i].astype(np.int32),
                        adapter_id=f"tenant{i % n_adapters}"
                        if n_adapters > 0 else None,
                        temperature=temperature, top_k=top_k,
                        top_p=top_p, seed=seed + i)
                for i in range(n_requests)]
    out = fabric.run(requests, min_rounds=rounds, timeout=timeout)
    out["completed"] = sum(1 for r in requests
                           if r.completed_at is not None)
    if verbose:
        c = out["cluster"]
        print(f"combined fabric served {out['completed']}/{n_requests} "
              f"requests on {c['n_replicas']} replicas while completing "
              f"{out['fl_rounds']} FL rounds: {c['generated_tokens']} "
              f"tokens, aggregate {c['throughput_sum_tok_s']:.1f} tok/s, "
              f"{c['train_steps']} fused train steps")
        for r in out["rounds"]:
            print(f"  round {r['round']}: avg member loss "
                  f"{r['avg_loss']:.4f} -> published v{r['version']} "
                  f"({r['members']} members)")
        if n_adapters > 0 and c.get("adapters"):
            for aid, a in c["adapters"].items():
                print(f"  {aid}: {a['requests']} requests, "
                      f"version {a['version_min']}..{a['version_max']}")
        for rid, row in out["replicas"].items():
            tl = row["train_loss"]
            print(f"  {rid}: v{row['adapter_version']}, "
                  f"{row['finished']} finished, "
                  f"{row['throughput_tok_s']:.1f} tok/s"
                  + (f", train CE {tl:.4f}" if tl is not None else ""))
        if chaos:
            _print_fault_telemetry(out)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=1,
                    help="live replicas; > 1 routes the trace through "
                         "the dispatcher-backed multi-replica fabric")
    ap.add_argument("--combined", action="store_true")
    ap.add_argument("--paged", action="store_true")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="paged pool size (0 = full worst case)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share identical prompt prefixes copy-on-write "
                         "over the paged pool (requires --paged)")
    ap.add_argument("--rounds", type=int, default=2,
                    help="FL rounds to drive in --combined --replicas "
                         "mode (best effort, bounded by the timeout)")
    ap.add_argument("--steps-per-round", type=int, default=4,
                    help="fused train steps per FL round in --combined "
                         "--replicas mode")
    ap.add_argument("--train-batch", type=int, default=4,
                    help="co-running train batch (combined modes)")
    ap.add_argument("--chunked-prefill", type=int, default=0,
                    help="prefill chunk size in tokens (default 0 = "
                         "monolithic prefill); > 0 splits each prompt "
                         "into fixed-token chunks interleaved with "
                         "decode ticks (paged mode rounds the chunk up "
                         "to a block multiple); greedy output is "
                         "bit-identical to monolithic prefill")
    ap.add_argument("--tpot-target", type=float, default=0.0,
                    help="decode TPOT SLO target in seconds/token "
                         "(default 0 = no tick budget); > 0 budgets "
                         "each tick: decode first, then prefill chunks "
                         "in deadline-slack order, leftover slack "
                         "admits (possibly shrunk) train microbatches")
    ap.add_argument("--oversubscribe", type=float, default=0.0,
                    help="oversubscribed KV pool watermark in (0, 1] "
                         "(default 0 = preemption-free worst-case "
                         "reservations); > 0 reserves only near-term "
                         "need against that fraction of the pool and "
                         "preempts on exhaustion (victims swap to host "
                         "or drop + re-prefill); requires --paged")
    ap.add_argument("--no-swap", dest="swap", action="store_false",
                    help="disable host swap for preempted requests — "
                         "every victim drops its private KV and "
                         "re-prefills on restore (--oversubscribe only)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, the default)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits (0 = all)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = no filter)")
    ap.add_argument("--adapters", type=int, default=0,
                    help="LoRA tenants to register and round-robin "
                         "requests across (0 = single-adapter serving)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--chaos", action="store_true",
                    help="arm seeded fault injection against the fabric "
                         "(requires --replicas > 1)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the chaos schedule")
    ap.add_argument("--chaos-horizon", type=float, default=5.0,
                    help="fault schedule horizon in seconds")
    ap.add_argument("--chaos-crashes", type=int, default=1,
                    help="replica crashes to schedule")
    ap.add_argument("--chaos-stalls", type=int, default=1,
                    help="straggler stalls to schedule")
    ap.add_argument("--chaos-ooms", type=int, default=0,
                    help="admission OOMs to schedule")
    ap.add_argument("--chaos-nan-rounds", type=int, default=0,
                    help="NaN-poisoned train rounds to schedule "
                         "(combined mode)")
    args = ap.parse_args()
    if args.prefix_cache and not args.paged:
        ap.error("--prefix-cache requires --paged (sharing rides on "
                 "pool block aliasing)")
    if args.oversubscribe and not args.paged:
        ap.error("--oversubscribe requires --paged (preemption swaps "
                 "pool blocks)")
    if args.chaos and args.replicas < 2:
        ap.error("--chaos requires --replicas > 1 (fault tolerance is "
                 "a property of the pool)")
    chaos = None
    if args.chaos:
        chaos = {"seed": args.chaos_seed, "horizon": args.chaos_horizon,
                 "crashes": args.chaos_crashes,
                 "stalls": args.chaos_stalls, "ooms": args.chaos_ooms,
                 "nan_rounds": args.chaos_nan_rounds}
    if args.replicas > 1:
        if args.combined:
            # the full co-execution path: launcher-driven incremental
            # train sessions over the live fabric
            run_combined_fabric_serving(
                args.arch, n_replicas=args.replicas,
                n_requests=args.requests, prompt_len=args.prompt_len,
                gen_tokens=args.gen, batch_size=args.batch,
                paged=args.paged, block_size=args.block_size,
                n_blocks=args.n_blocks, prefix_cache=args.prefix_cache,
                train_batch=args.train_batch, rounds=args.rounds,
                steps_per_round=args.steps_per_round,
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p, n_adapters=args.adapters,
                prefill_chunk=args.chunked_prefill,
                tpot_target=args.tpot_target,
                oversubscribe=args.oversubscribe, swap=args.swap,
                seed=args.seed, chaos=chaos)
            return
        run_multi_replica_serving(
            args.arch, n_replicas=args.replicas,
            n_requests=args.requests, prompt_len=args.prompt_len,
            gen_tokens=args.gen, batch_size=args.batch,
            paged=args.paged, block_size=args.block_size,
            n_blocks=args.n_blocks, prefix_cache=args.prefix_cache,
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, n_adapters=args.adapters,
            prefill_chunk=args.chunked_prefill,
            tpot_target=args.tpot_target,
            oversubscribe=args.oversubscribe, swap=args.swap,
            seed=args.seed, chaos=chaos)
        return
    run_serving(args.arch, n_requests=args.requests,
                prompt_len=args.prompt_len, gen_tokens=args.gen,
                batch_size=args.batch, combined=args.combined,
                train_batch=args.train_batch,
                paged=args.paged, block_size=args.block_size,
                n_blocks=args.n_blocks, prefix_cache=args.prefix_cache,
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p, n_adapters=args.adapters,
                prefill_chunk=args.chunked_prefill,
                tpot_target=args.tpot_target,
                oversubscribe=args.oversubscribe, swap=args.swap,
                seed=args.seed)


if __name__ == "__main__":
    main()
