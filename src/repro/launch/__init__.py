"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers,
perf-iteration harness.  NOTE: ``dryrun``/``perf`` set XLA_FLAGS for 512
host devices at import — import them only in dedicated processes.
"""
from repro.launch.mesh import make_production_mesh, rules_for  # noqa: F401
