import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

__doc__ = """Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input shape) cell on the
single-pod 16×16 and multi-pod 2×16×16 production meshes, prints
``compiled.memory_analysis()`` (proves it fits) and
``compiled.cost_analysis()`` (FLOPs/bytes for §Roofline), extracts the
collective schedule from the optimized HLO, and writes one JSON record
per cell under results/dryrun/.

The two os.environ lines above MUST run before any other import — jax
locks the device count at first init.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --arch llama3-8b --all-shapes --multi-pod
  python -m repro.launch.dryrun --all            # every runnable cell
  python -m repro.launch.dryrun --list           # cells + skip reasons
  python -m repro.launch.dryrun --arch llama3-8b --combined
                                                 # the paper's fused step
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ALL_SHAPES, Family, ModelConfig, ShapeCell, applicable_shapes,
)
from repro.configs.registry import ARCH_IDS, get_config
from repro.core.engine import make_engine
from repro.launch import hlo_analysis
from repro.launch.mesh import (
    batch_shardings, make_production_mesh, param_shardings, rules_for,
)
from repro.models.sharding import ShardingRules, sharding_context

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "..", "..", "..", "results", "dryrun")


def _cell_cfg(cfg: ModelConfig, kind: str, remat: str = "full"
              ) -> ModelConfig:
    if kind == "train":
        return dataclasses.replace(cfg, remat=remat)
    return dataclasses.replace(cfg, remat="none")


def default_grad_accum(cfg: ModelConfig, cell: ShapeCell) -> int:
    """Microbatch count for train cells: keep per-microbatch activations
    bounded so the big MoE/VLM archs fit 16 GB HBM (tuned empirically in
    EXPERIMENTS.md §Dry-run)."""
    if cell.kind != "train":
        return 1
    if cfg.d_model >= 8192:
        return 16   # vision-90b class: fits 13.0 GiB (§Perf appendix)
    if cfg.family is Family.MOE or cfg.d_model >= 6144:
        return 8
    if cfg.d_model >= 4096:
        return 4
    return 2


def _compile_cell(cfg: ModelConfig, cell: ShapeCell, mesh, rules, *,
                  block_kv: int, skip_masked_blocks: bool,
                  ce_chunk: int = 512, grad_accum: int = 1,
                  prefill_chunks: int = 1):
    """Lower + compile one step program; returns the compiled object."""
    engine = make_engine(cfg)
    model = engine.model
    with sharding_context(mesh, rules):
        param_specs = model.param_specs()
        lora_specs = model.lora_specs()
        inputs = model.input_specs(cell)
        p_sh = param_shardings(param_specs, cfg, mesh, rules)
        l_sh = param_shardings(lora_specs, cfg, mesh, rules)

        out_sh = None
        if cell.kind == "train":
            def fn(params, lora, opt_state, batch):
                return engine.train_step(
                    params, lora, opt_state, batch,
                    skip_masked_blocks=skip_masked_blocks,
                    ce_chunk=ce_chunk, grad_accum=grad_accum)
            donate = (1, 2)
            opt_specs = jax.eval_shape(engine.optimizer.init, lora_specs)
            o_sh = param_shardings(opt_specs, cfg, mesh, rules)
            b_sh = batch_shardings(inputs["batch"], cfg, mesh, rules)
            args = (param_specs, lora_specs, opt_specs, inputs["batch"])
            in_sh = (p_sh, l_sh, o_sh, b_sh)
            # donated outputs must keep the donors' shardings
            # (shardings accept pytree prefixes: None = XLA's choice)
            out_sh = (l_sh, o_sh, None)
        elif cell.kind == "prefill":
            if cfg.encoder_only:
                def fn(params, lora, batch):
                    return engine.encoder_serve_step(params, lora, batch)
            elif prefill_chunks <= 1:
                def fn(params, lora, batch):
                    return model.prefill(
                        params, lora, batch, block_kv=block_kv,
                        skip_masked_blocks=skip_masked_blocks)
            else:
                # batch-microchunked prefill: only one chunk's
                # activations are live at a time (same lever as
                # grad_accum for train cells); caches/logits re-merge
                # on the batch axis afterwards.  Non-VLM caches carry
                # batch right after the stacked-layer dim (axis 1).
                assert cfg.family is not Family.VLM, \
                    "prefill_chunks not wired for VLM cache layout"

                def fn(params, lora, batch):
                    nb = prefill_chunks

                    def split(x):
                        return x.reshape((nb, x.shape[0] // nb)
                                         + x.shape[1:])

                    sub = jax.tree.map(split, batch)

                    def body(_, b):
                        lg, caches = model.prefill(
                            params, lora, b, block_kv=block_kv,
                            skip_masked_blocks=skip_masked_blocks)
                        return None, (lg, caches)

                    _, (lgs, caches) = jax.lax.scan(body, None, sub)

                    def merge(x):
                        # [nb, L, B/nb, ...] -> [L, B, ...] chunk-major
                        moved = jnp.moveaxis(x, 0, 1)
                        return moved.reshape(
                            (moved.shape[0],
                             moved.shape[1] * moved.shape[2])
                            + moved.shape[3:])

                    logits = lgs.reshape((-1,) + lgs.shape[2:])
                    caches = jax.tree.map(merge, caches)
                    return logits, caches
            donate = ()
            b_sh = batch_shardings(inputs["batch"], cfg, mesh, rules)
            args = (param_specs, lora_specs, inputs["batch"])
            in_sh = (p_sh, l_sh, b_sh)
            if not cfg.encoder_only:
                # the returned KV/SSM caches MUST be sharded like the
                # decode step consumes them — without this, XLA picks a
                # replicated layout (grok: 17 GiB/dev of output)
                out_struct = jax.eval_shape(fn, *args)
                cache_sh = batch_shardings(out_struct[1], cfg, mesh,
                                           rules)
                out_sh = (None, cache_sh)
        else:
            def fn(params, lora, caches, token, pos):
                return model.decode_step(params, lora, caches, token, pos)
            donate = (2,)
            c_sh = batch_shardings(inputs["caches"], cfg, mesh, rules)
            t_sh = batch_shardings(
                {"token": inputs["token"], "pos": inputs["pos"]},
                cfg, mesh, rules)
            args = (param_specs, lora_specs, inputs["caches"],
                    inputs["token"], inputs["pos"])
            in_sh = (p_sh, l_sh, c_sh, t_sh["token"], t_sh["pos"])
            out_sh = (None, c_sh)   # donation-aligned cache layout

        if out_sh is not None:
            jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate)
        else:
            jfn = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
        lowered = jfn.lower(*args)
        compiled = lowered.compile()
    return compiled


def _cost_of(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_bytes(hlo)
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    over = float(hlo_analysis.slice_overcount(hlo))
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": raw_bytes,
            "bytes_corrected": max(raw_bytes - over, 0.0),
            "slice_overcount": over,
            "coll": float(coll["total"]),
            "coll_detail": coll}


def calibrate_cost(base_cfg: ModelConfig, cell: ShapeCell, mesh, rules, *,
                   block_kv: int, skip_masked_blocks: bool,
                   remat: str) -> Dict[str, Any]:
    """XLA's HLOCostAnalysis counts a while-loop body ONCE regardless of
    trip count, so scanned programs under-report FLOPs/bytes by ~trip×.
    Calibration: compile two reduced-depth variants of the same cell
    (identical widths/shapes/mesh) with EVERY loop unrolled — layer
    loop, attention KV-block loop (flash-style online-softmax traffic,
    matching the Pallas kernel's HBM behavior), unchunked CE — fit
    cost(L) = fixed + L·per_layer, extrapolate to the real depth.
    Documented in EXPERIMENTS.md §Roofline."""
    if base_cfg.family is Family.VLM:
        step = base_cfg.cross_attn_every          # extrapolate in units
        depths = (step, 2 * step)
    else:
        depths = (2, 4)
    # keep the unrolled KV loop bounded: ≥8 blocks, ≤16 blocks
    cal_block_kv = max(block_kv, cell.seq_len // 16) \
        if cell.kind in ("train", "prefill") else block_kv
    costs = []
    for L in depths:
        cfg_s = dataclasses.replace(
            base_cfg, n_layers=L, scan_layers=False,
            attn_impl="blockwise" if base_cfg.has_attention else "auto",
            unroll_attn_blocks=True,
            remat=remat if cell.kind == "train" else "none")
        ce_chunk = cell.seq_len if cell.kind == "train" else 512
        comp = _compile_cell(cfg_s, cell, mesh, rules,
                             block_kv=cal_block_kv,
                             skip_masked_blocks=skip_masked_blocks,
                             ce_chunk=ce_chunk)
        costs.append(_cost_of(comp))
    l1, l2 = depths
    full = base_cfg.n_layers
    out = {}
    for key in ("flops", "bytes", "bytes_corrected", "coll"):
        per_layer = (costs[1][key] - costs[0][key]) / (l2 - l1)
        fixed = costs[0][key] - l1 * per_layer
        out[key] = max(fixed + full * per_layer, 0.0)
        out[f"{key}_per_layer"] = per_layer
        out[f"{key}_fixed"] = fixed
    out["depths"] = depths
    return out


def lower_cell(arch: str, cell: ShapeCell, *, multi_pod: bool = False,
               rules_override: Optional[ShardingRules] = None,
               remat: str = "full", block_kv: int = 512,
               skip_masked_blocks: bool = False,
               verbose: bool = True, save: bool = True,
               calibrate: bool = True, grad_accum: int = 0,
               unroll_layers: bool = False, attn_f32: bool = True,
               kv_cache_dtype: str = "", prefill_chunks: int = 1,
               tag: str = "") -> Dict[str, Any]:
    """Lower + compile one cell; returns the analysis record."""
    t0 = time.time()
    base_cfg = get_config(arch)
    cfg = _cell_cfg(base_cfg, cell.kind, remat)
    if unroll_layers:
        cfg = dataclasses.replace(cfg, scan_layers=False)
    if kv_cache_dtype:
        cfg = dataclasses.replace(cfg, kv_cache_dtype=kv_cache_dtype)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, mesh, cell.kind, base=rules_override)
    if grad_accum <= 0:
        grad_accum = default_grad_accum(cfg, cell)

    compiled = _compile_cell(cfg, cell, mesh, rules, block_kv=block_kv,
                             skip_masked_blocks=skip_masked_blocks,
                             grad_accum=grad_accum,
                             prefill_chunks=prefill_chunks)
    t_compile = time.time() - t0
    t_lower = 0.0

    mem = compiled.memory_analysis()
    raw = _cost_of(compiled)
    coll = raw["coll_detail"]
    n_dev = mesh.size

    if calibrate and not multi_pod:
        cal = calibrate_cost(cfg, cell, mesh, rules, block_kv=block_kv,
                             skip_masked_blocks=skip_masked_blocks,
                             remat=remat)
        flops_dev, bytes_dev, coll_dev = cal["flops"], cal["bytes"], \
            cal["coll"]
        bytes_corr = cal["bytes_corrected"]
    else:
        cal = None
        flops_dev, bytes_dev, coll_dev = raw["flops"], raw["bytes"], \
            raw["coll"]
        bytes_corr = raw["bytes_corrected"]

    terms = hlo_analysis.roofline(flops_dev, bytes_dev, coll_dev, n_dev)
    terms_corr = hlo_analysis.roofline(flops_dev, bytes_corr, coll_dev,
                                       n_dev)
    mf = hlo_analysis.model_flops(base_cfg, cell)

    record = {
        "arch": arch, "shape": cell.name, "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "tag": tag,
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "grad_accum": grad_accum,
        "remat": cfg.remat if cell.kind == "train" else "none",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_device_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device": bytes_dev,
                 "raw_flops_scan_module": raw["flops"],
                 "raw_bytes_scan_module": raw["bytes"],
                 "calibration": dict(cal) if cal else None},
        "collectives": dict(coll, calibrated_total=coll_dev),
        "roofline": terms.as_dict(),
        # memory term with slice/DUS operand-overcount removed (the
        # physical-traffic view; see hlo_analysis.slice_overcount)
        "roofline_corrected": terms_corr.as_dict(),
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / max(flops_dev, 1.0),
        "params_total": base_cfg.param_count(),
        "params_active": base_cfg.active_param_count(),
        "lora_params": base_cfg.lora_param_count(),
    }

    if verbose:
        gb = 1024 ** 3
        print(f"[{arch} × {cell.name} × {record['mesh']}]"
              f"{' ' + tag if tag else ''}")
        print(f"  lower {t_lower:.1f}s  compile {t_compile:.1f}s")
        print(f"  memory_analysis: args "
              f"{mem.argument_size_in_bytes / gb:.2f} GiB + temp "
              f"{mem.temp_size_in_bytes / gb:.2f} GiB + out "
              f"{mem.output_size_in_bytes / gb:.2f} GiB - alias "
              f"{mem.alias_size_in_bytes / gb:.2f} GiB = peak "
              f"{record['memory']['peak_device_bytes'] / gb:.2f} GiB/dev")
        print(f"  cost_analysis: {flops_dev:.3e} FLOP/dev, "
              f"{bytes_dev:.3e} B/dev")
        print(f"  collectives: {coll['count']} ops, "
              f"{coll['total'] / gb:.3f} GiB/dev "
              f"(AR {coll['all-reduce'] / gb:.3f} AG "
              f"{coll['all-gather'] / gb:.3f} RS "
              f"{coll['reduce-scatter'] / gb:.3f} A2A "
              f"{coll['all-to-all'] / gb:.3f} CP "
              f"{coll['collective-permute'] / gb:.3f})")
        r = record["roofline"]
        print(f"  roofline: compute {r['compute_s'] * 1e3:.2f} ms | "
              f"memory {r['memory_s'] * 1e3:.2f} ms | collective "
              f"{r['collective_s'] * 1e3:.2f} ms -> {r['dominant']}-bound")
        rc = record["roofline_corrected"]
        print(f"  corrected (slice-overcount removed): memory "
              f"{rc['memory_s'] * 1e3:.2f} ms -> {rc['dominant']}-bound")
        print(f"  useful-FLOPs ratio {record['useful_flops_ratio']:.3f}")

    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        fn_out = os.path.join(
            RESULTS_DIR,
            f"{arch.replace('.', '_')}_{cell.name}_"
            f"{record['mesh'].replace('x', '-')}{suffix}.json")
        with open(fn_out, "w") as f:
            json.dump(record, f, indent=1)
    return record


# --------------------------------------------------------------------------
COMBINED_CELL = ShapeCell("combined_4k_32k", 4096, 64, "combined")


def _compile_combined(cfg: ModelConfig, mesh, rules, *,
                      grad_accum: int = 1, ce_chunk: int = 512):
    engine = make_engine(cfg)
    model = engine.model
    train_cell = ShapeCell("combined_train", 4096, 64, "train")
    decode_cell = ShapeCell("combined_decode", 32768, 128, "decode")

    def fn(params, lora, opt_state, tb, caches, token, pos):
        return engine.combined_step(params, lora, opt_state, tb, caches,
                                    token, pos)

    with sharding_context(mesh, rules):
        param_specs = model.param_specs()
        lora_specs = model.lora_specs()
        opt_specs = jax.eval_shape(engine.optimizer.init, lora_specs)
        tb = model.input_specs(train_cell)["batch"]
        dc = model.input_specs(decode_cell)
        p_sh = param_shardings(param_specs, cfg, mesh, rules)
        l_sh = param_shardings(lora_specs, cfg, mesh, rules)
        o_sh = param_shardings(opt_specs, cfg, mesh, rules)
        tb_sh = batch_shardings(tb, cfg, mesh, rules)
        c_sh = batch_shardings(dc["caches"], cfg, mesh, rules)
        tk_sh = batch_shardings({"token": dc["token"], "pos": dc["pos"]},
                                cfg, mesh, rules)
        jfn = jax.jit(fn,
                      in_shardings=(p_sh, l_sh, o_sh, tb_sh, c_sh,
                                    tk_sh["token"], tk_sh["pos"]),
                      out_shardings=(l_sh, o_sh, None, c_sh, None),
                      donate_argnums=(1, 2, 4))
        lowered = jfn.lower(param_specs, lora_specs, opt_specs, tb,
                            dc["caches"], dc["token"], dc["pos"])
        compiled = lowered.compile()
    return compiled


def lower_combined(arch: str, *, multi_pod: bool = False,
                   verbose: bool = True, save: bool = True,
                   calibrate: bool = True) -> Dict[str, Any]:
    """Lower the paper's fused combined_step: a LoRA train microbatch
    plus a decode batch over shared base weights in ONE XLA program."""
    t0 = time.time()
    base_cfg = get_config(arch)
    cfg = dataclasses.replace(base_cfg, remat="full")
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, mesh, "train")
    compiled = _compile_combined(cfg, mesh, rules)

    mem = compiled.memory_analysis()
    raw = _cost_of(compiled)
    coll = raw["coll_detail"]
    if calibrate and not multi_pod:
        depths = (2, 4)
        costs = []
        for L in depths:
            cfg_s = dataclasses.replace(
                cfg, n_layers=L, scan_layers=False,
                attn_impl="blockwise", unroll_attn_blocks=True)
            costs.append(_cost_of(_compile_combined(
                cfg_s, mesh, rules, ce_chunk=4096)))
        flops_dev, bytes_dev, coll_dev = (
            max(costs[0][k] + (costs[1][k] - costs[0][k]) / 2
                * (cfg.n_layers - 2), 0.0)
            for k in ("flops", "bytes", "coll"))
    else:
        flops_dev, bytes_dev, coll_dev = raw["flops"], raw["bytes"], \
            raw["coll"]
    terms = hlo_analysis.roofline(flops_dev, bytes_dev, coll_dev,
                                  mesh.size)
    record = {
        "arch": arch, "shape": COMBINED_CELL.name, "kind": "combined",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.size,
        "compile_s": round(time.time() - t0, 2),
        "memory": {"argument_bytes": mem.argument_size_in_bytes,
                   "temp_bytes": mem.temp_size_in_bytes,
                   "output_bytes": mem.output_size_in_bytes,
                   "alias_bytes": mem.alias_size_in_bytes,
                   "peak_device_bytes": mem.argument_size_in_bytes
                   + mem.output_size_in_bytes + mem.temp_size_in_bytes
                   - mem.alias_size_in_bytes},
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device": bytes_dev},
        "collectives": coll,
        "roofline": terms.as_dict(),
    }
    if verbose:
        gb = 1024 ** 3
        print(f"[{arch} × combined_step × {record['mesh']}] — the paper's "
              f"model-sharing fusion")
        print(f"  compile {record['compile_s']}s; peak "
              f"{record['memory']['peak_device_bytes'] / gb:.2f} GiB/dev; "
              f"{flops_dev:.3e} FLOP/dev; collectives "
              f"{coll['total'] / gb:.3f} GiB/dev")
        r = record["roofline"]
        print(f"  roofline: compute {r['compute_s'] * 1e3:.2f} ms | memory "
              f"{r['memory_s'] * 1e3:.2f} ms | collective "
              f"{r['collective_s'] * 1e3:.2f} ms -> {r['dominant']}-bound")
    if save:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        fn_out = os.path.join(
            RESULTS_DIR, f"{arch.replace('.', '_')}_combined_"
            f"{record['mesh'].replace('x', '-')}.json")
        with open(fn_out, "w") as f:
            json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=[c.name for c in ALL_SHAPES])
    ap.add_argument("--all-shapes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every runnable (arch × shape) cell")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--combined", action="store_true",
                    help="lower the fused combined_step for --arch")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--remat", default="full",
                    choices=["none", "full", "dots"])
    ap.add_argument("--grad-accum", type=int, default=0,
                    help="microbatches for train cells (0 = heuristic)")
    ap.add_argument("--block-kv", type=int, default=512)
    ap.add_argument("--skip-masked-blocks", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-save", action="store_true")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip cells whose result JSON already exists")
    args = ap.parse_args()

    if args.list:
        for arch in ARCH_IDS:
            cfg = get_config(arch)
            for cell, skip in applicable_shapes(cfg):
                status = skip if skip else "runnable"
                print(f"{arch:24s} {cell.name:12s} {status}")
        return

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    def cells_for(arch: str):
        cfg = get_config(arch)
        for cell, skip in applicable_shapes(cfg):
            if args.shape and cell.name != args.shape:
                continue
            if not args.shape and not (args.all_shapes or args.all):
                continue
            if skip:
                print(f"[{arch} × {cell.name}] SKIPPED: {skip}")
                continue
            yield cell

    archs = ARCH_IDS if args.all else ([args.arch] if args.arch else [])
    if not archs:
        ap.error("need --arch, --all, or --list")

    failures = []
    for arch in archs:
        if args.combined:
            for mp in meshes:
                lower_combined(arch, multi_pod=mp, save=not args.no_save)
            continue
        for cell in cells_for(arch):
            for mp in meshes:
                if args.skip_existing:
                    mesh_tag = "2-16-16" if mp else "16-16"
                    suffix = f"_{args.tag}" if args.tag else ""
                    path = os.path.join(
                        RESULTS_DIR, f"{arch.replace('.', '_')}_"
                        f"{cell.name}_{mesh_tag}{suffix}.json")
                    if os.path.exists(path):
                        print(f"[{arch} × {cell.name} × {mesh_tag}] cached")
                        continue
                try:
                    lower_cell(arch, cell, multi_pod=mp, remat=args.remat,
                               block_kv=args.block_kv,
                               grad_accum=args.grad_accum,
                               skip_masked_blocks=args.skip_masked_blocks,
                               tag=args.tag, save=not args.no_save)
                except Exception as e:
                    failures.append((arch, cell.name, mp, repr(e)))
                    traceback.print_exc()
    if failures:
        print("\nFAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
