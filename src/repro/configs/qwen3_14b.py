"""qwen3-14b — dense decoder with qk_norm + GQA.

[hf:Qwen/Qwen3-8B; hf] 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936, head_dim=128, qk_norm.
"""
from repro.configs.base import Family, LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family=Family.DENSE,
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    lora=LoRAConfig(targets=("q", "k", "v", "o")),
    source="hf:Qwen/Qwen3-8B; hf",
)
