"""grok-1-314b — MoE, 8 experts top-2.

[hf:xai-org/grok-1; unverified] 64L d_model=6144 48H (GQA kv=8)
d_ff=32768 vocab=131072, MoE 8e top-2.

Sharding note: 8 experts do not divide the 16-way model axis, so expert
weights are sharded expert-wise 8-way x ff-wise 2-way ("tp" hybrid); see
launch/mesh.py sharding rules.
"""
from repro.configs.base import Family, LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family=Family.MOE,
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    top_k=2,
    moe_shard="tp",
    lora=LoRAConfig(targets=("q", "k", "v", "o")),
    source="hf:xai-org/grok-1; unverified",
)
