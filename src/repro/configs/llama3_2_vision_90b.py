"""llama-3.2-vision-90b — decoder with interleaved cross-attention layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified] 100L d_model=8192 64H
(GQA kv=8) d_ff=28672 vocab=128256.  Every 5th layer cross-attends to
vision tokens; the vision frontend is a STUB (``input_specs()`` provides
precomputed patch embeddings of shape [batch, vision_tokens, d_model]).
"""
from repro.configs.base import Family, LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family=Family.VLM,
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5,
    vision_tokens=1601,
    lora=LoRAConfig(targets=("q", "k", "v", "o")),
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
