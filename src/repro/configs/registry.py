"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs import (
    mamba2_780m, hubert_xlarge, qwen3_14b, qwen1_5_0_5b, internlm2_1_8b,
    llama3_8b, hymba_1_5b, moonshot_v1_16b_a3b, grok1_314b,
    llama3_2_vision_90b,
)

_REGISTRY: Dict[str, ModelConfig] = {
    "mamba2-780m": mamba2_780m.CONFIG,
    "hubert-xlarge": hubert_xlarge.CONFIG,
    "qwen3-14b": qwen3_14b.CONFIG,
    "qwen1.5-0.5b": qwen1_5_0_5b.CONFIG,
    "internlm2-1.8b": internlm2_1_8b.CONFIG,
    "llama3-8b": llama3_8b.CONFIG,
    "hymba-1.5b": hymba_1_5b.CONFIG,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b.CONFIG,
    "grok-1-314b": grok1_314b.CONFIG,
    "llama-3.2-vision-90b": llama3_2_vision_90b.CONFIG,
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(arch: str) -> ModelConfig:
    try:
        return _REGISTRY[arch]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch!r}; available: {', '.join(ARCH_IDS)}")


def all_configs() -> Dict[str, ModelConfig]:
    return dict(_REGISTRY)
