from repro.configs.base import (  # noqa: F401
    ALL_SHAPES, DECODE_32K, Family, LONG_500K, LoRAConfig, ModelConfig,
    PREFILL_32K, ShapeCell, TRAIN_4K, applicable_shapes,
)
