"""hubert-xlarge — encoder-only audio backbone (same arch as wav2vec2).

[arXiv:2106.07447; unverified] 48L d_model=1280 16H (GQA kv=16) d_ff=5120
vocab=504 (k-means codebook units -> frame classifier head).
The audio frontend (conv feature extractor) is a STUB: ``input_specs()``
provides precomputed frame embeddings.
"""
from repro.configs.base import Family, LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family=Family.ENCODER,
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    encoder_only=True,
    lora=LoRAConfig(targets=("q", "k", "v", "o")),
    source="arXiv:2106.07447; unverified",
)
