"""Unified model/run configuration for every assigned architecture.

One ``ModelConfig`` describes any member of the five families this repo
supports (dense / ssm / hybrid / moe / encoder / vlm).  Family-specific
fields are simply unused by the others.  All assigned architectures in
``src/repro/configs/<arch>.py`` instantiate this dataclass with the exact
published numbers; reduced (smoke) variants are derived via ``scaled()``.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional, Tuple


class Family(str, enum.Enum):
    DENSE = "dense"      # decoder-only full-attention transformer
    SSM = "ssm"          # attention-free state-space (Mamba2 / SSD)
    HYBRID = "hybrid"    # parallel attention + SSM heads (Hymba)
    MOE = "moe"          # decoder-only with mixture-of-experts MLPs
    ENCODER = "encoder"  # encoder-only (HuBERT audio backbone)
    VLM = "vlm"          # decoder with interleaved cross-attention layers


@dataclasses.dataclass(frozen=True)
class LoRAConfig:
    """LoRA adapter surface (the paper's unified PEFT interface)."""
    rank: int = 16
    alpha: float = 32.0
    # projections that receive adapters; subset of
    # {"q","k","v","o","gate","up","down","ssm_in","ssm_out"}
    targets: Tuple[str, ...] = ("q", "k", "v", "o")
    dropout: float = 0.0

    @property
    def scaling(self) -> float:
        return self.alpha / float(self.rank)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // n_heads

    # ---- attention options -------------------------------------------------
    qk_norm: bool = False                  # qwen3-style per-head RMSNorm
    qkv_bias: bool = False                 # qwen1.5-style projection bias
    rope_theta: float = 10000.0
    sliding_window: int = 0                # 0 = full attention
    # ---- SSM (mamba2 / hymba) ---------------------------------------------
    ssm_state: int = 0                     # d_state (N)
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # ---- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ---- VLM ----------------------------------------------------------------
    cross_attn_every: int = 0              # every Nth layer is cross-attn
    vision_tokens: int = 1601              # stub frontend patch-embedding count
    # ---- encoder ------------------------------------------------------------
    encoder_only: bool = False
    # ---- numerics / memory ---------------------------------------------------
    dtype: str = "bfloat16"                # activations
    param_dtype: str = "bfloat16"
    remat: str = "none"                    # none | block | full
    scan_layers: bool = True
    attn_impl: str = "auto"                # auto | dense | blockwise
    unroll_attn_blocks: bool = False       # cost-calibration variant
    kv_cache_dtype: str = ""               # "" = activation dtype;
                                           # "float8_e4m3fn" halves caches
    # ---- adapters -----------------------------------------------------------
    lora: LoRAConfig = dataclasses.field(default_factory=LoRAConfig)
    # ---- MoE sharding mode: "ep" experts over model axis, "tp" ff over it ---
    moe_shard: str = "auto"
    # ---- provenance ----------------------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------ utils
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError(
                f"{self.name}: n_heads={self.n_heads} not divisible by "
                f"n_kv_heads={self.n_kv_heads}")

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def has_attention(self) -> bool:
        return self.family is not Family.SSM

    @property
    def has_ssm(self) -> bool:
        return self.family in (Family.SSM, Family.HYBRID)

    @property
    def has_decode(self) -> bool:
        return not self.encoder_only

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (sub-quadratic attention)."""
        return self.family is Family.SSM or (
            self.family is Family.HYBRID and self.sliding_window > 0)

    # ---------------------------------------------------------- param counts
    def param_count(self) -> int:
        """Total base parameters (embedding included, untied head)."""
        d, h = self.d_model, self.head_dim
        per_layer = 0
        if self.has_attention:
            per_layer += d * (self.n_heads * h)            # q
            per_layer += 2 * d * (self.n_kv_heads * h)     # k, v
            per_layer += (self.n_heads * h) * d            # o
            if self.qkv_bias:
                per_layer += (self.n_heads + 2 * self.n_kv_heads) * h
        if self.has_ssm:
            di, n = self.ssm_d_inner, self.ssm_state
            per_layer += d * (2 * di + 2 * n + self.ssm_n_heads)  # in_proj
            per_layer += di * d                                   # out_proj
            per_layer += self.ssm_conv_width * (di + 2 * n)       # conv
            per_layer += 2 * self.ssm_n_heads                     # A_log, D
        if self.d_ff > 0:
            ff = 3 * d * self.d_ff                          # gate/up/down
            if self.family is Family.MOE:
                per_layer += self.n_experts * ff + d * self.n_experts
            else:
                per_layer += ff
        per_layer += 2 * d                                  # 2 rmsnorm scales
        total = self.n_layers * per_layer
        total += self.vocab_size * d                        # embed
        if not self.encoder_only:
            total += self.vocab_size * d                    # lm head (untied)
        total += d                                          # final norm
        if self.family is Family.VLM and self.cross_attn_every:
            n_cross = self.n_layers // self.cross_attn_every
            cross = 2 * (d * self.n_heads * h + d * self.n_kv_heads * h)
            total += n_cross * (cross + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family is not Family.MOE or not self.n_experts:
            return self.param_count()
        ff = 3 * self.d_model * self.d_ff
        inactive = self.n_layers * (self.n_experts - self.top_k) * ff
        return self.param_count() - inactive

    def lora_param_count(self) -> int:
        d, h, r = self.d_model, self.head_dim, self.lora.rank
        dims = {
            "q": (d, self.n_heads * h), "k": (d, self.n_kv_heads * h),
            "v": (d, self.n_kv_heads * h), "o": (self.n_heads * h, d),
            "gate": (d, self.d_ff), "up": (d, self.d_ff),
            "down": (self.d_ff, d),
            "ssm_in": (d, 2 * self.ssm_d_inner + 2 * self.ssm_state
                       + self.ssm_n_heads),
            "ssm_out": (self.ssm_d_inner, d),
        }
        total = 0
        for t in self.lora.targets:
            if t not in dims:
                continue
            di, do = dims[t]
            if do <= 0 or di <= 0:
                continue
            total += r * (di + do)
        return self.n_layers * total

    # ----------------------------------------------------------- reductions
    def scaled(self, *, n_layers: int = 2, d_model: int = 128,
               n_heads: int = 4, d_ff: int = 256, vocab_size: int = 512,
               **kw) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kv = max(1, self.n_kv_heads * n_heads // self.n_heads)
        upd = dict(
            name=self.name + "-smoke", n_layers=n_layers, d_model=d_model,
            n_heads=n_heads, n_kv_heads=kv, head_dim=d_model // n_heads,
            d_ff=0 if self.d_ff == 0 else d_ff, vocab_size=vocab_size,
            dtype="float32", param_dtype="float32", remat="none",
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=min(self.ssm_head_dim, 32),
            ssm_chunk=32,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            sliding_window=min(self.sliding_window, 32)
            if self.sliding_window else 0,
            # keep ≥1 full (self*, cross) unit in reduced VLM stacks
            cross_attn_every=min(self.cross_attn_every, max(n_layers, 2))
            if self.cross_attn_every else 0,
            vision_tokens=16 if self.family is Family.VLM else self.vision_tokens,
            lora=dataclasses.replace(self.lora, rank=4, alpha=8.0),
        )
        upd.update(kw)
        return dataclasses.replace(self, **upd)


# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell: what gets lowered in the dry-run."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeCell("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def applicable_shapes(cfg: ModelConfig):
    """The runnable subset of the four assigned shapes, with skip reasons."""
    out = []
    for cell in ALL_SHAPES:
        if cell.kind == "decode" and not cfg.has_decode:
            out.append((cell, "skip: encoder-only arch has no decode step"))
        elif cell is LONG_500K and not cfg.subquadratic:
            out.append((cell, "skip: long_500k requires sub-quadratic attention"))
        else:
            out.append((cell, ""))
    return out
