"""mamba2-780m — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified] 48L d_model=1536 d_ff=0 vocab=50280
ssm_state=128.
"""
from repro.configs.base import Family, LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family=Family.SSM,
    n_layers=48,
    d_model=1536,
    n_heads=1,              # unused (attention-free); keeps divisibility
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,                 # mamba2 block has no separate MLP
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    lora=LoRAConfig(targets=("ssm_in", "ssm_out")),
    source="arXiv:2405.21060; unverified",
)
