"""llama3-8b — dense decoder, GQA, 128k vocab.  The paper's own serving
model is LLaMA-3.1-8B, so this arch is the paper-representative cell.

[arXiv:2407.21783; unverified] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256.
"""
from repro.configs.base import Family, LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family=Family.DENSE,
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    lora=LoRAConfig(targets=("q", "k", "v", "o")),
    source="arXiv:2407.21783; unverified",
)
