"""hymba-1.5b — hybrid: parallel attention + mamba heads per layer.

[arXiv:2411.13676; hf] 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16.

Adaptation note (DESIGN.md section 8): attention uses a 2048-token sliding
window in every layer (the published Hymba uses SWA in all but 3 layers plus
meta tokens); this preserves the sub-quadratic property required for the
long_500k cell and keeps the layer stack homogeneous for scan-over-layers.
"""
from repro.configs.base import Family, LoRAConfig, ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family=Family.HYBRID,
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    sliding_window=2048,
    lora=LoRAConfig(targets=("q", "k", "v", "o", "ssm_in", "ssm_out")),
    source="arXiv:2411.13676; hf",
)
