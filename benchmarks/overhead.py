"""Fig. 14 — CoLLM control-plane overhead + compute-time breakdown
(inference / fine-tuning / overhead) across workload scales.  Paper:
overhead <2% average, never >5%; fine-tuning share shrinks as load
rises (~30% at 1x, ~0 under saturation)."""
import os

from benchmarks.common import record
from repro.runtime.experiment import ExperimentConfig, run_experiment

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
SCALES = (1.0, 3.0) if QUICK else (1.0, 2.0, 3.0, 4.0)


def run() -> str:
    import time
    parts = []
    worst = 0.0
    for scale in SCALES:
        t0 = time.perf_counter()
        out = run_experiment(ExperimentConfig(
            policy="collm", n_replicas=8,
            duration=900.0 if QUICK else 1800.0, scale=scale, seed=0))
        us = (time.perf_counter() - t0) * 1e6
        worst = max(worst, out["overhead_frac"])
        record(f"fig14_overhead_x{scale:g}", us,
               f"overhead={out['overhead_frac'] * 100:.2f}% "
               f"train_share={out['train_frac'] * 100:.1f}% "
               f"infer_share={(1 - out['train_frac']) * 100:.1f}%")
        parts.append(f"x{scale:g}: ov={out['overhead_frac'] * 100:.2f}% "
                     f"train={out['train_frac'] * 100:.0f}%")
    derived = " | ".join(parts) + f" | worst_overhead={worst * 100:.2f}%"
    record("fig14_headline", 0.0, derived)
    return derived


if __name__ == "__main__":
    run()
