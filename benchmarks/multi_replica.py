"""Multi-replica serving fabric vs the single-replica runtime.

Three scenarios over one live smoke model (shared frozen base params,
per-replica adapters + KV pools):

  scaling   the same mixed trace through a 1-replica and a 2-replica
            fabric.  Gates: the 2-replica pool's AGGREGATE rate (sum of
            per-replica tokens / per-replica busy time — the pool's
            rate with each replica on its own accelerator) >= 1.5x the
            1-replica run, and every request's greedy tokens are
            bit-identical to the plain single-replica
            ``ContinuousBatcher`` serving the same prompts.
  skew      a repeated-prefix trace (two prompt families) over two
            prefix-cache replicas: seed requests register chains, the
            follow-up wave routes by prefix affinity — reported as
            ``affinity_routed`` / cached prefix tokens.
  failover  one of two replicas is killed mid-trace; its unfinished
            requests requeue on the survivor.  Gates: 100% of requests
            complete with full token budgets, and the dead replica's
            block pool is fully freed.

Results land in ``BENCH_multi_replica.json`` so the scaling trajectory
is tracked per PR.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import timed
from repro.core.interfaces import Request
from repro.data.synthetic import SyntheticDataset
from repro.runtime.fabric import FabricConfig, build_fabric
from repro.runtime.serving_loop import ContinuousBatcher, GenRequest

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))
OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "BENCH_multi_replica.json")

ARCH = "qwen1.5-0.5b"
SLOTS, PROMPT_PAD, MAX_GEN, BLOCK = 4, 16, 8, 8
STREAM = None   # filled from the model config at build time


def _trace(cfg, n, seed=0):
    """Mixed ragged trace with concrete prompts (both runtimes must see
    identical token ids for the bit-identity gate)."""
    rng = np.random.default_rng(seed)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=PROMPT_PAD, seed=seed)
    toks = data.sample_tokens(n)
    lens = rng.integers(PROMPT_PAD // 2, PROMPT_PAD + 1, size=n)
    gens = rng.integers(2, MAX_GEN + 1, size=n)
    return [(toks[i, :lens[i]].astype(np.int32), int(gens[i]))
            for i in range(n)]


def _requests(trace, arrival=0.0):
    return [Request(request_id=i, stream_id=STREAM, arrival=arrival,
                    deadline=1e9, tokens=gen, prompt=prompt.copy())
            for i, (prompt, gen) in enumerate(trace)]


def _fabric(n_replicas, **kw):
    fab, cfg = build_fabric(
        ARCH, n_replicas, n_slots=SLOTS, prompt_len=PROMPT_PAD,
        gen_tokens=MAX_GEN, cfg=FabricConfig(), **kw)
    return fab, cfg


def _row(summary, completed, n):
    c = summary["cluster"]
    return {
        "completed": completed, "requests": n,
        "generated_tokens": c["generated_tokens"],
        "prefill_tokens": c["prefill_tokens"],
        "cached_prefix_tokens": c["cached_prefix_tokens"],
        "decode_steps": c["decode_steps"],
        "tokens_per_s_aggregate": round(c["throughput_sum_tok_s"], 1),
        "tokens_per_s_shared_device": round(
            c["throughput_wall_tok_s"], 1),
        "per_replica": {rid: round(r["throughput_tok_s"], 1)
                        for rid, r in summary["replicas"].items()},
        "dispatch": summary["dispatchers"].get(STREAM, {}),
    }


@timed("multi_replica_fabric")
def run() -> str:
    global STREAM
    import jax

    from repro.configs.registry import get_config
    from repro.core.engine import make_engine

    n_req = 12 if QUICK else 24
    reps = 1 if QUICK else 2
    trace = _trace(get_config(ARCH).scaled(), n_req)

    # ---- single-replica runtime reference (tokens + throughput) ----------
    cfg = get_config(ARCH).scaled()
    STREAM = cfg.name
    engine = make_engine(cfg, lr=3e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = model.init_lora(jax.random.key(1))

    def run_single():
        reqs = [GenRequest(request_id=i, prompt=p.copy(),
                           max_new_tokens=g)
                for i, (p, g) in enumerate(trace)]
        b = ContinuousBatcher(engine, params, lora, n_slots=SLOTS,
                              max_seq=PROMPT_PAD + MAX_GEN,
                              prompt_pad=PROMPT_PAD)
        stats = b.run(reqs)
        return stats, [r.tokens for r in
                       sorted(reqs, key=lambda r: r.request_id)]

    run_single()                      # warm the jit caches
    single_stats, ref_tokens = run_single()

    # ---- scaling: 1-replica vs 2-replica fabric --------------------------
    results = {}
    fabric_tokens = None
    for n_rep in (1, 2):
        best = None
        for _ in range(reps):
            fab, _ = _fabric(n_rep)
            reqs = _requests(trace)
            summary = fab.run(reqs)
            completed = sum(1 for r in reqs
                            if r.completed_at is not None)
            row = _row(summary, completed, n_req)
            if best is None or row["tokens_per_s_aggregate"] \
                    > best["tokens_per_s_aggregate"]:
                best = row
            if n_rep == 2:
                fabric_tokens = [r.output_tokens for r in
                                 sorted(reqs,
                                        key=lambda r: r.request_id)]
        results[f"fabric_{n_rep}r"] = best

    assert results["fabric_2r"]["completed"] == n_req, \
        "2-replica fabric failed to complete the trace"
    assert fabric_tokens == ref_tokens, \
        "fabric greedy tokens diverged from the single-replica runtime"
    scaling = (results["fabric_2r"]["tokens_per_s_aggregate"]
               / max(results["fabric_1r"]["tokens_per_s_aggregate"],
                     1e-9))
    assert scaling >= 1.5, \
        f"2-replica aggregate scaling {scaling:.2f}x < 1.5x target"

    # ---- skew: prefix-affinity routing over two cached replicas ----------
    rng = np.random.default_rng(7)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=PROMPT_PAD, seed=7)
    toks = data.sample_tokens(n_req + 2)
    fams = [toks[n_req + f, :2 * BLOCK].astype(np.int32)
            for f in range(2)]
    skew_trace = []
    for i in range(n_req):
        fam = i % 2 if i < 2 else int(rng.integers(0, 2))
        tail = toks[i, :int(rng.integers(2, 5))].astype(np.int32)
        skew_trace.append((np.concatenate([fams[fam], tail]), 3 if i < 2
                           else int(rng.integers(MAX_GEN // 2,
                                                 MAX_GEN + 1))))
    fab, _ = _fabric(2, paged=True, block_size=BLOCK, prefix_cache=True)
    reqs = _requests(skew_trace)
    for r in reqs[2:]:
        r.arrival = 1.5        # seeds register chains first
    summary = fab.run(reqs)
    skew_row = _row(summary, sum(1 for r in reqs
                                 if r.completed_at is not None), n_req)
    assert skew_row["completed"] == n_req, \
        "skew trace failed to complete"

    # ---- failover: kill one of two replicas mid-trace --------------------
    fab, _ = _fabric(2, paged=True, block_size=BLOCK)
    reqs = _requests(trace)
    summary = fab.run(reqs, failures=[(0.4, "r1")])
    completed = sum(1 for r in reqs if r.completed_at is not None)
    assert completed == n_req, \
        f"failover lost requests: {completed}/{n_req} completed"
    assert all(len(r.output_tokens) == min(r.tokens, MAX_GEN)
               for r in reqs), "failover broke token accounting"
    assert [r.output_tokens for r in
            sorted(reqs, key=lambda r: r.request_id)] == ref_tokens, \
        "failover diverged from the single-replica greedy tokens"
    failover_row = _row(summary, completed, n_req)
    failover_row["survivors"] = sorted(fab.replicas)

    out = {
        "trace": {"n_requests": n_req, "slots": SLOTS,
                  "prompt_pad": PROMPT_PAD, "max_gen": MAX_GEN,
                  "arch": ARCH},
        "single_runtime": {
            "tokens_per_s": round(single_stats.throughput(), 1),
            "generated_tokens": single_stats.generated_tokens,
            "decode_steps": single_stats.decode_steps,
        },
        "scaling": {**results, "aggregate_ratio_2r_vs_1r":
                    round(scaling, 3),
                    "greedy_tokens_identical": True},
        "skew": skew_row,
        "failover": failover_row,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    return (f"scaling={scaling:.2f}x_aggregate "
            f"2r={results['fabric_2r']['tokens_per_s_aggregate']}tok_s "
            f"1r={results['fabric_1r']['tokens_per_s_aggregate']}tok_s "
            f"identical_tokens=yes "
            f"failover={completed}/{n_req} "
            f"affinity_routed={skew_row['dispatch'].get('affinity_routed', 0)}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short trace for CI (same as BENCH_QUICK=1)")
    if ap.parse_args().smoke:
        QUICK = True
    run()
