"""Batched segmented multi-LoRA decode vs unbatch-per-adapter serving.

The multi-tenant trace (several tenants, each with fewer requests than
the batcher has slots) is served two ways:

  batched    ONE ContinuousBatcher + AdapterRegistry: tenants share
             decode waves through the segmented LoRA paths, so slots
             stay full across tenant boundaries;
  unbatched  one single-adapter run PER tenant (the pre-registry
             deployment: swap the adapter in, drain that tenant, swap
             the next in) — every run pays its own under-full waves
             and drain tail.

Greedy tokens are asserted bit-identical between the two modes and the
batched/unbatched tokens-per-second ratio is hard-gated > 1.0 (the
whole point of batching tenants: same compute envelope, fewer decode
waves).  Also reports the registry's residency hit rate.  Written to
``BENCH_multi_lora.json`` so the perf trajectory is tracked per PR.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import timed
from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset
from repro.runtime.fabric import make_tenant_adapters
from repro.runtime.serving_loop import (
    AdapterRegistry, ContinuousBatcher, GenRequest,
)

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))
OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "BENCH_multi_lora.json")


@timed("multi_lora_batched_vs_unbatched")
def run() -> str:
    import jax
    n_tenants = 3 if QUICK else 4
    per_tenant = 2 if QUICK else 3
    reps = 2 if QUICK else 3
    slots, prompt_len, gen = 4, 16, 12
    max_seq = prompt_len + gen
    cfg = get_config("qwen1.5-0.5b").scaled()
    engine = make_engine(cfg, lr=1e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    tenants = make_tenant_adapters(model, n_tenants, seed=1)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=prompt_len, seed=0)
    prompts = data.sample_tokens(n_tenants * per_tenant)[:, :prompt_len]

    def trace():
        # round-robin tenant assignment: adjacent requests belong to
        # different tenants, the shape adapter-unaware serving cannot
        # batch
        return [GenRequest(request_id=i, prompt=prompts[i],
                           max_new_tokens=gen,
                           adapter_id=f"tenant{i % n_tenants}")
                for i in range(n_tenants * per_tenant)]

    def run_batched():
        reg = AdapterRegistry(model, capacity=n_tenants)
        for t, tree in enumerate(tenants):
            reg.register(f"tenant{t}", tree)
        b = ContinuousBatcher(engine, params, tenants[0], n_slots=slots,
                              max_seq=max_seq, prompt_pad=prompt_len,
                              adapters=reg)
        reqs = trace()
        t0 = time.perf_counter()
        stats = b.run(reqs)
        dt = time.perf_counter() - t0
        return reqs, stats, dt, reg

    def run_unbatched():
        # single-adapter runs take untagged requests: the tenant is
        # implied by which tree is installed as the batcher's ``lora``
        reqs = [GenRequest(request_id=r.request_id, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens)
                for r in trace()]
        t0 = time.perf_counter()
        steps = 0
        for t in range(n_tenants):
            b = ContinuousBatcher(engine, params, tenants[t],
                                  n_slots=slots, max_seq=max_seq,
                                  prompt_pad=prompt_len)
            mine = [r for r in reqs if r.request_id % n_tenants == t]
            steps += b.run(mine).decode_steps
        dt = time.perf_counter() - t0
        return reqs, steps, dt

    run_batched()            # warm the jit caches (shared programs)
    run_unbatched()
    best = {}
    tokens = {}
    for rep in range(reps):
        b_reqs, b_stats, b_dt, reg = run_batched()
        u_reqs, u_steps, u_dt = run_unbatched()
        n_tok = b_stats.generated_tokens
        cur = {
            "batched": {
                "tokens_per_s": round(n_tok / b_dt, 1),
                "decode_steps": b_stats.decode_steps,
                "adapter_hits": reg.hits,
                "adapter_loads": reg.loads,
                "residency_hit_rate": round(
                    reg.hits / max(reg.hits + reg.loads, 1), 3),
            },
            "unbatched": {
                "tokens_per_s": round(n_tok / u_dt, 1),
                "decode_steps": u_steps,
            },
        }
        if not best or cur["batched"]["tokens_per_s"] \
                > best["batched"]["tokens_per_s"]:
            best = cur
        key = lambda rs: [r.tokens for r in
                          sorted(rs, key=lambda r: r.request_id)]
        tokens["batched"], tokens["unbatched"] = key(b_reqs), key(u_reqs)
    assert tokens["batched"] == tokens["unbatched"], \
        "batched segmented decode diverged from per-adapter serving"
    ratio = (best["batched"]["tokens_per_s"]
             / max(best["unbatched"]["tokens_per_s"], 1e-9))
    assert ratio > 1.0, \
        f"tenant batching ratio {ratio:.2f}x <= 1.0 (no win over " \
        "unbatch-per-adapter serving)"
    assert best["batched"]["decode_steps"] \
        < best["unbatched"]["decode_steps"], \
        "tenant batching did not reduce decode waves"
    out = {
        "trace": {"n_tenants": n_tenants, "per_tenant": per_tenant,
                  "slots": slots, "prompt_len": prompt_len, "gen": gen},
        **best,
        "tokens_per_s_ratio": round(ratio, 3),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    return (f"batched={best['batched']['tokens_per_s']:.1f}tok_s "
            f"unbatched={best['unbatched']['tokens_per_s']:.1f}tok_s "
            f"ratio={ratio:.2f}x "
            f"steps={best['batched']['decode_steps']}"
            f"/{best['unbatched']['decode_steps']} "
            f"hit_rate={best['batched']['residency_hit_rate']}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short trace for CI (same as BENCH_QUICK=1)")
    if ap.parse_args().smoke:
        QUICK = True
    run()
