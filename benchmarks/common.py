"""Shared benchmark utilities: timing + the `name,us_per_call,derived`
CSV contract."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def record(name: str, us_per_call: float, derived: str) -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timed(name: str):
    """Decorator: runs the benchmark, records wall time + derived str."""
    def deco(fn: Callable[[], str]):
        def run():
            t0 = time.perf_counter()
            derived = fn()
            us = (time.perf_counter() - t0) * 1e6
            record(name, us, derived)
            return derived
        run.__name__ = name
        return run
    return deco
