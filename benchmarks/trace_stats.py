"""Fig. 1 — arrival-rate dynamics of the (synthesized) Azure-like
traces: trough <0.7% of peak, surges ~440%, high sub-second CV."""
from benchmarks.common import timed
from repro.data.traces import code_trace, conv_trace, merged_trace, stats


@timed("fig1_trace_stats")
def run() -> str:
    parts = []
    for name, trace in [("conv", conv_trace(3600, seed=2)),
                        ("code", code_trace(3600, seed=1)),
                        ("merged", merged_trace(3600, seed=0))]:
        s = stats(trace, bucket=30.0)
        parts.append(
            f"{name}: n={s['requests']} peak={s['peak_rate']:.1f}/s "
            f"trough/peak={s['trough_over_peak']:.4f} "
            f"surge/median={s['surge_over_median']:.1f}x "
            f"cv={s['per_second_cv']:.2f}")
    return " | ".join(parts)


if __name__ == "__main__":
    run()
