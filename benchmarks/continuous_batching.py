"""Continuous vs static batching on the live smoke model: identical
ragged request sets (varying prompt + generation lengths) through the
slot-based ``ContinuousBatcher`` and the lock-step ``static_batch_serve``
baseline.  The static loop pays max-of-batch decode steps per batch
(short requests ride as dead slots); continuous batching evicts and
admits mid-flight, so the same tokens take fewer, fuller steps —
token throughput and goodput-per-step are the paper-level win.
"""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import timed
from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset
from repro.runtime.serving_loop import (
    ContinuousBatcher, GenRequest, static_batch_serve,
)

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))


def _requests(cfg, n, prompt_pad, max_gen, seed=0):
    rng = np.random.default_rng(seed)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=prompt_pad, seed=seed)
    toks = data.sample_tokens(n)
    lens = rng.integers(prompt_pad // 2, prompt_pad + 1, size=n)
    gens = rng.integers(2, max_gen + 1, size=n)
    return [GenRequest(request_id=i,
                       prompt=toks[i, :lens[i]].astype(np.int32),
                       max_new_tokens=int(gens[i]))
            for i in range(n)]


@timed("continuous_vs_static_batching")
def run() -> str:
    import jax
    n_req = 8 if QUICK else 24
    slots = 4
    prompt_pad, max_gen = 16, 12
    cfg = get_config("qwen1.5-0.5b").scaled()
    engine = make_engine(cfg, lr=1e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = model.init_lora(jax.random.key(1))
    max_seq = prompt_pad + max_gen

    def measure(mode):
        reqs = _requests(cfg, n_req, prompt_pad, max_gen)
        if mode == "continuous":
            b = ContinuousBatcher(engine, params, lora, n_slots=slots,
                                  max_seq=max_seq, prompt_pad=prompt_pad)
            return b.run(reqs)
        return static_batch_serve(engine, params, lora, reqs,
                                  batch_size=slots, prompt_pad=prompt_pad,
                                  max_seq=max_seq)

    for mode in ("continuous", "static"):   # warm the jit caches
        measure(mode)
    stat = measure("static")
    cont = measure("continuous")
    # same requests, same greedy tokens either way (equivalence-tested);
    # continuous wins by finishing them in fewer, fuller decode steps
    speedup = stat.wall_time / max(cont.wall_time, 1e-9)
    return (f"tokens={cont.generated_tokens} "
            f"continuous={cont.decode_steps}steps/"
            f"{cont.throughput():.1f}tok_s "
            f"static={stat.decode_steps}steps/"
            f"{stat.throughput():.1f}tok_s "
            f"speedup={speedup:.2f}x")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short trace for CI (same as BENCH_QUICK=1)")
    if ap.parse_args().smoke:
        QUICK = True
    run()
