"""Paged vs contiguous KV cache on the live smoke model: the same
mixed short/long request trace through the slot-based
``ContinuousBatcher`` in both cache layouts.

The contiguous runtime allocates ``n_slots * max_seq`` worst-case rows
up front; the paged runtime serves the identical trace (identical
greedy tokens — asserted) out of a block pool 3/4 that size, because
short requests only ever hold the blocks their tokens need and decode
only streams the bucketed live block range instead of the padded pool.
Reported: allocated cache bytes, peak blocks in use, tokens/s — written
to ``BENCH_paged_kv.json`` so the perf trajectory is tracked per PR.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import timed
from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset
from repro.runtime.serving_loop import ContinuousBatcher, GenRequest

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))
OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "BENCH_paged_kv.json")


def _mixed_requests(cfg, n, prompt_pad, max_gen, seed=0):
    """Production-shaped mix: mostly short chat-style requests with an
    occasional long-context one — the regime where worst-case slot
    sizing wastes the most memory (every short slot pays the long
    request's budget) and padded decode streams the most dead rows."""
    rng = np.random.default_rng(seed)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=prompt_pad, seed=seed)
    toks = data.sample_tokens(n)
    reqs = []
    for i in range(n):
        if rng.random() < 0.8:
            plen = int(rng.integers(4, prompt_pad // 4 + 1))
            gen = int(rng.integers(2, max_gen // 8 + 1))
        else:
            plen = int(rng.integers(prompt_pad // 2, prompt_pad + 1))
            gen = int(rng.integers(max_gen // 2, max_gen + 1))
        reqs.append(GenRequest(request_id=i,
                               prompt=toks[i, :plen].astype(np.int32),
                               max_new_tokens=gen))
    return reqs


@timed("paged_vs_contiguous_kv")
def run() -> str:
    import jax
    n_req = 10 if QUICK else 24
    reps = 3
    slots, prompt_pad, max_gen, block_size = 4, 32, 64, 8
    max_seq = prompt_pad + max_gen
    cfg = get_config("qwen1.5-0.5b").scaled()
    engine = make_engine(cfg, lr=1e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = model.init_lora(jax.random.key(1))
    # paged pool at 3/4 of the contiguous worst case (+ scratch block
    # 0): enough headroom that worst-case admission reservations rarely
    # stall the queue, while still the memory paging buys back
    n_blocks = 1 + (3 * slots * max_seq) // (4 * block_size)

    def build(paged: bool) -> ContinuousBatcher:
        kw = dict(n_slots=slots, max_seq=max_seq, prompt_pad=prompt_pad)
        if paged:
            kw.update(paged=True, block_size=block_size,
                      n_blocks=n_blocks)
        return ContinuousBatcher(engine, params, lora, **kw)

    for mode in ("contiguous", "paged"):    # warm the jit caches
        build(mode == "paged").run(
            _mixed_requests(cfg, n_req, prompt_pad, max_gen))
    # interleaved best-of-N: background load drifts over seconds, so
    # alternating the two runtimes and keeping each one's best run
    # compares like with like
    results, tokens = {}, {}
    for rep in range(reps):
        for mode in ("contiguous", "paged"):
            reqs = _mixed_requests(cfg, n_req, prompt_pad, max_gen)
            b = build(mode == "paged")
            stats = b.run(reqs)
            cur = {
                "tokens_per_s": round(stats.throughput(), 1),
                "decode_steps": stats.decode_steps,
                "generated_tokens": stats.generated_tokens,
                "cache_bytes": b.cache_bytes(),
            }
            if mode == "paged":
                cur["pool_blocks"] = b.allocator.capacity
                cur["peak_used_blocks"] = b.allocator.peak_used
                cur["peak_used_bytes"] = (
                    b.allocator.peak_used * b.cache_bytes()
                    // max(b.n_blocks, 1))
                # fraction of the allocated pool the trace ever touched
                # — the headroom an oversubscribed pool could reclaim
                cur["pool_utilization"] = round(
                    b.allocator.peak_used
                    / max(b.allocator.capacity, 1), 3)
            if mode not in results or cur["tokens_per_s"] \
                    > results[mode]["tokens_per_s"]:
                results[mode] = cur
            tokens[mode] = [r.tokens for r in
                            sorted(reqs, key=lambda r: r.request_id)]
    assert tokens["paged"] == tokens["contiguous"], \
        "paged runtime diverged from contiguous greedy tokens"
    bytes_ratio = (results["contiguous"]["cache_bytes"]
                   / results["paged"]["cache_bytes"])
    speedup = (results["paged"]["tokens_per_s"]
               / max(results["contiguous"]["tokens_per_s"], 1e-9))
    out = {
        "trace": {"n_requests": n_req, "slots": slots,
                  "prompt_pad": prompt_pad, "max_gen": max_gen,
                  "max_seq": max_seq, "block_size": block_size},
        "contiguous": results["contiguous"],
        "paged": results["paged"],
        "cache_bytes_ratio": round(bytes_ratio, 3),
        "tokens_per_s_ratio": round(speedup, 3),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    return (f"cache={bytes_ratio:.2f}x_smaller "
            f"paged={results['paged']['tokens_per_s']:.1f}tok_s "
            f"contig={results['contiguous']['tokens_per_s']:.1f}tok_s "
            f"speedup={speedup:.2f}x "
            f"peak_blocks={results['paged']['peak_used_blocks']}"
            f"/{results['paged']['pool_blocks']} "
            f"util={results['paged']['pool_utilization']:.0%}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short trace for CI (same as BENCH_QUICK=1)")
    if ap.parse_args().smoke:
        QUICK = True
    run()
