"""Fig. 3 + Fig. 12a — response-quality gains from model sharing,
measured on REAL JAX LoRA training (reduced llama3-family model):

  Model Sharing   serve with the live adapter while fine-tuning runs
                  (CoLLM: updates visible immediately)
  Separate        fine-tune offline; serving uses the stale adapter
                  until training finishes + redeploy
  Inference Only  static model

Quality = 1 / CE-loss on held-out same-domain requests (paper §8.1).
Derived: mean quality per mode + the fraction of responses above
quality 1.0 (the paper's CDF crossing).
"""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timed
from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset


def _quality_trajectories(steps: int = 120, serve_every: int = 4,
                          redeploy_frac: float = 1.0, seed: int = 0):
    cfg = get_config("llama3-8b").scaled()
    engine = make_engine(cfg, lr=5e-3)
    model = engine.model
    params = model.init(jax.random.key(seed))
    lora0 = model.init_lora(jax.random.key(seed + 1))
    opt = engine.optimizer.init(lora0)
    train_data = SyntheticDataset("code_alpaca", vocab_size=cfg.vocab_size,
                                  seq_len=48, seed=seed)
    held = [
        {k: jnp.asarray(v) for k, v in train_data.batch(4).items()}
        for _ in range(8)]

    # NOTE: no donation — lora0 and intermediate adapters are re-served
    # later by the Separate/Inference-Only modes
    jit_train = jax.jit(engine.train_step)
    jit_eval = jax.jit(lambda p, l, b: model.forward_loss(p, l, b)[0])

    def quality(lora, i):
        return 1.0 / max(float(jit_eval(params, lora, held[i % 8])), 1e-6)

    lora, shared_q, adapters = lora0, [], [lora0]
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in train_data.batch(8).items()}
        lora, opt, _ = jit_train(params, lora, opt, batch)
        adapters.append(lora)
        if s % serve_every == 0:
            shared_q.append(quality(lora, s))   # live adapter (sharing)
    final = adapters[-1]
    sep_q, inf_q = [], []
    redeploy_at = int(steps * redeploy_frac)
    for s in range(0, steps, serve_every):
        # Separate: stale until training completes, then redeployed
        sep_q.append(quality(lora0 if s < redeploy_at else final, s))
        inf_q.append(quality(lora0, s))
    return np.array(shared_q), np.array(sep_q), np.array(inf_q)


@timed("fig3_12a_quality_model_sharing")
def run() -> str:
    shared, separate, inf_only = _quality_trajectories()
    thr = float(np.median(inf_only) * 1.05)   # "quality 1.0" analogue
    f = lambda a: float(np.mean(a > thr))
    return (f"mean_quality shared={shared.mean():.3f} "
            f"separate={separate.mean():.3f} static={inf_only.mean():.3f}"
            f" | frac>thr shared={f(shared):.2f} separate={f(separate):.2f}"
            f" static={f(inf_only):.2f}"
            f" | final shared={shared[-1]:.3f} static={inf_only[-1]:.3f}")


if __name__ == "__main__":
    run()
