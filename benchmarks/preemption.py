"""Oversubscribed KV pool vs preemption-free backpressure on the live
smoke model: the same heavy-tail request trace (80% short / 20% long
``max_gen``) through the paged ``ContinuousBatcher`` with the pool
sized BELOW the trace's worst-case block demand.

Preemption-free admission reserves every request's worst case up
front, so the undersized pool backpressures the queue and decode waves
run half-empty.  Oversubscribed admission reserves only near-term need
and preempts on exhaustion (host swap or drop + re-prefill, EMA cost
model), so the same pool keeps every slot decoding.  Reported per
mode: completion, goodput two ways — ``tokens_per_step`` (generated
tokens per decode wave, the deterministic packing measure the gate
uses) and wall tokens/s — plus preemption/swap/re-prefill counts and
peak pool utilization, written to ``BENCH_preemption.json``.

Hard gates (the PR's acceptance criteria): oversubscribed mode
completes 100% of the trace, its greedy tokens are bit-identical to a
never-preempted big-pool reference, and its tokens-per-decode-step
goodput is >= 1.3x the preemption-free baseline on the same pool.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import timed
from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset
from repro.runtime.serving_loop import ContinuousBatcher, GenRequest

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))
OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "BENCH_preemption.json")


def _heavy_tail_requests(cfg, n, prompt_pad, max_gen, seed=0):
    """80/20 short/long decode lengths: the regime where worst-case
    reservations strand the most pool capacity — most requests finish
    in a few blocks while every admission pays for the tail."""
    rng = np.random.default_rng(seed)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=prompt_pad, seed=seed)
    toks = data.sample_tokens(n)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(prompt_pad // 2, prompt_pad + 1))
        if rng.random() < 0.8:
            gen = int(rng.integers(2, max_gen // 8 + 1))
        else:
            gen = int(rng.integers(max_gen // 2, max_gen + 1))
        reqs.append(GenRequest(request_id=i,
                               prompt=toks[i, :plen].astype(np.int32),
                               max_new_tokens=gen))
    return reqs


@timed("oversubscribed_preemption")
def run() -> str:
    import jax
    n_req = 10 if QUICK else 24
    slots, prompt_pad, max_gen, block_size = 4, 16, 48, 8
    max_seq = prompt_pad + max_gen
    cfg = get_config("qwen1.5-0.5b").scaled()
    engine = make_engine(cfg, lr=1e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = model.init_lora(jax.random.key(1))
    # worst case = every slot filled with a full-length request; size
    # the shared pool well below it so worst-case reservations cannot
    # all coexist (preemption-free mode MUST backpressure here)
    worst_demand = slots * (max_seq // block_size)
    n_blocks = 1 + worst_demand // 3
    trace_args = (cfg, n_req, prompt_pad, max_gen)

    def serve(**kw):
        reqs = _heavy_tail_requests(*trace_args)
        b = ContinuousBatcher(engine, params, lora, n_slots=slots,
                              max_seq=max_seq, prompt_pad=prompt_pad,
                              paged=True, block_size=block_size, **kw)
        stats = b.run(reqs)
        toks = [list(r.tokens) for r in
                sorted(reqs, key=lambda r: r.request_id)]
        done = sum(1 for r in reqs if r.finished_at is not None)
        return {
            "completed": done,
            "completion": round(done / n_req, 3),
            "generated_tokens": stats.generated_tokens,
            "decode_steps": stats.decode_steps,
            "tokens_per_step": round(stats.generated_tokens
                                     / max(stats.decode_steps, 1), 3),
            "tokens_per_s": round(stats.throughput(), 1),
            "preemptions": stats.preemptions,
            "swap_out_blocks": stats.swap_out_blocks,
            "swap_in_blocks": stats.swap_in_blocks,
            "reprefill_tokens": stats.reprefill_tokens,
            "pool_blocks": b.allocator.capacity,
            "peak_used_blocks": b.allocator.peak_used,
            "pool_utilization": round(b.allocator.peak_used
                                      / max(b.allocator.capacity, 1),
                                      3),
        }, toks

    # never-preempted reference on a worst-case pool: the greedy token
    # oracle every constrained run must match bit-for-bit
    ref, ref_toks = serve(n_blocks=1 + worst_demand)
    base, base_toks = serve(n_blocks=n_blocks)
    over, over_toks = serve(n_blocks=n_blocks, oversubscribe=1.0)

    assert over["completed"] == n_req, \
        f"oversubscribed run dropped requests: {over['completed']}/{n_req}"
    assert over_toks == ref_toks, \
        "oversubscribed greedy tokens diverged from the never-" \
        "preempted reference"
    assert base_toks == ref_toks, \
        "preemption-free baseline diverged from the reference"
    goodput_ratio = over["tokens_per_step"] \
        / max(base["tokens_per_step"], 1e-9)
    assert goodput_ratio >= 1.3, \
        f"oversubscription goodput {goodput_ratio:.2f}x < 1.3x over " \
        "preemption-free backpressure"

    out = {
        "trace": {"n_requests": n_req, "slots": slots,
                  "prompt_pad": prompt_pad, "max_gen": max_gen,
                  "block_size": block_size,
                  "worst_case_blocks": worst_demand,
                  "pool_blocks": n_blocks - 1},
        "reference": ref,
        "preemption_free": base,
        "oversubscribed": over,
        "goodput_ratio": round(goodput_ratio, 3),
        "bit_identical": True,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    return (f"goodput={goodput_ratio:.2f}x "
            f"over={over['tokens_per_step']:.2f}tok_step "
            f"base={base['tokens_per_step']:.2f}tok_step "
            f"preempt={over['preemptions']} "
            f"swap={over['swap_out_blocks']}blk "
            f"reprefill={over['reprefill_tokens']}tok "
            f"util={over['pool_utilization']:.0%}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short trace for CI (same as BENCH_QUICK=1)")
    if ap.parse_args().smoke:
        QUICK = True
    run()
