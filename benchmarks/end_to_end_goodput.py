"""Fig. 10 — goodput + Q-goodput vs baselines at 1-4x workload scales
on the merged Azure-like trace (16 replicas in the paper; configurable
for bench-runtime reasons)."""
import os

from benchmarks.common import record, timed
from repro.runtime.experiment import ExperimentConfig, run_experiment

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"
SCALES = (1.0, 3.0) if QUICK else (1.0, 2.0, 3.0, 4.0)
POLICIES = ("collm", "dlora", "shepherd", "peft")
DURATION = 900.0 if QUICK else 1800.0
N_REPLICAS = 8


def run() -> str:
    import time
    results = {}
    for policy in POLICIES:
        for scale in SCALES:
            t0 = time.perf_counter()
            out = run_experiment(ExperimentConfig(
                policy=policy, n_replicas=N_REPLICAS, duration=DURATION,
                scale=scale, seed=0))
            us = (time.perf_counter() - t0) * 1e6
            results[(policy, scale)] = out
            record(f"fig10_{policy}_x{scale:g}", us,
                   f"goodput={out['goodput_tok_s']:.0f}tok/s "
                   f"qgoodput={out['q_goodput']:.0f} "
                   f"slo={out['slo_rate']:.3f} util={out['mean_util']:.3f}")
    # headline ratios at the largest scale
    top = max(SCALES)
    c = results[("collm", top)]
    lines = []
    for p in POLICIES[1:]:
        b = results[(p, top)]
        lines.append(f"vs {p}@x{top:g}: goodput "
                     f"{c['goodput_tok_s'] / max(b['goodput_tok_s'], 1):.2f}x"
                     f" qgoodput {c['q_goodput'] / max(b['q_goodput'], 1):.2f}x")
    derived = " | ".join(lines)
    record("fig10_headline", 0.0, derived)
    return derived


if __name__ == "__main__":
    run()
