"""Roofline table (deliverable g): reads the dry-run JSON records from
results/dryrun/ and emits the per-(arch × shape × mesh) three-term
table — compute / memory / collective seconds, dominant bottleneck,
MODEL_FLOPS ratio — consumed verbatim by EXPERIMENTS.md §Roofline."""
import glob
import json
import os

from benchmarks.common import record

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "dryrun")


def load_records(mesh: str = "16x16", tag: str = ""):
    out = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh:
            continue
        if (rec.get("tag") or "") != tag:
            continue
        out.append(rec)
    return out


def fmt_table(records) -> str:
    header = (f"{'arch':22s} {'shape':12s} {'comp_ms':>9s} {'mem_ms':>10s} "
              f"{'coll_ms':>9s} {'bound':>10s} {'GiB/dev':>8s} "
              f"{'useful':>7s}")
    lines = [header]
    for r in sorted(records, key=lambda x: (x["arch"], x["shape"])):
        rl = r["roofline"]
        peak = r["memory"]["peak_device_bytes"] / 2 ** 30
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} "
            f"{rl['compute_s'] * 1e3:9.2f} {rl['memory_s'] * 1e3:10.2f} "
            f"{rl['collective_s'] * 1e3:9.2f} {rl['dominant']:>10s} "
            f"{peak:8.2f} {r.get('useful_flops_ratio', 0):7.3f}")
    return "\n".join(lines)


def run() -> str:
    import time
    t0 = time.perf_counter()
    recs = load_records("16x16")
    if not recs:
        derived = "no dry-run records yet (run repro.launch.dryrun --all)"
        record("roofline_table", 0.0, derived)
        return derived
    print(fmt_table(recs))
    bounds = {}
    for r in recs:
        bounds[r["roofline"]["dominant"]] = \
            bounds.get(r["roofline"]["dominant"], 0) + 1
    mp = load_records("2x16x16")
    derived = (f"cells={len(recs)} bounds={bounds} "
               f"multi_pod_cells={len(mp)} "
               f"max_mem_gib={max(r['memory']['peak_device_bytes'] for r in recs) / 2**30:.1f}")
    record("roofline_table", (time.perf_counter() - t0) * 1e6, derived)
    return derived


if __name__ == "__main__":
    run()
