"""Fig. 2 — separate-loading vs model-sharing cost, analytically from
the configs: replica load time (HBM fill over PCIe/DCN) and run-time
memory for concurrent fine-tuning + inference.

Separate loading deploys a second full model instance for training;
CoLLM's sharing loads the base once and adds only LoRA params, grads,
and optimizer state (plus shared activations).
"""
from benchmarks.common import timed
from repro.configs.registry import get_config

PCIE_BW = 16e9   # bytes/s host->device staging


def _bytes(cfg, dtype_bytes=2):
    base = cfg.param_count() * dtype_bytes
    lora = cfg.lora_param_count() * 4          # f32 adapters
    opt = cfg.lora_param_count() * 8           # adam m+v in f32
    return base, lora, opt


@timed("fig2_model_sharing_cost")
def run() -> str:
    parts = []
    for arch in ["qwen1.5-0.5b", "llama3-8b", "qwen3-14b"]:
        cfg = get_config(arch)
        base, lora, opt = _bytes(cfg)
        sep_mem = 2 * base + lora + opt        # two full instances
        shared_mem = base + lora + opt         # one shared instance
        sep_load = 2 * base / PCIE_BW
        shared_load = (base + lora) / PCIE_BW
        extra_lat = (sep_load - shared_load) / shared_load * 100
        extra_mem = (sep_mem - shared_mem) / shared_mem * 100
        parts.append(
            f"{arch}: separate +{sep_load - shared_load:.1f}s load "
            f"(+{extra_lat:.0f}%) +{(sep_mem - shared_mem) / 2**30:.1f}GiB "
            f"(+{extra_mem:.0f}%)")
    return " | ".join(parts)


if __name__ == "__main__":
    run()
