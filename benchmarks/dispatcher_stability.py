"""Fig. 5 + Fig. 13 — subflow pacing vs round-robin: windowed serving
stability and SLO compliance under the same bursty load (fine-tuning
disabled to isolate the dispatcher)."""
import os

import numpy as np

from benchmarks.common import timed
from repro.data.traces import merged_trace
from repro.runtime.experiment import ExperimentConfig, run_experiment

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"


@timed("fig5_13_dispatcher_stability")
def run() -> str:
    duration = 600.0 if QUICK else 1200.0
    outs = {}
    for policy in ("collm", "rr"):
        trace = merged_trace(duration, scale=2.0, seed=4)
        cfg = ExperimentConfig(policy=policy, n_replicas=8,
                               duration=duration, scale=2.0, seed=4,
                               enable_finetuning=False)
        out = run_experiment(cfg, trace)
        # windowed served-token throughput: stability = low CV across
        # windows relative to offered load
        w = 30.0
        nbins = int(duration / w)
        served = np.zeros(nbins)
        for r in trace:
            if r.completed_at is not None and r.slo_met:
                b = min(int(r.completed_at / w), nbins - 1)
                served[b] += r.tokens
        active = served[served > 0]
        cv = float(np.std(active) / max(np.mean(active), 1e-9))
        outs[policy] = (out["slo_rate"], cv)
    return (f"subflow: slo={outs['collm'][0]:.3f} cv={outs['collm'][1]:.2f}"
            f" | rr: slo={outs['rr'][0]:.3f} cv={outs['rr'][1]:.2f}")


if __name__ == "__main__":
    run()
