"""Benchmark entry point: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py).

Set BENCH_QUICK=1 for shortened simulator horizons.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        component_ablation, continuous_batching, coordinator_ablation,
        dispatcher_stability, end_to_end_goodput, latency_model_fit,
        model_sharing_cost, overhead, paged_kv, preemption,
        quality_sharing, roofline, trace_stats, utilization,
    )
    print("name,us_per_call,derived")
    failures = []
    for mod in (trace_stats, model_sharing_cost, latency_model_fit,
                quality_sharing, dispatcher_stability, coordinator_ablation,
                end_to_end_goodput, utilization, overhead,
                component_ablation, continuous_batching, paged_kv,
                preemption, roofline):
        try:
            mod.run()
        except Exception as e:
            failures.append((mod.__name__, repr(e)))
            traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
