"""Live co-execution over the multi-replica fabric: FL fine-tuning
co-running with serving vs the serve-only fabric.

One trace, two N=2-replica fabrics over the same smoke model:

  serve-only  the PR-4 fabric (enable_finetuning=False) — the goodput
              baseline.
  combined    enable_finetuning=True: the launcher cohorts both
              replicas into an FL session; every fabric tick advances
              each member's incremental train session ONE fused
              combined_step (shadow adapter trains while decode reads
              the published snapshot) and aggregation publishes the
              merged adapter at round boundaries.

BOTH fabrics run token-level co-scheduling: chunked prefill (prompts
prefill in fixed-token chunks riding the decode wave) under a per-tick
SLO budget derived from the decode TPOT target.  The budget is what
closes the historical 0.31x goodput gap: serving-busy ticks skip or
shrink the train microbatch (decode is first-class), and training
drains through the idle tail after the trace completes — so the
combined fabric must now retain >= GOODPUT_FLOOR of serve-only
throughput, the TRUE co-execution target, not a documented-regression
floor.

Gates: the combined run completes 100% of the trace while finishing
>= MIN_ROUNDS FL rounds, per-member train CE falls from its first to
its last fused step, per-round avg member CE falls across rounds, the
merged adapter version is coherent across the pool, and combined
goodput >= GOODPUT_FLOOR x serve-only.

Results land in ``BENCH_combined_fabric.json``.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import timed
from repro.core.interfaces import Request
from repro.data.synthetic import SyntheticDataset
from repro.runtime.fabric import FabricConfig, build_fabric

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))
OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "BENCH_combined_fabric.json")

ARCH = "qwen1.5-0.5b"
SLOTS, PROMPT_PAD, MAX_GEN = 4, 48, 8
MIN_ROUNDS = 2
# combined tok/s must stay within this fraction of serve-only: with the
# token-budget scheduler deferring train work off serving-busy ticks
# (decode first-class, training drains in the idle tail), co-execution
# is no longer allowed to tax goodput 3x — this is the paper's target,
# not a documented-regression floor
GOODPUT_FLOOR = 0.8
# token-level co-scheduling knobs, identical on BOTH fabrics so the
# ratio isolates the cost of co-running training
PREFILL_CHUNK = 16
TPOT_TARGET = 0.004         # s/token decode SLO -> per-tick budget
STREAM = None


def _trace(cfg, n, seed=0):
    """Heavy-tailed prompt lengths: ~80% short conversational prompts,
    ~20% long-context stragglers near PROMPT_PAD.  The long tail is
    what chunked prefill exists for — a monolithic 48-token prefill
    would stall every decoding slot for the whole prompt."""
    rng = np.random.default_rng(seed)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=PROMPT_PAD, seed=seed)
    toks = data.sample_tokens(n)
    short = rng.integers(6, 17, size=n)
    long = rng.integers(36, PROMPT_PAD + 1, size=n)
    lens = np.where(rng.random(n) < 0.8, short, long)
    gens = rng.integers(2, MAX_GEN + 1, size=n)
    return [(toks[i, :lens[i]].astype(np.int32), int(gens[i]))
            for i in range(n)]


def _requests(trace):
    return [Request(request_id=i, stream_id=STREAM, arrival=0.0,
                    deadline=1e9, tokens=gen, prompt=prompt.copy())
            for i, (prompt, gen) in enumerate(trace)]


def _serve_cfg(**kw):
    """FabricConfig with the co-scheduling knobs both fabrics share."""
    return FabricConfig(prefill_chunk=PREFILL_CHUNK,
                        tpot_target=TPOT_TARGET, **kw)


def _row(summary, reqs):
    c = summary["cluster"]
    return {
        "completed": sum(1 for r in reqs if r.completed_at is not None),
        "requests": len(reqs),
        "generated_tokens": c["generated_tokens"],
        "decode_steps": c["decode_steps"],
        "train_steps": c["train_steps"],
        "train_skipped_ticks": c["train_skipped_ticks"],
        "tokens_per_s_aggregate": round(c["throughput_sum_tok_s"], 1),
        "tokens_per_s_shared_device": round(
            c["throughput_wall_tok_s"], 1),
        "adapter_version": c["adapter_version_max"],
        "train_loss": c["train_loss"],
        "budget_utilization": c["budget_utilization"],
        "ttft": c["ttft"],
        "tpot": c["tpot"],
    }


@timed("combined_fabric")
def run() -> str:
    global STREAM
    n_req = 10 if QUICK else 20
    steps = 4 if QUICK else 8

    from repro.configs.registry import get_config
    trace = _trace(get_config(ARCH).scaled(), n_req)

    # ---- warmup: pay every compile outside both measured runs (the
    # engine jit cache is shared across fabrics of the same smoke
    # model, so whichever run went first would eat them).  The serve
    # warmup runs the FULL trace — admission-wave programs compile per
    # wave width, so a shorter trace would leave cold shapes — and the
    # combined warmup compiles the fused/plain train programs at every
    # train_tokens bucket the budget scheduler can pick (full/half).
    fab, cfg = build_fabric(ARCH, 2, n_slots=SLOTS,
                            prompt_len=PROMPT_PAD, gen_tokens=MAX_GEN,
                            cfg=_serve_cfg())
    STREAM = cfg.name
    fab.run(_requests(trace))
    fab, _ = build_fabric(
        ARCH, 2, n_slots=SLOTS, prompt_len=PROMPT_PAD,
        gen_tokens=MAX_GEN, train_pool=4,
        cfg=_serve_cfg(enable_finetuning=True, bootstrap_steps=2,
                       steps_per_round=2, decision_interval=0.1))
    fab.run(_requests(trace[:4]), min_rounds=1, timeout=120.0)

    # ---- serve-only baseline fabric --------------------------------------
    fab, _ = build_fabric(ARCH, 2, n_slots=SLOTS,
                          prompt_len=PROMPT_PAD, gen_tokens=MAX_GEN,
                          cfg=_serve_cfg())
    reqs = _requests(trace)
    base = _row(fab.run(reqs), reqs)
    assert base["completed"] == n_req, "serve-only baseline incomplete"

    # ---- combined: FL sessions co-running with the same trace ------------
    # the fine-tuning corpus is a FIXED pool of batches cycled
    # epoch-style (a finite PEFT finetuning set): per-round avg member
    # CE then falls monotonically — fresh random batches every step
    # would drown the few smoke-run steps in sampling noise
    fab, _ = build_fabric(
        ARCH, 2, n_slots=SLOTS, prompt_len=PROMPT_PAD,
        gen_tokens=MAX_GEN, train_pool=4,
        cfg=_serve_cfg(enable_finetuning=True, bootstrap_steps=steps,
                       steps_per_round=steps, decision_interval=0.1))
    reqs = _requests(trace)
    summary = fab.run(reqs, min_rounds=MIN_ROUNDS, timeout=300.0)
    comb = _row(summary, reqs)
    comb["fl_rounds"] = summary["fl_rounds"]
    comb["rounds"] = summary["rounds"]

    assert comb["completed"] == n_req, \
        f"combined fabric lost requests: {comb['completed']}/{n_req}"
    assert comb["fl_rounds"] >= MIN_ROUNDS, \
        f"only {comb['fl_rounds']} FL rounds completed"
    assert summary["cluster"]["adapter_version_min"] \
        == summary["cluster"]["adapter_version_max"] >= MIN_ROUNDS, \
        "merged adapter did not reach every member"
    # quality progression: avg member train CE falls across rounds
    round_losses = [r["avg_loss"] for r in summary["rounds"]]
    assert round_losses[-1] < round_losses[0], \
        f"train loss did not fall across rounds: {round_losses}"
    losses = {rid: rep.batcher.train_losses
              for rid, rep in fab.replicas.items()}
    for rid, ls in losses.items():
        assert len(ls) >= MIN_ROUNDS * steps, f"{rid}: too few steps"

    ratio = comb["tokens_per_s_aggregate"] \
        / max(base["tokens_per_s_aggregate"], 1e-9)
    assert ratio >= GOODPUT_FLOOR, \
        f"co-execution goodput hit too deep: {ratio:.2f}x of serve-only"

    out = {
        "trace": {"n_requests": n_req, "slots": SLOTS,
                  "prompt_pad": PROMPT_PAD, "max_gen": MAX_GEN,
                  "steps_per_round": steps, "arch": ARCH,
                  "prefill_chunk": PREFILL_CHUNK,
                  "tpot_target": TPOT_TARGET},
        "serve_only": base,
        "combined": comb,
        "goodput_ratio_combined_vs_serve_only": round(ratio, 3),
        "round_avg_loss": [round(l, 4) for l in round_losses],
        "train_loss_first_to_last": {
            rid: [round(ls[0], 4), round(ls[-1], 4)]
            for rid, ls in losses.items()},
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    return (f"rounds={comb['fl_rounds']} "
            f"completed={comb['completed']}/{n_req} "
            f"goodput_ratio={ratio:.2f}x "
            f"combined={comb['tokens_per_s_aggregate']}tok_s "
            f"serve_only={base['tokens_per_s_aggregate']}tok_s "
            f"round_loss={round_losses[0]:.3f}->{round_losses[-1]:.3f} "
            f"adapter_v={comb['adapter_version']}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short trace for CI (same as BENCH_QUICK=1)")
    if ap.parse_args().smoke:
        QUICK = True
    run()
