"""Fig. 12b — Inference-Training Coordinator vs fixed (B, b) configs.

A 3-replica COMBINED cohort runs FL rounds while serving a constant
request stream.  Metrics (normalized to the Coordinator run):
  Q-goodput  — quality-weighted served tokens/s
  JCT        — sim-time for the cohort mean loss to reach a target
Static configs (4,16)/(8,12)/(12,8)/(16,4) expose the skew the paper
shows; the Coordinator's interference-aware optimization wins both.
"""
import numpy as np

from benchmarks.common import record
from repro.core.coordinator import CoordinatorConfig, \
    InferenceTrainingCoordinator
from repro.core.interfaces import BatchResult, Request
from repro.runtime.replica import InterferenceSurface, LossCurve, SimReplica
from repro.runtime.simulator import Simulator

TARGET_LOSS = 1.30
HORIZON = 600.0
RATE = 30.0          # req/s offered to the cohort
SLO = 0.5


def _run(mode) -> dict:
    """mode: (B, b) fixed tuple or 'coordinator'."""
    sim = Simulator()
    results = []

    def on_result(res, sid):
        results.append(res)
        coord.observe_infer(res)   # the Coordinator's Eq. 10 samples

    replicas = {}
    for i in range(3):
        r = SimReplica(f"r{i}", "m", sim, on_result,
                       InterferenceSurface(),
                       LossCurve(init_loss=2.4, floor=1.0, rate=1 / 5000),
                       seed=i)
        replicas[f"r{i}"] = r
    coord = InferenceTrainingCoordinator(
        "abl", list(replicas), SLO,
        CoordinatorConfig(bootstrap_train_batch=mode[0],
                          bootstrap_infer_batch=mode[1])
        if mode != "coordinator" else CoordinatorConfig())

    jct = [None]

    def fl_round(now: float) -> None:
        if jct[0] is not None:
            return  # converged; cohort back to pure serving
        for rid, r in replicas.items():
            plan = coord.plan_for(rid)
            stats = r.train_round(plan.train_batch, plan.infer_batch,
                                  coord.steps_per_round, now)
            coord.observe_train(stats)
        mean_loss = float(np.mean(
            [r.loss_curve.loss() for r in replicas.values()]))
        if mean_loss <= TARGET_LOSS:
            jct[0] = now
            return
        if mode == "coordinator":
            # τ' with headroom for the surface's ~4% latency noise —
            # b* exactly on the boundary loses half its batches
            coord.replan(SLO * 0.8)
        done = max(r.training_until for r in replicas.values())
        sim.schedule(max(done, now + 1.0), fl_round)

    sim.schedule(0.0, fl_round)

    # serve a paced stream at each replica's planned inference batch
    rid_list = list(replicas)
    req_id = [0]

    def dispatch(now: float) -> None:
        for rid in rid_list:
            plan = coord.plan_for(rid)
            b = max(plan.infer_batch, 1)
            r = replicas[rid]
            if r.outstanding_batches(now) <= 1:
                reqs = [Request(req_id[0] + k, "m", now, now + SLO,
                                tokens=150) for k in range(b)]
                req_id[0] += len(reqs)
                r.submit_batch(reqs, now)
        sim.schedule(now + 0.8 * SLO, dispatch)   # ideal-mode pacing

    sim.schedule(0.0, dispatch)
    sim.run(HORIZON)
    q_tokens = sum(res.tokens * res.quality for res in results
                   if res.total_latency <= SLO + 1e-9)
    return {"q_goodput": q_tokens / HORIZON,
            "jct": jct[0] if jct[0] is not None else float("inf")}


def run() -> str:
    import time
    t0 = time.perf_counter()
    modes = [(4, 16), (8, 12), (12, 8), (16, 4), "coordinator"]
    outs = {str(m): _run(m) for m in modes}
    ref = outs["coordinator"]
    parts = []
    for m in modes:
        o = outs[str(m)]
        qg = o["q_goodput"] / max(ref["q_goodput"], 1e-9)
        if np.isfinite(o["jct"]) and np.isfinite(ref["jct"]):
            parts.append(f"{m}: qg={qg:.2f} "
                         f"jct={o['jct'] / max(ref['jct'], 1e-9):.2f}")
        else:
            parts.append(f"{m}: qg={qg:.2f} jct="
                         + ("conv" if np.isfinite(o["jct"]) else "no-conv"))
    derived = " | ".join(parts)
    record("fig12b_coordinator_ablation",
           (time.perf_counter() - t0) * 1e6, derived)
    return derived


if __name__ == "__main__":
    run()
