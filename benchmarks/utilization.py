"""Fig. 11 — cluster SM-utilization over time per policy at 3x load:
CoLLM backfills troughs with fine-tuning (>70% in dips, paper) while
baselines idle (<45%)."""
import os

import numpy as np

from benchmarks.common import timed
from repro.runtime.experiment import ExperimentConfig, run_experiment

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"


@timed("fig11_utilization")
def run() -> str:
    duration = 900.0 if QUICK else 1800.0
    outs = {}
    for policy in ("collm", "dlora", "peft"):
        out = run_experiment(ExperimentConfig(
            policy=policy, n_replicas=8, duration=duration, scale=3.0,
            seed=0))
        ts, us = out["_metrics"].utilization_timeline(bucket=60.0)
        # trough window = lowest-load fifth of the run
        k = max(len(us) // 5, 1)
        trough = float(np.mean(np.sort(us)[:k]))
        outs[policy] = (out["mean_util"], trough)
    parts = [f"{p}: mean={m:.2f} trough={t:.2f}"
             for p, (m, t) in outs.items()]
    ratio = outs["collm"][1] / max(outs["peft"][1], 1e-3)
    parts.append(f"collm/peft trough-util={ratio:.1f}x")
    return " | ".join(parts)


if __name__ == "__main__":
    run()
