"""Fig. 4 + Fig. 9 — latency-model fragility under interference: the
univariate fit's R² collapses when a co-running training batch varies
(paper: 0.994 -> 0.758), while CoLLM's bivariate model (Eq. 9-10)
restores accuracy.  Samples come from a SimReplica's ground-truth
surface with realistic noise — the control plane never sees the
coefficients, only (b, B, latency) observations.
"""
import numpy as np

from benchmarks.common import timed
from repro.core.latency_model import BivariateLatencyModel, LinearLatencyModel
from repro.runtime.replica import InterferenceSurface


@timed("fig4_latency_model_r2")
def run() -> str:
    surface = InterferenceSurface(noise_frac=0.015)
    rng = np.random.default_rng(0)

    # exclusive serving: univariate fit is excellent (paper: 0.994)
    uni_excl = LinearLatencyModel()
    for _ in range(200):
        b = int(rng.integers(1, 12))
        uni_excl.observe(b, surface.t_infer(b, 0, rng))
    uni_excl.fit()

    # co-located fine-tuning with B in {16,12,8,4}, b in {3..6} (Fig. 4b)
    uni_mix = LinearLatencyModel()
    bi_mix = BivariateLatencyModel()
    for _ in range(300):
        b = int(rng.integers(3, 7))
        B = int(rng.choice([4, 8, 12, 16]))
        lat = surface.t_infer(b, B, rng)
        uni_mix.observe(b, lat)
        bi_mix.observe(b, B, lat)
    uni_mix.fit()
    bi_mix.fit()

    # Fig. 9: prediction accuracy of the bivariate model on held-out pts
    errs = []
    for _ in range(100):
        b = int(rng.integers(2, 8))
        B = int(rng.choice([0, 4, 8, 12, 16]))
        true = surface.t_infer(b, B, rng)
        errs.append(abs(bi_mix.predict(b, B) - true) / true)
    mape = float(np.mean(errs)) * 100
    return (f"uni_exclusive_R2={uni_excl.r2:.3f} "
            f"uni_interfered_R2={uni_mix.r2:.3f} "
            f"bivariate_R2={bi_mix.r2:.3f} bivariate_MAPE={mape:.1f}%")


if __name__ == "__main__":
    run()
