"""Copy-on-write prefix sharing vs the plain paged runtime on a
repeated-prefix trace (the edge-personalization pattern: a handful of
system/few-shot prompts reused across many requests).

Both runtimes serve the identical trace out of the same paged block
pool; with ``prefix_cache=True`` each request's longest cached
block-aligned prefix is aliased at refcount+1 and only the uncached
suffix is prefilled, so prefill compute scales with *distinct* prompt
tokens and concurrent same-prefix slots share pool blocks.  Greedy
tokens are asserted identical, and the prefill-token reduction and
peak-blocks-in-use drop are hard-gated (both are deterministic counts;
tokens/s is reported best-of-N).  Written to ``BENCH_prefix_cache.json``
so the perf trajectory is tracked per PR.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import timed
from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset
from repro.runtime.serving_loop import ContinuousBatcher, GenRequest

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))
OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "BENCH_prefix_cache.json")


def _repeated_prefix_requests(cfg, n, prompt_pad, max_gen, *,
                              n_prefixes=2, prefix_len=56, seed=0):
    """A few long shared prefixes (>=50% of every prompt) + short unique
    tails.  The first ``n_prefixes`` requests finish fast, seeding the
    cache; the rest decode long enough that same-prefix slots overlap,
    so sharing shows up in peak blocks, not just prefill compute."""
    rng = np.random.default_rng(seed)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=prompt_pad, seed=seed)
    toks = data.sample_tokens(n + n_prefixes)
    prefixes = [toks[n + p, :prefix_len].astype(np.int32)
                for p in range(n_prefixes)]
    reqs = []
    for i in range(n):
        fam = i % n_prefixes if i < n_prefixes \
            else int(rng.integers(0, n_prefixes))
        tail_len = int(rng.integers(2, 7))
        prompt = np.concatenate([prefixes[fam],
                                 toks[i, :tail_len].astype(np.int32)])
        gen = 4 if i < n_prefixes else int(
            rng.integers(max_gen // 2, max_gen + 1))
        reqs.append(GenRequest(request_id=i, prompt=prompt,
                               max_new_tokens=gen))
    return reqs


@timed("prefix_cache_vs_paged")
def run() -> str:
    import jax
    n_req = 10 if QUICK else 24
    reps = 3
    slots, prompt_pad, max_gen, block_size = 4, 64, 16, 8
    max_seq = prompt_pad + max_gen
    cfg = get_config("qwen1.5-0.5b").scaled()
    engine = make_engine(cfg, lr=1e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = model.init_lora(jax.random.key(1))

    def build(shared: bool) -> ContinuousBatcher:
        return ContinuousBatcher(
            engine, params, lora, n_slots=slots, max_seq=max_seq,
            prompt_pad=prompt_pad, paged=True, block_size=block_size,
            prefix_cache=shared)

    def trace():
        return _repeated_prefix_requests(cfg, n_req, prompt_pad, max_gen)

    for mode in ("paged", "shared"):        # warm the jit caches
        build(mode == "shared").run(trace())
    # interleaved best-of-N (timing only; the counters are deterministic)
    results, tokens = {}, {}
    for rep in range(reps):
        for mode in ("paged", "shared"):
            reqs = trace()
            b = build(mode == "shared")
            stats = b.run(reqs)
            cur = {
                "tokens_per_s": round(stats.throughput(), 1),
                "prefill_tokens_computed": stats.prefill_tokens,
                "cached_prefix_tokens": stats.cached_prefix_tokens,
                "generated_tokens": stats.generated_tokens,
                "decode_steps": stats.decode_steps,
                "peak_used_blocks": b.allocator.peak_used,
                "pool_blocks": b.allocator.capacity,
            }
            if mode == "shared":
                cur["prefix_cache_hits"] = b.prefix_cache.hits
                cur["retained_blocks_end"] = b.allocator.n_retained
            if mode not in results or cur["tokens_per_s"] \
                    > results[mode]["tokens_per_s"]:
                results[mode] = cur
            tokens[mode] = [r.tokens for r in
                            sorted(reqs, key=lambda r: r.request_id)]
    assert tokens["shared"] == tokens["paged"], \
        "prefix sharing diverged from the non-shared paged greedy tokens"
    prefill_ratio = (results["paged"]["prefill_tokens_computed"]
                     / max(results["shared"]["prefill_tokens_computed"], 1))
    assert prefill_ratio >= 1.5, \
        f"prefill reduction {prefill_ratio:.2f}x < 1.5x target"
    assert results["shared"]["peak_used_blocks"] \
        < results["paged"]["peak_used_blocks"], \
        "sharing did not reduce peak blocks in use"
    speedup = (results["shared"]["tokens_per_s"]
               / max(results["paged"]["tokens_per_s"], 1e-9))
    out = {
        "trace": {"n_requests": n_req, "slots": slots,
                  "prompt_pad": prompt_pad, "max_gen": max_gen,
                  "block_size": block_size,
                  "shared_prefix_len": 56, "n_prefixes": 2},
        "paged": results["paged"],
        "shared": results["shared"],
        "prefill_tokens_ratio": round(prefill_ratio, 3),
        "peak_blocks_ratio": round(
            results["paged"]["peak_used_blocks"]
            / max(results["shared"]["peak_used_blocks"], 1), 3),
        "tokens_per_s_ratio": round(speedup, 3),
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    return (f"prefill={prefill_ratio:.2f}x_fewer "
            f"peak_blocks={results['shared']['peak_used_blocks']}"
            f"/{results['paged']['peak_used_blocks']} "
            f"shared={results['shared']['tokens_per_s']:.1f}tok_s "
            f"paged={results['paged']['tokens_per_s']:.1f}tok_s "
            f"speedup={speedup:.2f}x")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short trace for CI (same as BENCH_QUICK=1)")
    if ap.parse_args().smoke:
        QUICK = True
    run()
