"""Chaos harness for the live serving fabric: seeded fault injection
against a 2-replica pool, gated on zero request loss and bit-identical
greedy output.

Two scenarios over one live smoke model:

  serving   the same two-wave trace through (a) a clean 2-replica
            fabric (reference) and (b) the same fabric under seeded
            chaos — one STALL turning r0 into a gross straggler from
            t=0.05 plus one CRASH killing r1 mid-trace.  Gates: the
            straggler is quarantined (drain + requeue + subflow
            suspension, replica stays a pool member), the crash is
            failed over, EVERY retry-eligible request completes
            (completion_rate >= 1.0), and each request's greedy tokens
            are bit-identical to the no-chaos reference — failover
            regeneration and quarantine requeues must not perturb
            decoding.  Goodput retention (chaos aggregate tok/s over
            clean aggregate tok/s) is recorded for trajectory
            tracking, not gated.
  nan_round a combined (serve + FL fine-tune) fabric with one
            nan_grads event poisoning a member's shadow tree
            mid-round.  Gates: the publish gates block the poisoned
            shadow (``nan_publishes_blocked >= 1``), at least one FL
            round still completes, and every replica's SERVED adapter
            tree stays finite.

Results land in ``BENCH_chaos.json`` so the fault-tolerance trajectory
is tracked per PR.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import timed
from repro.core.interfaces import Request
from repro.data.synthetic import SyntheticDataset
from repro.runtime.fabric import FabricConfig, build_fabric
from repro.runtime.fault import FaultEvent, FaultInjector

QUICK = bool(int(os.environ.get("BENCH_QUICK", "0")))
OUT_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "..", "BENCH_chaos.json")

ARCH = "qwen1.5-0.5b"
SLOTS, PROMPT_PAD, MAX_GEN, BLOCK = 4, 16, 8, 8
STREAM = None   # filled from the model config at build time


def _trace(cfg, n, seed=11):
    rng = np.random.default_rng(seed)
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=PROMPT_PAD, seed=seed)
    toks = data.sample_tokens(n)
    lens = rng.integers(PROMPT_PAD // 2, PROMPT_PAD + 1, size=n)
    gens = rng.integers(3, MAX_GEN + 1, size=n)
    return [(toks[i, :lens[i]].astype(np.int32), int(gens[i]))
            for i in range(n)]


def _requests(trace, spacing=0.0):
    """``spacing > 0`` streams arrivals (one every ``spacing`` seconds)
    so the trace is still live when the scheduled faults fire — a batch
    trace on a warm-jit pool drains in tens of milliseconds, before any
    fault can matter."""
    return [Request(request_id=i, stream_id=STREAM, arrival=i * spacing,
                    deadline=1e9, tokens=gen, prompt=prompt.copy())
            for i, (prompt, gen) in enumerate(trace)]


def _chaos_cfg(**kw):
    # jit caches are warm by the time the chaos run starts (the clean
    # reference runs first in the same process), so the straggler watch
    # needs only a short compile grace
    return FabricConfig(
        straggler_threshold=2.0, straggler_window=8,
        straggler_min_samples=4, straggler_warmup=2,
        quarantine_cooldown=0.5, health_poll_interval=0.05, **kw)


def _sorted_tokens(reqs):
    return [r.output_tokens for r in
            sorted(reqs, key=lambda r: r.request_id)]


@timed("chaos_fabric")
def run() -> str:
    global STREAM
    import jax

    from repro.configs.registry import get_config

    n_req = 16 if QUICK else 28
    cfg = get_config(ARCH).scaled()
    STREAM = cfg.name
    trace = _trace(cfg, n_req)

    # ---- clean reference: same trace, no chaos.  The first run only
    # warms the jit caches (its rate is compile-dominated); the second
    # is the measured reference, so goodput retention compares warm
    # against warm ---------------------------------------------------------
    clean_rate = 0.0
    for _ in range(2):
        fab, _ = build_fabric(ARCH, 2, n_slots=SLOTS,
                              prompt_len=PROMPT_PAD, gen_tokens=MAX_GEN,
                              paged=True, block_size=BLOCK,
                              cfg=FabricConfig())
        clean_reqs = _requests(trace)
        clean = fab.run(clean_reqs)
        assert all(r.completed_at is not None for r in clean_reqs), \
            "clean reference failed to complete"
        ref_tokens = _sorted_tokens(clean_reqs)
        clean_rate = clean["cluster"]["throughput_sum_tok_s"]

    # ---- chaos: stall r0 (straggler) + crash r1 mid-trace ----------------
    inj = FaultInjector([
        FaultEvent(at=0.0, replica_id="r0", kind="stall",
                   duration=60.0, stall_s=0.05),
        FaultEvent(at=1.2, replica_id="r1", kind="crash"),
    ])
    fab, _ = build_fabric(ARCH, 2, n_slots=SLOTS, prompt_len=PROMPT_PAD,
                          gen_tokens=MAX_GEN, paged=True,
                          block_size=BLOCK, cfg=_chaos_cfg(),
                          injector=inj)
    chaos_reqs = _requests(trace, spacing=0.04)
    chaos = fab.run(chaos_reqs)
    ft = chaos["fault_tolerance"]

    kinds = {k for _, _, k in ft["injected"]}
    assert "crash" in kinds and "stall" in kinds, \
        f"scheduled faults did not fire: {sorted(kinds)}"
    assert ft["failovers"] >= 1, "crash was not failed over"
    assert ft["quarantines"] >= 1, "straggler was never quarantined"
    assert "r0" in fab.replicas, \
        "quarantine must bench the straggler, not remove it"

    completed = sum(1 for r in chaos_reqs if r.completed_at is not None)
    eligible = n_req - len(fab.retry_policy.rejected)
    completion_rate = completed / max(eligible, 1)
    assert completion_rate >= 1.0, \
        f"lost retry-eligible requests: {completed}/{eligible}"
    assert chaos["failed_requests"] == 0, \
        "retry budget should cover a single crash + one quarantine"
    assert _sorted_tokens(chaos_reqs) == ref_tokens, \
        "chaos run diverged from the clean greedy reference"
    retention = (chaos["cluster"]["throughput_sum_tok_s"]
                 / max(clean_rate, 1e-9))

    serving_row = {
        "requests": n_req, "completed": completed,
        "completion_rate": round(completion_rate, 3),
        "greedy_tokens_identical": True,
        "failovers": ft["failovers"], "quarantines": ft["quarantines"],
        "retried_requests": ft["retried_requests"],
        "rejected_requests": ft["rejected_requests"],
        "injected": [[round(t, 3), rid, k]
                     for t, rid, k in ft["injected"][:8]],
        "clean_tok_s_aggregate": round(clean_rate, 1),
        "chaos_tok_s_aggregate": round(
            chaos["cluster"]["throughput_sum_tok_s"], 1),
        "goodput_retention": round(retention, 3),
        "survivors": sorted(fab.replicas),
    }

    # ---- nan_round: poisoned shadow must never reach serving -------------
    inj = FaultInjector([FaultEvent(at=0.0, replica_id="r0",
                                    kind="nan_grads")])
    fcfg = _chaos_cfg(enable_finetuning=True, train_batch=4,
                      bootstrap_steps=3, steps_per_round=3,
                      min_cohort=2)
    fab, _ = build_fabric(ARCH, 2, n_slots=SLOTS, prompt_len=PROMPT_PAD,
                          gen_tokens=MAX_GEN, train_pool=8, cfg=fcfg,
                          injector=inj)
    nan_reqs = _requests(trace[:n_req // 2])
    nan_out = fab.run(nan_reqs, min_rounds=1, timeout=180.0)
    nft = nan_out["fault_tolerance"]

    assert any(k == "nan_grads" for _, _, k in nft["injected"]), \
        "nan_grads event never fired"
    assert nft["nan_publishes_blocked"] >= 1, \
        "poisoned shadow was not blocked at a publish gate"
    assert nan_out["fl_rounds"] >= 1, \
        "FL round did not complete under the NaN fault"
    for rid, rep in fab.replicas.items():
        for leaf in jax.tree_util.tree_leaves(rep.lora):
            assert bool(jax.numpy.isfinite(leaf).all()), \
                f"{rid}: non-finite served adapter leaked past the gates"

    nan_row = {
        "requests": len(nan_reqs),
        "completed": sum(1 for r in nan_reqs
                         if r.completed_at is not None),
        "fl_rounds": nan_out["fl_rounds"],
        "nan_publishes_blocked": nft["nan_publishes_blocked"],
        "served_adapters_finite": True,
    }

    out = {
        "trace": {"n_requests": n_req, "slots": SLOTS,
                  "prompt_pad": PROMPT_PAD, "max_gen": MAX_GEN,
                  "arch": ARCH},
        "serving_chaos": serving_row,
        "nan_round": nan_row,
    }
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=2)
    return (f"completion={completed}/{n_req} "
            f"identical_tokens=yes "
            f"failovers={ft['failovers']} "
            f"quarantines={ft['quarantines']} "
            f"retries={ft['retried_requests']} "
            f"goodput_retention={retention:.2f} "
            f"nan_blocked={nft['nan_publishes_blocked']} "
            f"fl_rounds={nan_out['fl_rounds']}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="short trace for CI (same as BENCH_QUICK=1)")
    if ap.parse_args().smoke:
        QUICK = True
    run()
