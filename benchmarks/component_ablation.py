"""Beyond-paper ablation: which CoLLM component buys what?

Four system variants on the same trace:
  full        states + launcher/FL + coordinator + subflow dispatcher
  no-ft       subflow dispatcher only (enable_finetuning=False) — isolates
              the serving-side contribution (pacing + SLO-aware batching)
  rr          round-robin baseline (no CoLLM component at all)
  no-ft vs full quality delta isolates the model-sharing contribution.

Run separately from benchmarks.run when BENCH_ABLATION=1 (it adds ~4
simulator runs); included in run.py by default since it is quick at the
reduced horizon.
"""
import numpy as np

from benchmarks.common import record
from repro.runtime.experiment import ExperimentConfig, run_experiment


def run() -> str:
    import time
    t0 = time.perf_counter()
    outs = {}
    for name, policy, ft in [("full", "collm", True),
                             ("no-ft", "collm", False),
                             ("rr", "rr", False)]:
        out = run_experiment(ExperimentConfig(
            policy=policy, n_replicas=8, duration=900.0, scale=2.0,
            seed=11, enable_finetuning=ft))
        outs[name] = out
    full, noft, rr = outs["full"], outs["no-ft"], outs["rr"]
    derived = (
        f"serving-side (no-ft vs rr): goodput "
        f"{noft['goodput_tok_s'] / max(rr['goodput_tok_s'], 1):.2f}x "
        f"slo {noft['slo_rate']:.2f} vs {rr['slo_rate']:.2f} | "
        f"model-sharing (full vs no-ft): quality "
        f"{full['mean_quality'] / max(noft['mean_quality'], 1e-9):.2f}x "
        f"qgoodput {full['q_goodput'] / max(noft['q_goodput'], 1):.2f}x | "
        f"util full={full['mean_util']:.2f} no-ft={noft['mean_util']:.2f}")
    record("ablation_components", (time.perf_counter() - t0) * 1e6,
           derived)
    return derived


if __name__ == "__main__":
    run()
