"""Multi-LoRA multi-tenant serving: batched segmented kernel parity,
AdapterRegistry residency/refcount invariants, mixed-tenant decode
equivalence, publish isolation, and the control-plane surfaces
(dispatcher adapter-affinity routing, failover re-registration,
per-adapter stats aggregation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import sample_prompts as _prompts
from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.data.synthetic import SyntheticDataset
from repro.kernels import ops, ref
from repro.runtime.fabric import make_tenant_adapters
from repro.runtime.serving_loop import (
    AdapterError, AdapterRegistry, ContinuousBatcher, GenRequest,
    OutOfAdapterSlots,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen1.5-0.5b").scaled()
    engine = make_engine(cfg, lr=3e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    tenants = make_tenant_adapters(model, 3, seed=1)
    return cfg, engine, model, params, tenants


def _registry(model, tenants, capacity):
    reg = AdapterRegistry(model, capacity=capacity)
    for t, tree in enumerate(tenants):
        reg.register(f"tenant{t}", tree)
    return reg


# ------------------------------------------------------ kernel parity ------
@pytest.mark.parametrize("m,k,n,r,A", [(128, 256, 128, 8, 3),
                                       (256, 128, 256, 16, 2),
                                       (128, 128, 128, 4, 5)])
def test_segmented_kernel_parity(m, k, n, r, A):
    """Interpret-mode segmented kernel == pure-jnp oracle over mixed
    rows (every adapter present plus disabled rows)."""
    ks = jax.random.split(jax.random.key(0), 4)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    w = jax.random.normal(ks[1], (k, n), jnp.float32) * 0.05
    a = jax.random.normal(ks[2], (A, k, r), jnp.float32) * 0.05
    b = jax.random.normal(ks[3], (A, r, n), jnp.float32) * 0.05
    idx = jnp.asarray(np.random.default_rng(1).integers(-1, A, m),
                      jnp.int32)
    y = ops.segmented_lora_matmul(x, w, a, b, idx, 2.0, force_kernel=True)
    yr = ref.segmented_lora_matmul(x, w, a, b, idx, 2.0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


def test_segmented_disabled_rows_bitwise_base():
    """Rows with adapter_idx < 0 must return the pure base product
    BITWISE — garbage (even NaN, on the oracle path) in adapter slots
    never leaks into disabled rows.  This is what lets single-adapter
    and multi-tenant traces share one compiled program."""
    m, k, n, r, A = 128, 128, 128, 8, 3
    ks = jax.random.split(jax.random.key(2), 2)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    w = jax.random.normal(ks[1], (k, n), jnp.float32) * 0.05
    base = np.asarray(
        (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype))
    idx = jnp.asarray([i % A if i % 2 == 0 else -1 for i in range(m)],
                      jnp.int32)
    off = np.asarray(idx) < 0

    # oracle path: NaN poison (select happens AFTER the einsum)
    a_nan = jnp.full((A, k, r), jnp.nan, jnp.float32)
    b_nan = jnp.full((A, r, n), jnp.nan, jnp.float32)
    y = np.asarray(ops.segmented_lora_matmul(x, w, a_nan, b_nan, idx, 2.0))
    np.testing.assert_array_equal(y[off], base[off])

    # kernel path: finite poison (masked before the B matmul)
    a_big = jnp.full((A, k, r), 1e6, jnp.float32)
    b_big = jnp.full((A, r, n), 1e6, jnp.float32)
    y = np.asarray(ops.segmented_lora_matmul(x, w, a_big, b_big, idx, 2.0,
                                             force_kernel=True))
    np.testing.assert_array_equal(y[off], base[off])
    assert np.isfinite(y).all()


def test_rank0_all_disabled_is_base_matmul():
    """An all-disabled wave (every idx = -1) is the single-adapter
    fast path: bitwise-identical to x @ w regardless of stack contents."""
    m, k, n = 128, 128, 128
    ks = jax.random.split(jax.random.key(3), 2)
    x = jax.random.normal(ks[0], (m, k), jnp.float32)
    w = jax.random.normal(ks[1], (k, n), jnp.float32) * 0.05
    idx = jnp.full((m,), -1, jnp.int32)
    a = jnp.full((2, k, 4), jnp.nan, jnp.float32)
    b = jnp.full((2, 4, n), jnp.nan, jnp.float32)
    base = np.asarray(
        (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype))
    for force in (False, True):
        stacks = (a, b) if not force else \
            (jnp.zeros_like(a), jnp.zeros_like(b))
        y = np.asarray(ops.segmented_lora_matmul(
            x, w, *stacks, idx, 2.0, force_kernel=force))
        np.testing.assert_array_equal(y, base)


# -------------------------------------------------- registry invariants ----
def test_registry_refcount_lru_eviction(setup):
    cfg, engine, model, params, tenants = setup
    reg = _registry(model, tenants, capacity=2)
    assert reg.registered() == ["tenant0", "tenant1", "tenant2"]
    assert reg.resident_ids() == ()          # residency is lazy

    s0 = reg.acquire("tenant0")
    assert reg.refcount("tenant0") == 1 and reg.slot_index("tenant0") == s0
    reg.acquire("tenant0")
    assert reg.refcount("tenant0") == 2 and reg.hits == 1
    reg.acquire("tenant1")
    assert reg.resident_ids() == ("tenant0", "tenant1")

    # every slot pinned: tenant2 cannot be admitted, and acquire raises
    assert not reg.can_acquire("tenant2")
    with pytest.raises(OutOfAdapterSlots):
        reg.acquire("tenant2")

    # releasing tenant1 leaves it warm (LRU) — tenant2 now evicts it
    reg.release("tenant1")
    assert reg.refcount("tenant1") == 0
    assert reg.resident_ids() == ("tenant0", "tenant1")
    assert reg.can_acquire("tenant2")
    reg.acquire("tenant2")
    assert reg.evictions == 1
    assert reg.resident_ids() == ("tenant0", "tenant2")

    # re-acquiring the evicted tenant reloads from host
    reg.release("tenant2")
    loads = reg.loads
    reg.acquire("tenant1")
    assert reg.loads == loads + 1


def test_registry_register_update_guards(setup):
    cfg, engine, model, params, tenants = setup
    reg = _registry(model, tenants, capacity=2)
    reg.acquire("tenant0")
    with pytest.raises(AdapterError):
        reg.register("tenant0", tenants[0])   # resident: must use update
    with pytest.raises(AdapterError):
        reg.unregister("tenant0")             # pinned by an in-flight ref
    reg.update("tenant0", tenants[1], version=7)
    assert reg.version("tenant0") == 7
    assert reg.refcount("tenant0") == 1       # publish never drops refs
    reg.release("tenant0")
    reg.unregister("tenant0")
    assert not reg.is_registered("tenant0")


# ------------------------------------------------ mixed-tenant serving -----
def _serve(engine, params, lora, prompts, gen, *, registry=None,
           adapter_ids=None, n_slots=4):
    pad = max(len(p) for p in prompts)
    b = ContinuousBatcher(engine, params, lora, n_slots=n_slots,
                          max_seq=pad + gen, prompt_pad=pad,
                          adapters=registry)
    reqs = [GenRequest(request_id=i, prompt=np.asarray(p, np.int32),
                       max_new_tokens=gen,
                       adapter_id=adapter_ids[i] if adapter_ids else None)
            for i, p in enumerate(prompts)]
    stats = b.run(reqs)
    return b, reqs, stats


def test_mixed_vs_solo_bit_identity(setup):
    """One mixed wave (base + 3 tenants sharing slots) must emit tokens
    bit-identical to each tenant served alone with its tree as the
    plain single-adapter ``lora`` — the segmented path adds tenancy,
    never drift."""
    cfg, engine, model, params, tenants = setup
    prompts = _prompts(cfg, 4, [8, 8, 8, 8])
    aids = [None, "tenant0", "tenant1", "tenant2"]
    reg = _registry(model, tenants, capacity=3)
    _, mixed, stats = _serve(engine, params, tenants[0], prompts, 6,
                             registry=reg, adapter_ids=aids)
    assert all(r.done for r in mixed)
    # tenants diverge (the registry trees are deliberately distinct)
    assert mixed[1].tokens != mixed[2].tokens
    assert mixed[2].tokens != mixed[3].tokens
    for i, aid in enumerate(aids):
        tree = model.init_lora(jax.random.key(9)) if aid is None \
            else tenants[int(aid[-1])]
        _, solo, _ = _serve(engine, params, tree, [prompts[i]], 6)
        assert solo[0].tokens == mixed[i].tokens, \
            f"{aid or 'base'}: mixed wave drifted from solo serving"
    assert stats.adapter_requests == {"tenant0": 1, "tenant1": 1,
                                      "tenant2": 1}


def test_batcher_releases_refs_on_drain(setup):
    """Slot eviction must hand adapter refs back: leaked refs would pin
    slots forever and deadlock admission behind can_acquire."""
    cfg, engine, model, params, tenants = setup
    prompts = _prompts(cfg, 6, [8] * 6)
    aids = [f"tenant{i % 3}" for i in range(6)]
    reg = _registry(model, tenants, capacity=3)
    b, reqs, stats = _serve(engine, params, tenants[0], prompts, 4,
                            registry=reg, adapter_ids=aids, n_slots=3)
    assert stats.finished == 6
    assert all(reg.refcount(f"tenant{t}") == 0 for t in range(3))
    assert all(aid is None for aid in b.slot_aid)
    assert stats.adapter_requests == {"tenant0": 2, "tenant1": 2,
                                      "tenant2": 2}


def test_capacity_backpressure_evicts_and_serves_all(setup):
    """More tenants than device slots: admission backpressures on
    can_acquire, the LRU rotates residency, and every request still
    finishes with the right tenant's weights."""
    cfg, engine, model, params, tenants = setup
    prompts = _prompts(cfg, 6, [8] * 6)
    aids = [f"tenant{i % 3}" for i in range(6)]
    reg = _registry(model, tenants, capacity=2)
    _, reqs, stats = _serve(engine, params, tenants[0], prompts, 4,
                            registry=reg, adapter_ids=aids, n_slots=2)
    assert stats.finished == 6
    assert reg.evictions > 0
    assert all(reg.refcount(f"tenant{t}") == 0 for t in range(3))


def test_publish_isolation_across_update(setup):
    """Rewriting one tenant's slot (the publish path) must not perturb
    any other tenant's greedy stream."""
    cfg, engine, model, params, tenants = setup
    prompts = _prompts(cfg, 1, [8]) * 2      # same prompt, two tenants
    aids = ["tenant1", "tenant2"]
    reg = _registry(model, tenants, capacity=3)
    _, before, _ = _serve(engine, params, tenants[0], prompts, 6,
                          registry=reg, adapter_ids=aids)
    # publish new tenant1 weights (tenant2's tree, version-bumped)
    reg.update("tenant1", tenants[2], version=5)
    _, after, stats = _serve(engine, params, tenants[0], prompts, 6,
                             registry=reg, adapter_ids=aids)
    assert after[1].tokens == before[1].tokens      # tenant2 untouched
    assert after[0].tokens == before[1].tokens      # tenant1 now = t2 tree
    assert stats.adapter_versions["tenant1"] == 5


# ------------------------------------------------------- control plane -----
def test_aggregate_serve_stats_adapter_rollup():
    from repro.runtime.metrics import aggregate_serve_stats

    class S:
        def __init__(self, reqs, vers):
            self.admitted = self.finished = sum(reqs.values())
            self.prefill_tokens = self.cached_prefix_tokens = 0
            self.generated_tokens = self.decode_steps = 0
            self.train_steps = 0
            self.wall_time = 1.0
            self.adapter_version = max(vers.values(), default=0)
            self.train_loss = float("nan")
            self.adapter_requests = reqs
            self.adapter_versions = vers

        def throughput(self):
            return 0.0

    out = aggregate_serve_stats({
        "r0": S({"tenant0": 3, "tenant1": 1}, {"tenant0": 2, "tenant1": 0}),
        "r1": S({"tenant0": 2}, {"tenant0": 5}),
    })
    a = out["cluster"]["adapters"]
    assert a["tenant0"] == {"requests": 5, "version_min": 2,
                            "version_max": 5}
    assert a["tenant1"] == {"requests": 1, "version_min": 0,
                            "version_max": 0}
    assert out["replicas"]["r1"]["adapter_requests"] == {"tenant0": 2}


def test_dispatcher_adapter_affinity_routing():
    """A queued request whose adapter is device-resident on the firing
    replica jumps the FCFS scan window (prefix hits still outrank it)."""
    from test_dispatcher import make_dispatcher
    from repro.core.interfaces import ReplicaPressure, Request

    d, reps, _ = make_dispatcher(1)
    for i in range(4):
        d.submit(Request(request_id=i, stream_id="s", arrival=0.0,
                         deadline=100.0, tokens=4,
                         adapter_id="tenantB" if i == 3 else "tenantA"))
    p = ReplicaPressure(queue_len=0, pending=0, active_slots=0,
                        total_slots=4,
                        resident_adapters=("tenantB",))
    batch = d._select_batch("r0", 2, 0.0, 0.0, pressure=p)
    assert [r.request_id for r in batch] == [3, 0]
    assert d.adapter_routed == 1 and d.affinity_routed == 0


def test_fabric_failover_reregisters_tenants():
    """Killing a replica must leave every tenant it served registered
    somewhere — survivors lacking the tenant inherit its host tree at
    the dead replica's version."""
    from repro.runtime.fabric import build_fabric

    fabric, cfg = build_fabric("qwen1.5-0.5b", 2, n_slots=2,
                               prompt_len=8, gen_tokens=4, n_adapters=2)
    (r0, rep0), (r1, rep1) = sorted(fabric.replicas.items())
    rep1.adapters.unregister("tenant1")
    rep0.adapters.update("tenant1", rep0.adapters.host_tree("tenant1"),
                         version=3)
    fabric.fail_replica(r0, 0.0)
    assert rep1.adapters.is_registered("tenant1")
    assert rep1.adapters.version("tenant1") == 3
    assert rep1.adapters.is_registered("tenant0")
