"""Subflow dispatcher (§6): pacing, backpressure, feasibility shedding,
micro-cycle priority allocation, overload promotion, and placement-aware
routing (headroom order, prefix affinity, queued-request rebalance)."""
import pytest

from repro.core.dispatcher import DispatcherConfig, Subflow, SubflowDispatcher
from repro.core.interfaces import BatchResult, ReplicaPressure, Request
from repro.core.states import ReplicaState


class FakeReplica:
    def __init__(self, rid):
        self.replica_id = rid
        self.model_id = "m"
        self.batches = []
        self.outstanding = 0
        self.quality = 1.0

    def submit_batch(self, reqs, now):
        self.batches.append((now, list(reqs)))

    def outstanding_batches(self, now):
        return self.outstanding

    def queue_length(self, now):
        return self.outstanding

    def quality_score(self, now):
        return self.quality


class FakeLiveReplica(FakeReplica):
    """Fake exporting the live-runtime placement surface."""

    def __init__(self, rid, free_blocks=8, pool_blocks=8,
                 affinity_tokens=0):
        super().__init__(rid)
        self.free_blocks = free_blocks
        self.pool_blocks = pool_blocks
        self.affinity_tokens = affinity_tokens
        self.pending_reqs = []
        self.reclaim_calls = []

    def pressure(self, now):
        return ReplicaPressure(
            queue_len=self.outstanding,
            pending=len(self.pending_reqs),
            active_slots=0, total_slots=4,
            free_blocks=self.free_blocks,
            pool_blocks=self.pool_blocks)

    def prefix_affinity(self, prompt, adapter_id=None):
        return self.affinity_tokens if prompt is not None else 0

    def reclaim_queued(self, max_n, now):
        self.reclaim_calls.append(max_n)
        out = self.pending_reqs[-max_n:]
        self.pending_reqs = self.pending_reqs[:-max_n]
        return out


def make_dispatcher(n=2, **cfg_kw):
    cfg = DispatcherConfig(**cfg_kw)
    replicas = {f"r{i}": FakeReplica(f"r{i}") for i in range(n)}
    promoted = []

    def promote(now):
        promoted.append(now)
        return None

    d = SubflowDispatcher("m", cfg, replicas,
                          state_of=lambda rid: ReplicaState.SERVING,
                          promote_idle=promote)
    return d, replicas, promoted


def _req(i, t=0.0, slo=0.5):
    return Request(request_id=i, stream_id="m", arrival=t, deadline=t + slo)


def test_fire_respects_batch_bound():
    d, replicas, _ = make_dispatcher(n=1)
    for i in range(100):
        d.submit(_req(i))
    sf = d._ensure_subflow("r0", 0.0)
    sf.batch_size = 4
    sf.b_max = 4
    d._fire_due_subflows(0.0)
    assert len(replicas["r0"].batches) == 1
    assert len(replicas["r0"].batches[0][1]) == 4


def test_backpressure_blocks_busy_replica():
    d, replicas, _ = make_dispatcher(n=1)
    replicas["r0"].outstanding = 5
    for i in range(10):
        d.submit(_req(i))
    d._fire_due_subflows(0.0)
    assert replicas["r0"].batches == []
    assert d.queue_depth() == 10


def test_feasibility_shedding():
    """Eq. 13c: requests that cannot meet their deadline are dropped."""
    d, replicas, _ = make_dispatcher(n=1)
    d._ensure_subflow("r0", 0.0)
    lm = d.latency_models["r0"]
    for b, lat in [(1, 0.12), (4, 0.18), (8, 0.26)]:
        lm.observe(b, lat)
    lm.fit()
    d.submit(_req(0, t=-0.45))     # deadline 0.05 < predicted latency
    d.submit(_req(1, t=0.0))
    sf = d.subflows["r0"]
    sf.batch_size = 4
    d._fire_due_subflows(0.0)
    assert d.dropped == 1
    assert len(replicas["r0"].batches[0][1]) == 1


def test_expired_requests_dropped():
    d, _, _ = make_dispatcher(n=1)
    d.submit(_req(0, t=0.0, slo=0.1))
    d._expire_requests(now=1.0)
    assert d.dropped == 1 and d.queue_depth() == 0


def test_micro_cycle_priority_allocation():
    """Eq. 18-19: higher quality + higher unsaturation gets more batch."""
    d, replicas, _ = make_dispatcher(n=2)
    a = d._ensure_subflow("r0", 0.0)
    b = d._ensure_subflow("r1", 0.0)
    a.b_max = b.b_max = 32
    a.batch_size = b.batch_size = 16
    replicas["r0"].quality = 4.0
    replicas["r1"].quality = 1.0
    a.history.append((16, 16))
    b.history.append((16, 16))
    d.micro_cycle(0.0)
    assert a.batch_size > b.batch_size


def test_micro_cycle_smoothing_bounds():
    d, replicas, _ = make_dispatcher(n=1)
    sf = d._ensure_subflow("r0", 0.0)
    sf.b_max = 64
    sf.batch_size = 4
    replicas["r0"].quality = 100.0
    d.micro_cycle(0.0)
    assert sf.batch_size <= int(1.5 * 4) + 1   # no abrupt jump


def test_overload_pressure_promotes():
    d, replicas, promoted = make_dispatcher(n=1)
    sf = d._ensure_subflow("r0", 0.0)
    sf.b_max = 4
    for i in range(50):
        d.submit(_req(i))
    d._overload_pressure(0.0)
    assert promoted, "deep backlog must trigger promotion"


def test_macro_cycle_sets_bmax_from_model():
    d, replicas, _ = make_dispatcher(n=1)
    d._ensure_subflow("r0", 0.0)
    lm = d.latency_models["r0"]
    for b in range(1, 12):
        lm.observe(b, 0.02 * b + 0.05)
    # completed batches feed T_queue
    d.on_batch_result(BatchResult(
        replica_id="r0", batch_size=4, infer_latency=0.13,
        total_latency=0.2, queue_latency=0.07, finished_at=1.0,
        quality=1.0, tokens=100))
    d.macro_cycle(1.0)
    sf = d.subflows["r0"]
    expected = int(((0.5 - 0.07) - 0.05) // 0.02)
    assert abs(sf.b_max - expected) <= 1


def test_macro_overload_reset_clears_stale_queue_samples():
    """Regression: the overload promotion resets T̄_queue to 0.1τ for the
    current cycle, but the pre-promotion latency samples used to stay in
    the deque — the NEXT macro cycle read the same stale overload and
    re-promoted immediately.  The reset must clear the window so
    T̄_queue is re-measured under the new capacity."""
    cfg = DispatcherConfig(slo=0.5)
    replicas = {"r0": FakeReplica("r0"), "r1": FakeReplica("r1")}
    d = SubflowDispatcher("m", cfg, replicas,
                          state_of=lambda rid: ReplicaState.SERVING,
                          promote_idle=lambda now: "r1")
    for _ in range(8):                      # way past the SLO
        d.on_batch_result(BatchResult(
            replica_id="r0", batch_size=4, infer_latency=0.2,
            total_latency=0.9, queue_latency=0.7, finished_at=1.0,
            quality=1.0, tokens=100))
    d.macro_cycle(0.0)
    assert d.overload_promotions == 1
    assert len(d.queue_lat) == 0            # stale window dropped
    assert d.avg_queue_latency() == pytest.approx(0.1 * cfg.slo)
    # next macro cycle: override expired, no fresh samples -> no
    # phantom re-promotion off the old window
    d.macro_cycle(cfg.t_fit)
    assert d.overload_promotions == 1


def test_in_flight_limit_is_at_most():
    """'At most in_flight_limit outstanding' (§2.3 double buffering):
    with the default limit of 1, one outstanding batch must already
    block the next fire — the old ``>`` stacked a third batch behind
    two."""
    d, replicas, _ = make_dispatcher(n=1)
    replicas["r0"].outstanding = 1
    for i in range(8):
        d.submit(_req(i))
    d._fire_due_subflows(0.0)
    assert replicas["r0"].batches == [], \
        "limit 1 with 1 outstanding must not fire"
    replicas["r0"].outstanding = 0
    sf = d.subflows["r0"]
    sf.next_fire = 0.0
    d._fire_due_subflows(0.1)
    assert len(replicas["r0"].batches) == 1


def _live_dispatcher(replicas):
    return SubflowDispatcher(
        "m", DispatcherConfig(), replicas,
        state_of=lambda rid: ReplicaState.SERVING,
        promote_idle=lambda now: None)


def test_placement_prefers_pool_headroom():
    """Due subflows drain the queue in headroom order: the replica with
    free pool blocks gets the head request; an exhausted pool ranks
    last (admission there would just backpressure)."""
    full = FakeLiveReplica("full", free_blocks=0, pool_blocks=8)
    free = FakeLiveReplica("free", free_blocks=8, pool_blocks=8)
    d = _live_dispatcher({"full": full, "free": free})
    for rid in ("full", "free"):
        sf = d._ensure_subflow(rid, 0.0)
        sf.batch_size = sf.b_max = 4
    d.submit(_req(0))
    d._fire_due_subflows(0.0)
    assert [len(b) for _, b in free.batches] == [1]
    assert full.batches == []


def test_placement_prefix_affinity_routing():
    """A request whose prompt matches a replica's prefix cache routes
    there even when FCFS order would have sent it elsewhere."""
    warm = FakeLiveReplica("warm", affinity_tokens=16)
    cold = FakeLiveReplica("cold", free_blocks=16, pool_blocks=16)
    d = _live_dispatcher({"cold": cold, "warm": warm})
    for rid in ("cold", "warm"):
        sf = d._ensure_subflow(rid, 0.0)
        sf.batch_size = sf.b_max = 1
    plain = _req(0)
    hot = _req(1)
    hot.prompt = [1, 2, 3]      # matches warm's cache (fake: any prompt)
    d.submit(plain)
    d.submit(hot)
    d._fire_due_subflows(0.0)
    # cold (more headroom) fires first but takes the PLAIN head request;
    # the prompt-matching one jumps to the warm replica
    assert [r.request_id for _, b in warm.batches for r in b] == [1]
    assert [r.request_id for _, b in cold.batches for r in b] == [0]
    assert d.affinity_routed == 1


def test_micro_cycle_rebalances_queued_requests():
    """A starved replica (empty admission queue, free slots) pulls
    excess queued work back to the stream queue for re-placement."""
    busy = FakeLiveReplica("busy")
    idle = FakeLiveReplica("idle")
    d = _live_dispatcher({"busy": busy, "idle": idle})
    for rid in ("busy", "idle"):
        sf = d._ensure_subflow(rid, 0.0)
        sf.batch_size = 2
        sf.history.append((2, 2))
    busy.pending_reqs = [_req(i) for i in range(6)]
    d.micro_cycle(0.0)
    assert d.rebalanced > 0
    assert d.queue_depth() == d.rebalanced
    assert len(busy.pending_reqs) == 6 - d.rebalanced


def test_requeue_preserves_order_at_front():
    d, _, _ = make_dispatcher(n=1)
    d.submit(_req(10))
    back = [_req(0), _req(1)]
    for r in back:
        r.dispatched = True
    d.requeue(back)
    assert [r.request_id for r in d.queue] == [0, 1, 10]
    assert all(not r.dispatched for r in back)


def test_unsaturation_ignores_empty_queue_fires():
    """Eq. 17: a fire against an EMPTY stream queue says nothing about
    replica capacity — recording (target, 0) would inflate u_i and
    skew micro-cycle priorities toward idle streams."""
    d, replicas, _ = make_dispatcher(n=1)
    sf = d._ensure_subflow("r0", 0.0)
    sf.batch_size = 4
    d._fire_due_subflows(0.0)          # no demand at all
    assert len(sf.history) == 0
    assert sf.unsaturation() == 0.0
    d.submit(_req(0, t=0.2))
    sf.next_fire = 0.0
    d._fire_due_subflows(0.2)          # real demand, partial fill
    assert list(sf.history) == [(4, 1)]
    assert sf.unsaturation() == pytest.approx(0.75)
