"""Copy-on-write prefix sharing over the paged KV pool: allocator
refcount/double-free/alias invariants, greedy equivalence with the
cache on vs off (full attention AND sliding-window ring-wrap COW),
LRU retention + allocator-pressure reclaim, hash-collision safety and
partial-block boundaries, and the match cap that always leaves one
prompt token to prefill."""
import jax
import numpy as np
import pytest

from conftest import reference_greedy as _reference_greedy
from conftest import sample_prompts as _prompts
from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.runtime import paging
from repro.runtime.paging import BlockAllocator, BlockError
from repro.runtime.serving_loop import ContinuousBatcher, GenRequest


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen1.5-0.5b").scaled()
    engine = make_engine(cfg, lr=3e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = jax.tree.map(lambda x: x + 0.01,
                        model.init_lora(jax.random.key(1)))
    return cfg, engine, model, params, lora


def _family_requests(prompts, gens):
    return [GenRequest(request_id=i, prompt=p.copy(), max_new_tokens=g)
            for i, (p, g) in enumerate(zip(prompts, gens))]


def _run_pair(engine, params, lora, build_reqs, **kw):
    """Run the same trace with the prefix cache off and on; returns
    (reqs_off, reqs_on, batcher_on)."""
    off = build_reqs()
    ContinuousBatcher(engine, params, lora, paged=True, **kw).run(off)
    on = build_reqs()
    b = ContinuousBatcher(engine, params, lora, paged=True,
                          prefix_cache=True, **kw)
    b.run(on)
    return off, on, b


# ----------------------------------------------------- allocator units -----
def test_double_free_detected_immediately():
    a = BlockAllocator(n_blocks=8, block_size=4)
    a.reserve(3)
    ids = a.take(3)
    a.free(ids[:1])
    with pytest.raises(BlockError, match="double free"):
        a.free(ids[:1])           # fails NOW, not at pool overflow
    a.free(ids[1:])
    assert a.n_free == 7 and a.n_used == 0


def test_alias_of_free_block_detected():
    a = BlockAllocator(n_blocks=8, block_size=4)
    a.reserve(1)
    (bid,) = a.take(1)
    a.share([bid])                # live alias ok
    assert a.ref(bid) == 2
    a.free([bid])
    a.free([bid])
    with pytest.raises(BlockError, match="share of unreferenced"):
        a.share([bid])
    with pytest.raises(BlockError, match="acquire of free"):
        a.acquire([bid])


def test_retained_pool_and_revive():
    a = BlockAllocator(n_blocks=8, block_size=4)
    a.reserve(2)
    ids = a.take(2)
    a.pin(ids[0])
    a.free(ids)
    # pinned block parks in the retained pool, unpinned returns to free
    assert a.n_retained == 1 and a.n_free == 6 and a.n_used == 0
    assert a.available() == 7     # retained still reclaimable capacity
    a.acquire([ids[0]])           # cache hit revives it
    assert a.ref(ids[0]) == 1 and a.n_retained == 0
    a.free([ids[0]])
    a.unpin(ids[0])               # unregistration frees it outright
    assert a.n_retained == 0 and a.n_free == 7


def test_take_reclaims_retained_lru_and_notifies():
    a = BlockAllocator(n_blocks=5, block_size=4)   # capacity 4
    reclaimed = []
    a.on_reclaim = reclaimed.append
    a.reserve(4)
    ids = a.take(4)
    for b in ids:
        a.pin(b)
    a.free(ids)                    # all retained, free list empty
    assert a.n_free == 0 and a.n_retained == 4
    a.reserve(2)
    got = a.take(2)                # must reclaim the two OLDEST
    assert got == ids[:2] and reclaimed == ids[:2]
    assert a.n_retained == 2


# ------------------------------------------------- full-attention path -----
def test_prefix_cache_matches_uncached_and_reference(setup):
    """Repeated-prefix trace: cache on must produce bit-identical greedy
    tokens to cache off and the one-at-a-time reference, with clean
    refcount drain and warm blocks retained."""
    cfg, engine, model, params, lora = setup
    (shared,) = _prompts(cfg, 1, [24])            # 3 full blocks of 8
    tails = _prompts(cfg, 5, [4, 7, 2, 8, 5], seed=11)
    prompts = [np.concatenate([shared, t]) for t in tails]
    gens = [5, 3, 6, 2, 4]

    off, on, b = _run_pair(
        engine, params, lora,
        lambda: _family_requests(prompts, gens),
        n_slots=2, max_seq=48, prompt_pad=32, block_size=8)
    for i in range(len(prompts)):
        ref = _reference_greedy(model, params, lora, prompts[i], gens[i])
        assert on[i].tokens == ref, f"shared diverges on req {i}"
        assert off[i].tokens == ref, f"paged diverges on req {i}"
    # refcount invariants after admit/evict churn: no live refs, no
    # leaked reservations, warm prefix blocks retained for reuse
    assert b.allocator.n_used == 0 and b.allocator.reserved == 0
    assert b.allocator.n_retained > 0
    assert b.allocator.n_free + b.allocator.n_retained \
        == b.allocator.capacity
    assert b.prefix_cache.hits > 0
    assert b.stats.cached_prefix_tokens > 0
    assert b.stats.prefill_tokens < sum(len(p) for p in prompts)


def test_match_cap_leaves_one_suffix_token(setup):
    """A fully block-aligned, fully cached prompt must still prefill at
    least one token — its logits seed generation."""
    cfg, engine, model, params, lora = setup
    (p16,) = _prompts(cfg, 1, [16])               # exactly 2 blocks of 8
    reqs = [GenRequest(request_id=i, prompt=p16.copy(), max_new_tokens=4)
            for i in range(2)]
    b = ContinuousBatcher(engine, params, lora, n_slots=1, max_seq=24,
                          prompt_pad=16, paged=True, block_size=8,
                          prefix_cache=True)
    for r in reqs:
        b.submit(r)
    while not b.idle():
        b.step()
    assert reqs[0].tokens == reqs[1].tokens
    ref = _reference_greedy(model, params, lora, p16, 4)
    assert reqs[0].tokens == ref
    # second request matched only ONE of the two full blocks
    assert b.prefix_cache.hits == 1
    assert b.stats.cached_prefix_tokens == 8
    matcher = b.prefix_cache.match(p16)
    assert len(matcher) == 1      # cap: (16-1)//8 == 1


def test_partial_block_boundary_and_hash_collision(setup, monkeypatch):
    """Prefixes that end mid-block share only their full blocks, and a
    degenerate (constant) content hash must not alias wrong content —
    lookups verify the full token bytes."""
    cfg, engine, model, params, lora = setup
    monkeypatch.setattr(paging, "_digest",
                        lambda tokens, namespace=None: b"collide")
    (shared,) = _prompts(cfg, 1, [10])            # 2 full blocks of 4 + 2
    tails = _prompts(cfg, 3, [3, 5, 2], seed=7)
    prompts = [np.concatenate([shared, t]) for t in tails]
    gens = [4, 3, 5]
    off, on, b = _run_pair(
        engine, params, lora,
        lambda: _family_requests(prompts, gens),
        n_slots=1, max_seq=24, prompt_pad=16, block_size=4)
    for i in range(len(prompts)):
        assert on[i].tokens == off[i].tokens, f"req {i} diverged"
    # the shared 10-token prefix contributes exactly 2 full blocks per
    # warm request, even though every chunk hashes identically
    assert b.stats.cached_prefix_tokens == 2 * 8
    assert b.prefix_cache.hits == 4


# ------------------------------------------------------- reclaim path ------
def test_allocator_pressure_reclaims_retained(setup):
    """With a pool too small to retain every prefix, distinct prompts
    force LRU reclaim of cached blocks — admission must never stall and
    outputs stay correct."""
    cfg, engine, model, params, lora = setup
    prompts = _prompts(cfg, 6, [12, 12, 12, 12, 12, 12], seed=5)
    gens = [3] * 6
    # capacity 6: each request worst-cases 4 blocks (12+2 tokens, bs 4),
    # so retained prefixes MUST be reclaimed to admit the next request
    off, on, b = _run_pair(
        engine, params, lora,
        lambda: _family_requests(prompts, gens),
        n_slots=1, max_seq=16, prompt_pad=12, block_size=4, n_blocks=7)
    for i in range(6):
        assert on[i].tokens == off[i].tokens, f"req {i} diverged"
    assert b.prefix_cache.reclaimed > 0
    assert b.allocator.n_used == 0 and b.allocator.reserved == 0
    # cache table never points at reclaimed blocks: every registered
    # block is still retained or live
    for bid in list(b.prefix_cache._key_of):
        assert b.allocator.ref(bid) > 0 or bid in b.allocator._retained


# ------------------------------------------------- sliding-window path -----
def test_sliding_window_sharing_with_cow(setup):
    """Windowed archs ring-wrap decode writes back into prompt blocks:
    a sharer whose wrap re-enters an aliased block must copy-on-write a
    private block, bit-identically to the unshared runtime."""
    cfg = get_config("qwen1.5-0.5b").scaled(sliding_window=16)
    engine = make_engine(cfg, lr=3e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = jax.tree.map(lambda x: x + 0.01,
                        model.init_lora(jax.random.key(1)))
    (shared,) = _prompts(cfg, 1, [12])            # 3 full blocks of 4
    tails = _prompts(cfg, 2, [2, 2], seed=3)

    def run(pc):
        b = ContinuousBatcher(engine, params, lora, n_slots=2,
                              max_seq=40, prompt_pad=16, paged=True,
                              block_size=4, n_blocks=13,
                              prefix_cache=pc)
        cows = []
        if pc:
            orig = b._jit_copy_blocks
            b._jit_copy_blocks = \
                lambda c, s, d: (cows.append(1), orig(c, s, d))[1]
        # seed: short generation (never wraps) registers the prefix
        seed = GenRequest(request_id=0, prompt=shared.copy(),
                          max_new_tokens=4)
        b.submit(seed)
        while not b.idle():
            b.step()
        # two concurrent sharers decode past the window: their ring
        # wrap re-enters the aliased prefix blocks
        sharers = [GenRequest(request_id=1 + i,
                              prompt=np.concatenate([shared, tails[i]]),
                              max_new_tokens=10) for i in range(2)]
        for r in sharers:
            b.submit(r)
        while not b.idle():
            b.step()
        return b, [seed] + sharers, cows

    b_on, on, cows = run(True)
    b_off, off, _ = run(False)
    for i in range(3):
        assert on[i].tokens == off[i].tokens, f"req {i} diverged"
    assert b_on.prefix_cache.hits > 0, "sharers must alias the prefix"
    assert cows, "ring wrap over a shared block must copy-on-write"
    assert b_on.allocator.n_used == 0 and b_on.allocator.reserved == 0


def test_wrapping_request_blocks_not_registered(setup):
    """A request whose decode will wrap the ring never registers its
    prompt blocks (they are doomed to be overwritten mid-flight, and an
    owner COWing its own blocks would outrun its reservation)."""
    cfg = get_config("qwen1.5-0.5b").scaled(sliding_window=8)
    engine = make_engine(cfg, lr=3e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = model.init_lora(jax.random.key(1))
    (p,) = _prompts(cfg, 1, [8])
    b = ContinuousBatcher(engine, params, lora, n_slots=1, max_seq=24,
                          prompt_pad=8, paged=True, block_size=4,
                          prefix_cache=True)
    b.submit(GenRequest(request_id=0, prompt=p.copy(),
                        max_new_tokens=12))       # wraps the 8-ring
    while not b.idle():
        b.step()
    assert len(b.prefix_cache) == 0
    assert b.allocator.n_retained == 0


# ------------------------------------------------------- cache gating ------
def test_prefix_cache_requires_paged(setup):
    cfg, engine, model, params, lora = setup
    with pytest.raises(ValueError, match="prefix_cache requires paged"):
        ContinuousBatcher(engine, params, lora, n_slots=1,
                          prefix_cache=True)


def test_windowed_hit_on_tiny_pool_never_deadlocks(setup):
    """Reviving retained blocks costs capacity ON TOP of a windowed
    request's full worst-case reservation: on a pool sized for exactly
    one worst-case request, a warm hit must trim its match and admit
    cold rather than backpressure an idle pool forever."""
    cfg = get_config("qwen1.5-0.5b").scaled(sliding_window=16)
    engine = make_engine(cfg, lr=3e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = model.init_lora(jax.random.key(1))
    (shared,) = _prompts(cfg, 1, [8])
    (tail,) = _prompts(cfg, 1, [4], seed=9)

    def build_reqs():
        return [
            GenRequest(request_id=0, prompt=shared.copy(),
                       max_new_tokens=4),          # registers 2 blocks
            GenRequest(request_id=1,
                       prompt=np.concatenate([shared, tail]),
                       max_new_tokens=8),          # worst case = 4 = pool
        ]

    # capacity 4 == one worst-case request: matched revive (2) + worst
    # (4) exceeds the pool, so the hit must be trimmed away
    off, on, b = _run_pair(
        engine, params, lora, build_reqs,
        n_slots=1, max_seq=24, prompt_pad=16, block_size=4, n_blocks=5)
    for i in range(2):
        assert on[i].tokens == off[i].tokens, f"req {i} diverged"
    assert b.stats.finished == 2
    assert b.allocator.n_used == 0 and b.allocator.reserved == 0


def test_recycled_parent_id_cannot_resurrect_stale_chain():
    """Entries are keyed by parent BLOCK ID: dropping a parent must
    cascade to its children, or a reclaimed-and-re-registered parent id
    would resurrect a chain whose KV was computed under a DIFFERENT
    prefix (byte verification cannot catch it — the child's content
    matches, its attention context does not)."""
    from repro.runtime.paging import PrefixCache
    a = BlockAllocator(n_blocks=5, block_size=4)   # capacity 4
    pc = PrefixCache(a)
    A = np.arange(4, dtype=np.int32)
    B = np.arange(4, dtype=np.int32) + 100
    D = np.arange(4, dtype=np.int32) + 200
    # register chain X(A) -> C(B) and evict it into the retained pool
    a.reserve(3)
    x, c, extra = a.take(3)
    pc.register(np.concatenate([A, B, [7]]), [x, c, extra], 0)
    assert pc.is_registered(x) and pc.is_registered(c)
    a.free([x, c, extra])
    assert a.n_retained == 2
    # pressure reclaims X (oldest) — C's (X, digest(B)) entry must die
    # with it, and C must stop being retained (unreachable content)
    a.reserve(4)
    got = a.take(4)
    assert x in got
    assert not pc.is_registered(c)
    # X comes back holding DIFFERENT content D; a [D, B, ...] prompt
    # must match only the D block, never the stale B child
    pc.register(np.concatenate([D, B, [9]]), got[:3], 0)
    assert pc.match(np.concatenate([D, B, [9]]))[:1] == [got[0]]
    assert pc.match(np.concatenate([A, B, [7]])) == []
