"""Incremental decode must reproduce the full-sequence forward pass —
the core correctness invariant of the serving path (KV caches, SSD
recurrence, ring buffers, cross-attention caches)."""
import jax
import jax.numpy as jnp
import pytest

from conftest import make_batch
from repro.configs.registry import ARCH_IDS, get_config
from repro.models.model import build

PARITY_ARCHS = [a for a in ARCH_IDS if get_config(a).has_decode]


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).scaled()
    is_moe = cfg.n_experts > 0
    m = build(cfg)
    params = m.init(jax.random.key(1))
    lora = jax.tree.map(lambda x: x + 0.01,
                        m.init_lora(jax.random.key(2)))
    B, S = 2, 20
    batch = make_batch(cfg, batch=B, seq=S)
    toks = batch["tokens"]
    full = m.logits(params, lora, batch)
    caches = m.init_caches(B, S)
    errs = []
    for t in range(S):
        lg, caches = m.decode_step(params, lora, caches, toks[:, t:t + 1],
                                   jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    rel = sorted(e / scale for e in errs)
    if is_moe:
        # capacity-based top-k routing depends on batch composition: a
        # near-tie router logit can select different experts between the
        # 40-token forward group and the 2-token decode group (standard
        # MoE serving nondeterminism).  Require the vast majority of
        # positions to match exactly and the median to be tight.
        matched = sum(1 for r in rel if r < 5e-5)
        assert matched >= int(0.6 * S), f"{arch}: {matched}/{S} match"
        assert rel[S // 2] < 5e-5, f"{arch}: median {rel[S // 2]}"
        # decode itself is deterministic: same caches + token → same out
        lg2, _ = m.decode_step(params, lora, caches,
                               toks[:, -1:], jnp.int32(S - 1))
        lg3, _ = m.decode_step(params, lora, caches,
                               toks[:, -1:], jnp.int32(S - 1))
        assert bool(jnp.all(lg2 == lg3))
    else:
        assert rel[-1] < 5e-5, f"{arch}: decode diverges ({rel[-1]})"


def test_sliding_window_ring_buffer():
    """Hymba's ring-buffer cache: decoding past the window must agree
    with a full-cache decode (window masking equivalence)."""
    cfg = get_config("hymba-1.5b").scaled(sliding_window=8)
    m = build(cfg)
    params = m.init(jax.random.key(0))
    lora = m.init_lora(jax.random.key(1))
    B, S = 1, 20
    toks = jax.random.randint(jax.random.key(2), (B, S), 0, cfg.vocab_size)
    full = m.logits(params, lora, {"tokens": toks})
    caches = m.init_caches(B, S)     # ring buffer: min(S, window)=8 slots
    assert caches["kv"][0].shape[2] == 8
    worst = 0.0
    for t in range(S):
        lg, caches = m.decode_step(params, lora, caches, toks[:, t:t + 1],
                                   jnp.int32(t))
        worst = max(worst, float(jnp.max(jnp.abs(lg[:, 0] - full[:, t]))))
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert worst / scale < 5e-5
