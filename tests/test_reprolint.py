"""reprolint regression tests: each rule class must FIRE on a seeded
violation and stay SILENT on the shipped tree, the pragma escape hatch
must suppress (and demand a reason), and the interface-conformance rule
must catch a real drift — a method removed from a copy of the real
``SimReplica``."""
import importlib.util
import pathlib
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]

_spec = importlib.util.spec_from_file_location(
    "reprolint", REPO / "tools" / "analysis" / "reprolint.py")
reprolint = importlib.util.module_from_spec(_spec)
sys.modules["reprolint"] = reprolint      # dataclasses needs the module
_spec.loader.exec_module(reprolint)


def _tree(tmp_path, files):
    """Materialize a minimal repo tree from {relpath: source}."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return str(tmp_path)


def _rules(findings):
    return sorted({f.rule for f in findings})


# ------------------------------------------------------- shipped tree ------
def test_shipped_tree_is_clean():
    """The gate CI enforces: zero findings on the repo as committed."""
    findings = reprolint.lint_root(str(REPO))
    assert findings == [], "\n".join(f.render(str(REPO)) for f in findings)


# ------------------------------------------------------ JAX hazard rules ---
def test_host_sync_in_hot_path_fires(tmp_path):
    root = _tree(tmp_path, {"src/repro/runtime/serving_loop.py": """\
        import jax
        import jax.numpy as jnp
        import numpy as np

        class ContinuousBatcher:
            def step(self, logits):
                x = logits.item()
                y = float(jnp.argmax(logits))
                z = np.asarray(jnp.exp(logits))
                w = jax.device_get(logits)
                return x, y, z, w
        """})
    findings = reprolint.lint_root(root, rules={"RL001"})
    assert len(findings) == 4
    assert _rules(findings) == ["RL001"]
    assert all("ContinuousBatcher.step" in f.msg for f in findings)


def test_host_sync_reaches_through_helpers(tmp_path):
    """The closure walks helper calls: a sync buried two frames below
    ``step`` is still a hot-path sync."""
    root = _tree(tmp_path, {"src/repro/runtime/serving_loop.py": """\
        import jax.numpy as jnp

        def _inner(logits):
            return logits.item()

        def _helper(logits):
            return _inner(logits)

        class ContinuousBatcher:
            def step(self, logits):
                return _helper(logits)
        """})
    findings = reprolint.lint_root(root, rules={"RL001"})
    assert len(findings) == 1 and findings[0].rule == "RL001"


def test_time_in_jitted_closure_fires(tmp_path):
    root = _tree(tmp_path, {"src/repro/runtime/kern.py": """\
        import time
        import jax

        def _traced(a):
            return a * time.time()

        _jit_traced = jax.jit(_traced)
        """})
    findings = reprolint.lint_root(root, rules={"RL002"})
    assert len(findings) == 1 and findings[0].rule == "RL002"
    assert "wall-clock" in findings[0].msg


def test_unhashable_static_arg_fires(tmp_path):
    root = _tree(tmp_path, {"src/repro/runtime/kern.py": """\
        import jax

        def _traced(a, *, mode=None):
            return a

        _jit_traced = jax.jit(_traced, static_argnames=("mode",))

        def caller(buf):
            return _jit_traced(buf, mode=["not", "hashable"])
        """})
    findings = reprolint.lint_root(root, rules={"RL003"})
    assert len(findings) == 1 and findings[0].rule == "RL003"


def test_donated_buffer_reuse_fires(tmp_path):
    root = _tree(tmp_path, {"src/repro/runtime/kern.py": """\
        import jax

        def _traced(a):
            return a * 2

        _jit_donor = jax.jit(_traced, donate_argnums=(0,))

        def caller(buf):
            y = _jit_donor(buf)
            return buf + y
        """})
    findings = reprolint.lint_root(root, rules={"RL004"})
    assert len(findings) == 1 and findings[0].rule == "RL004"
    assert "donat" in findings[0].msg.lower()


def test_donated_buffer_rebound_is_clean(tmp_path):
    """Rebinding the name to the result (the standard donate idiom)
    must NOT be flagged."""
    root = _tree(tmp_path, {"src/repro/runtime/kern.py": """\
        import jax

        def _traced(a):
            return a * 2

        _jit_donor = jax.jit(_traced, donate_argnums=(0,))

        def caller(buf):
            buf = _jit_donor(buf)
            return buf + 1
        """})
    assert reprolint.lint_root(root, rules={"RL004"}) == []


# --------------------------------------------------------- pragma ----------
def test_pragma_suppresses_with_reason(tmp_path):
    root = _tree(tmp_path, {"src/repro/runtime/serving_loop.py": """\
        class ContinuousBatcher:
            def step(self, logits):
                return logits.item()  # lint: host-sync-ok single scalar per request, measured
        """})
    assert reprolint.lint_root(root, rules={"RL001"}) == []


def test_pragma_without_reason_fires_rl000(tmp_path):
    root = _tree(tmp_path, {"src/repro/runtime/serving_loop.py": """\
        class ContinuousBatcher:
            def step(self, logits):
                return logits.item()  # lint: host-sync-ok
        """})
    findings = reprolint.lint_root(root, rules={"RL001"})
    assert _rules(findings) == ["RL000"]
    assert "reason" in findings[0].msg


# -------------------------------------------------- conformance rules ------
def _strip_method(src: str, meth: str) -> str:
    """Delete one method (header + body) from a class, textually —
    every other line stays identical to the shipped file."""
    lines = src.splitlines(keepends=True)
    start = next(i for i, ln in enumerate(lines)
                 if ln.startswith(f"    def {meth}("))
    end = start + 1
    while end < len(lines):
        ln = lines[end]
        if ln.strip() and not ln.startswith("        "):
            break
        end += 1
    return "".join(lines[:start] + lines[end:])


def test_replica_conformance_catches_removed_method(tmp_path):
    """The satellite-mandated regression: copy the REAL interfaces and
    SimReplica into a scratch tree, remove one ``ReplicaHandle`` method
    from the copy, and assert reprolint names exactly that drift."""
    interfaces = (REPO / "src/repro/core/interfaces.py").read_text()
    replica = (REPO / "src/repro/runtime/replica.py").read_text()
    mutated = _strip_method(replica, "begin_round")
    assert "def begin_round" in replica
    assert mutated.count("def begin_round") \
        == replica.count("def begin_round") - 1
    root = _tree(tmp_path, {
        "src/repro/core/interfaces.py": interfaces,
        "src/repro/runtime/replica.py": mutated,
    })
    findings = reprolint.lint_root(root, rules={"RL101"})
    assert len(findings) == 1 and findings[0].rule == "RL101"
    assert "begin_round" in findings[0].msg \
        and "SimReplica" in findings[0].msg

    # the unmutated copies are conformant — the finding is the drift,
    # not an artifact of the scratch tree
    clean = _tree(tmp_path / "clean", {
        "src/repro/core/interfaces.py": interfaces,
        "src/repro/runtime/replica.py": replica,
    })
    assert reprolint.lint_root(clean, rules={"RL101"}) == []


def test_stats_coverage_fires_on_unfolded_field(tmp_path):
    root = _tree(tmp_path, {"src/repro/runtime/metrics.py": """\
        import dataclasses

        @dataclasses.dataclass
        class ServeStats:
            admitted: int = 0
            ghost_field: int = 0

        def aggregate_serve_stats(per):
            return {"admitted": sum(p.admitted for p in per)}
        """})
    findings = reprolint.lint_root(root, rules={"RL102"})
    assert len(findings) == 1 and findings[0].rule == "RL102"
    assert "ghost_field" in findings[0].msg


def test_request_threading_fires_on_dead_field(tmp_path):
    root = _tree(tmp_path, {"src/repro/runtime/serving_loop.py": """\
        import dataclasses

        @dataclasses.dataclass
        class GenRequest:
            request_id: int
            dead_knob: float = 0.0

        def submit(req):
            return req.request_id
        """})
    findings = reprolint.lint_root(root, rules={"RL103"})
    assert len(findings) == 1 and findings[0].rule == "RL103"
    assert "dead_knob" in findings[0].msg


def test_bench_registration_fires_then_clears(tmp_path):
    files = {
        "benchmarks/rogue.py": 'OUT = "BENCH_rogue.json"\n',
        "scripts/ci.sh": "set -e\n",
    }
    root = _tree(tmp_path, files)
    findings = reprolint.lint_root(root, rules={"RL104"})
    assert len(findings) == 1 and findings[0].rule == "RL104"
    assert "rogue.py" in findings[0].msg
    (tmp_path / "scripts/ci.sh").write_text(
        "set -e\npython benchmarks/rogue.py --smoke\n")
    assert reprolint.lint_root(root, rules={"RL104"}) == []


def test_sanitizer_hooks_fires_on_unhooked_mutator(tmp_path):
    """RL105: every public ``BlockAllocator`` entry point that mutates
    allocator state must call its ``BlockSanitizer`` hook — a mutator
    that skips ``self.san`` leaves the shadow mirror stale and the
    use-after-free/use-after-swap checks blind."""
    root = _tree(tmp_path, {"src/repro/runtime/paging.py": """\
        class BlockAllocator:
            def __init__(self, san):
                self.san = san
                self.refcount = {}
                self.n_free = 0

            def free(self, ids):
                for b in ids:
                    self.refcount[b] -= 1
                self.san.on_free(ids)

            def swap_out(self, ids):
                for b in ids:
                    self.refcount[b] = 0
                    self.n_free += 1

            def ref(self, b):
                return self.refcount.get(b, 0)
        """})
    findings = reprolint.lint_root(root, rules={"RL105"})
    # swap_out mutates without touching self.san; the hooked free and
    # the read-only ref stay silent
    assert len(findings) == 1 and findings[0].rule == "RL105"
    assert "swap_out" in findings[0].msg and "san" in findings[0].msg

    hooked = _tree(tmp_path / "hooked", {"src/repro/runtime/paging.py": """\
        class BlockAllocator:
            def __init__(self, san):
                self.san = san
                self.refcount = {}

            def swap_out(self, ids):
                for b in ids:
                    self.refcount[b] = 0
                self.san.on_swap_out(ids)
        """})
    assert reprolint.lint_root(hooked, rules={"RL105"}) == []


# ------------------------------------------------------------- CLI ---------
def test_main_exit_codes(tmp_path, capsys):
    dirty = _tree(tmp_path / "dirty", {
        "src/repro/runtime/serving_loop.py": """\
        class ContinuousBatcher:
            def step(self, logits):
                return logits.item()
        """})
    assert reprolint.main(["--root", dirty]) == 1
    out = capsys.readouterr().out
    assert "RL001[host-sync]" in out

    clean = _tree(tmp_path / "clean",
                  {"src/repro/runtime/ok.py": "x = 1\n"})
    assert reprolint.main(["--root", clean]) == 0
    assert "reprolint: clean" in capsys.readouterr().out
