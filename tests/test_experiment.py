"""End-to-end simulation: CoLLM + baselines on short traces — the
integration surface every paper figure rests on."""
import pytest

from repro.runtime.experiment import ExperimentConfig, run_experiment


@pytest.fixture(scope="module")
def short_runs():
    out = {}
    for policy in ["collm", "dlora", "shepherd", "peft", "rr"]:
        cfg = ExperimentConfig(policy=policy, n_replicas=6,
                               duration=420.0, scale=1.0, seed=3)
        out[policy] = run_experiment(cfg)
    return out


def test_all_policies_complete_requests(short_runs):
    for policy, out in short_runs.items():
        assert out["requests"] > 0
        assert out["completed"] > 0, policy
        assert out["slo_rate"] > 0.3, (policy, out["slo_rate"])


def test_collm_finetunes_at_low_load(short_runs):
    out = short_runs["collm"]
    assert out["fl_rounds"] > 0, "idle troughs must trigger FL rounds"
    assert out["mean_loss"] < 2.4, "fine-tuning must reduce loss"


def test_collm_quality_beats_static_baselines(short_runs):
    q_collm = short_runs["collm"]["mean_quality"]
    for p in ["dlora", "shepherd", "peft", "rr"]:
        assert q_collm > short_runs[p]["mean_quality"], p


def test_collm_utilization_higher(short_runs):
    u_collm = short_runs["collm"]["mean_util"]
    assert u_collm > short_runs["peft"]["mean_util"]


def test_overhead_small(short_runs):
    assert short_runs["collm"]["overhead_frac"] < 0.05


def test_determinism():
    cfg = ExperimentConfig(policy="collm", n_replicas=4, duration=200.0,
                           seed=7)
    a = run_experiment(cfg)
    b = run_experiment(cfg)
    assert a["slo_met"] == b["slo_met"]
    assert a["goodput_tok_s"] == pytest.approx(b["goodput_tok_s"])


def test_replica_failure_tolerated():
    cfg = ExperimentConfig(policy="collm", n_replicas=6, duration=300.0,
                           seed=1, failures=[(2, 100.0, 200.0)])
    out = run_experiment(cfg)
    assert out["slo_rate"] > 0.3
    assert out["completed"] > 0


def test_straggler_mitigated():
    base = ExperimentConfig(policy="collm", n_replicas=6, duration=300.0,
                            seed=2)
    slow = ExperimentConfig(policy="collm", n_replicas=6, duration=300.0,
                            seed=2, stragglers={0: 3.0})
    out_base = run_experiment(base)
    out_slow = run_experiment(slow)
    # a 3x straggler on 1/6 replicas must not collapse goodput
    assert out_slow["goodput_tok_s"] > 0.6 * out_base["goodput_tok_s"]
