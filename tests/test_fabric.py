"""Multi-replica live serving fabric: dispatcher-routed pool of
continuous batchers — placement routing, greedy equivalence with the
single-replica runtime, mid-flight failover, sampled decoding through
the control plane, and cluster ServeStats aggregation."""
import time

import numpy as np
import pytest

from conftest import reference_greedy, sample_prompts
from repro.core.interfaces import Request
from repro.runtime.fabric import FabricConfig, ServingFabric, build_fabric
from repro.runtime.metrics import aggregate_serve_stats
from repro.runtime.serving_loop import ServeStats

ARCH = "qwen1.5-0.5b"
PROMPT_PAD, MAX_GEN, SLOTS = 10, 6, 2


@pytest.fixture()
def fabric2():
    fab, cfg = build_fabric(ARCH, 2, n_slots=SLOTS,
                            prompt_len=PROMPT_PAD, gen_tokens=MAX_GEN,
                            paged=True, block_size=4)
    return fab, cfg


def _reqs(cfg, lens, gens, stream, **kw):
    prompts = sample_prompts(cfg, len(lens), lens)
    return [Request(request_id=i, stream_id=stream, arrival=0.0,
                    deadline=1e9, tokens=gens[i],
                    prompt=prompts[i], **kw)
            for i in range(len(lens))], prompts


def _drive(fab, reqs, *, fail_at_step=None, fail_rid=None,
           max_iters=3000):
    """Deterministic control loop (no wall-clock pacing in asserts):
    tick the controller + pump every replica until all requests
    complete, optionally killing one replica after N iterations."""
    for r in reqs:
        fab.submit(r)
    t0 = time.perf_counter()
    dead = None
    for it in range(max_iters):
        now = time.perf_counter() - t0
        if fail_at_step is not None and it == fail_at_step:
            dead = fab.fail_replica(fail_rid, now)
        fab.cluster.tick(now)
        busy = False
        for rep in list(fab.replicas.values()):
            busy = rep.pump_once(now) or busy
        if not busy and all(r.completed_at is not None for r in reqs):
            return dead
        if not busy:
            time.sleep(0.002)   # wait out subflow pacing, don't spin
    raise AssertionError(
        f"fabric did not drain: "
        f"{sum(r.completed_at is None for r in reqs)} incomplete")


def test_two_replicas_serve_identically_to_reference(fabric2):
    fab, cfg = fabric2
    lens = [6, 9, 4, 8, 7, 5]
    gens = [4, 2, 5, 3, 4, 2]
    reqs, prompts = _reqs(cfg, lens, gens, cfg.name)
    _drive(fab, reqs)
    rep = next(iter(fab.replicas.values()))
    model, params, lora = rep.engine.model, rep.params, rep.lora
    served = {rid: s["finished"] for rid, s in
              aggregate_serve_stats(
                  {r: h.batcher.stats
                   for r, h in fab.replicas.items()})["replicas"].items()}
    assert sum(served.values()) == len(reqs)
    # the pool actually spread the work (placement, not one hot replica)
    assert all(v > 0 for v in served.values()), served
    for i, r in enumerate(reqs):
        ref = reference_greedy(model, params, lora, prompts[i], gens[i])
        assert r.output_tokens == ref, f"req {i} diverged on the fabric"


def test_failover_requeues_to_survivor(fabric2):
    fab, cfg = fabric2
    lens = [6, 8, 5, 7, 6, 9, 4, 8]
    gens = [5, 4, 5, 3, 4, 5, 6, 3]
    reqs, prompts = _reqs(cfg, lens, gens, cfg.name)
    # kill r1 after a few ticks: some requests are mid-decode there
    dead = _drive(fab, reqs, fail_at_step=4, fail_rid="r1")
    assert dead is not None and "r1" not in fab.replicas
    # 100% completion on the survivor, with full token budgets
    assert all(r.completed_at is not None for r in reqs)
    assert all(len(r.output_tokens) == gens[i]
               for i, r in enumerate(reqs))
    # greedy accounting identical to the reference despite the requeue
    rep = fab.replicas["r0"]
    for i, r in enumerate(reqs):
        ref = reference_greedy(rep.engine.model, rep.params, rep.lora,
                               prompts[i], gens[i])
        assert r.output_tokens == ref, f"req {i} diverged after failover"
    # the dead replica's pool is fully freed: no leaked blocks or
    # reservations, every slot evicted
    alloc = dead.batcher.allocator
    assert alloc.n_used == 0 and alloc.reserved == 0
    assert dead.batcher.active_slots() == []
    assert dead.queue_length(1e9) == 0
    # cluster accounting is coherent: every request finished exactly
    # once — on r1 before the kill, or on the survivor after requeue
    stats = aggregate_serve_stats({rid: h.batcher.stats for rid, h in
                                   list(fab.replicas.items())
                                   + [("r1", dead)]})
    assert stats["cluster"]["finished"] == len(reqs)


def test_fabric_sampled_decoding_deterministic(fabric2):
    """Sampling params thread through Request -> GenRequest -> decode
    tick; a fixed per-request seed reproduces the same tokens."""
    fab, cfg = fabric2
    lens = [6, 7, 5, 8]
    gens = [4, 4, 4, 4]
    reqs, prompts = _reqs(cfg, lens, gens, cfg.name,
                          temperature=1.2, top_k=8, seed=123)
    for i, r in enumerate(reqs):
        r.seed = 100 + i
    _drive(fab, reqs)
    fab2, _ = build_fabric(ARCH, 2, n_slots=SLOTS,
                           prompt_len=PROMPT_PAD, gen_tokens=MAX_GEN,
                           paged=True, block_size=4)
    reqs2 = [Request(request_id=i, stream_id=cfg.name, arrival=0.0,
                     deadline=1e9, tokens=gens[i], prompt=prompts[i],
                     temperature=1.2, top_k=8, seed=100 + i)
             for i in range(len(lens))]
    _drive(fab2, reqs2)
    for a, b in zip(reqs, reqs2):
        assert a.output_tokens == b.output_tokens
        assert len(a.output_tokens) == a.tokens


def test_two_timescale_loop_over_live_replicas():
    """The macro timescale runs over LIVE replicas: the launcher opens
    an FL session across idle live replicas, each runs REAL fused train
    rounds through its batcher, the coordinator aggregates + replans
    per-replica train/infer splits, and the dispatcher's macro cycle
    consumes the plan for COMBINED pacing — while serving requests
    still complete."""
    from repro.core.states import ReplicaState

    fab, cfg = build_fabric(
        ARCH, 3, n_slots=SLOTS, prompt_len=PROMPT_PAD,
        gen_tokens=MAX_GEN,
        cfg=FabricConfig(enable_finetuning=True))
    coord_cfg = fab.cluster.cfg.launcher.coordinator
    coord_cfg.bootstrap_steps = 2
    coord_cfg.steps_per_round = 2
    fab.cluster.cfg.launcher.decision_interval = 0.05
    for rid in list(fab.replicas):
        fab.cluster.states.transition(rid, ReplicaState.IDLE, 0.0)
    lens = [6, 7, 5, 8]
    gens = [3, 3, 3, 3]
    reqs, _ = _reqs(cfg, lens, gens, cfg.name)
    for r in reqs:
        fab.submit(r)
    t0 = time.perf_counter()
    launcher = fab.cluster.launcher
    for _ in range(1500):
        now = time.perf_counter() - t0
        fab.cluster.tick(now)
        for rep in list(fab.replicas.values()):
            rep.pump_once(now)
        if launcher.completed_rounds >= 1 \
                and all(r.completed_at is not None for r in reqs):
            break
        time.sleep(0.002)
    assert launcher.completed_rounds >= 1, "no live FL round completed"
    # real fused/plain train steps ran on the live batchers
    assert sum(rep.batcher.stats.train_steps
               for rep in fab.replicas.values()) >= 6   # 2 steps x 3
    assert fab.cluster.launcher.adapter_versions.get(cfg.name, 0) >= 1
    # the coordinator exports a per-replica plan the dispatcher's macro
    # cycle consumes for COMBINED replicas
    d = fab.cluster.dispatcher_for(cfg.name)
    combined = [rid for rid in fab.replicas
                if fab.cluster.states.state_of(rid)
                is ReplicaState.COMBINED]
    for rid in combined:
        plan = fab.cluster._combined_plan(rid)
        assert plan is not None
        b_star, bivar = plan
        assert b_star >= 1
    # serving survived the co-running fine-tuning
    assert all(r.completed_at is not None for r in reqs)
    assert all(len(r.output_tokens) == gens[i]
               for i, r in enumerate(reqs))


def test_aggregate_serve_stats_totals():
    a = ServeStats(admitted=5, finished=5, prefill_tokens=40,
                   cached_prefix_tokens=8, generated_tokens=50,
                   decode_steps=12, train_steps=2, wall_time=2.0)
    b = ServeStats(admitted=3, finished=3, prefill_tokens=30,
                   cached_prefix_tokens=0, generated_tokens=30,
                   decode_steps=10, train_steps=0, wall_time=1.0)
    out = aggregate_serve_stats({"r0": a, "r1": b})
    c = out["cluster"]
    assert c["n_replicas"] == 2
    assert c["generated_tokens"] == 80
    assert c["prefill_tokens"] == 70
    assert c["cached_prefix_tokens"] == 8
    assert c["decode_steps"] == 22 and c["train_steps"] == 2
    assert c["wall_time_busy"] == pytest.approx(3.0)
    assert c["wall_time_max"] == pytest.approx(2.0)
    assert c["throughput_sum_tok_s"] == pytest.approx(
        50 / 2.0 + 30 / 1.0)
    # shared-device rate divides by SUMMED busy time (time-sliced device)
    assert c["throughput_wall_tok_s"] == pytest.approx(80 / 3.0)
    assert out["replicas"]["r0"]["throughput_tok_s"] \
        == pytest.approx(25.0)
