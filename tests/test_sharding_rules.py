"""Sharding-rule machinery: logical-axis resolution, divisibility
filtering, duplicate-axis dedup, per-arch coverage."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import (
    batch_shardings, logical_axes_for, make_mesh_compat, param_shardings,
    rules_for,
)
from repro.models.model import build
from repro.models.sharding import (
    RULES_TP_FSDP, ShardingRules, _filter_spec, sharding_context, shard,
)


def _mesh11():
    return make_mesh_compat((1, 1), ("data", "model"))


def test_filter_spec_drops_nondivisible():
    mesh = make_mesh_compat((1, 1), ("data", "model"))
    # craft a fake mesh shape dict via a real mesh of size 1 but checking
    # logic with the mesh axis sizes it reports
    spec = _filter_spec(P("model", "data"), mesh, (25, 16))
    # axes of size 1 always divide; just sanity-check structure
    assert len(spec) == 2


def test_logical_axes_for_paths():
    cfg = get_config("llama3-8b")
    assert logical_axes_for("blocks/attn/wq", 3, cfg) == \
        (None, "w_embed", "heads")
    assert logical_axes_for("blocks/mlp/wd", 3, cfg) == \
        (None, "ff", "w_embed")
    assert logical_axes_for("embed", 2, cfg) == ("vocab", "w_embed")
    assert logical_axes_for("blocks/q/a", 3, cfg) == (None, None, None)


def test_vlm_paths_two_leading():
    cfg = get_config("llama-3.2-vision-90b")
    assert logical_axes_for("blocks/attn/wq", 4, cfg) == \
        (None, None, "w_embed", "heads")
    assert logical_axes_for("cross/attn/wq", 3, cfg) == \
        (None, "w_embed", "heads")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_shardings_cover_every_leaf(arch):
    """Every param/adapter leaf must resolve to a valid NamedSharding on
    the (1,1) stand-in mesh — guards the path-table against drift."""
    cfg = get_config(arch).scaled()
    mesh = _mesh11()
    rules = rules_for(cfg, mesh, "train")
    model = build(cfg)
    specs = model.param_specs()
    sh = param_shardings(specs, cfg, mesh, rules)
    assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(specs))
    lsh = param_shardings(model.lora_specs(), cfg, mesh, rules)
    assert all(s is not None for s in jax.tree.leaves(lsh))


def test_rules_for_head_fallback():
    mesh16 = make_mesh_compat((1, 1), ("data", "model"))
    # qwen3 has 40 heads: on a 16-way model axis they don't divide —
    # emulate by checking the rule function's branch directly

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    r = rules_for(get_config("qwen3-14b"), FakeMesh(), "train")
    assert r.heads is None and r.q_seq == "model"
    r2 = rules_for(get_config("llama3-8b"), FakeMesh(), "train")
    assert r2.heads == "model" and r2.kv_seq == "model"  # kv=8 < 16
    r3 = rules_for(get_config("moonshot-v1-16b-a3b"), FakeMesh(), "train")
    assert r3.experts == "model"
    r4 = rules_for(get_config("grok-1-314b"), FakeMesh(), "train")
    assert r4.experts is None and r4.expert_ff == "model"


def test_shard_noop_without_context():
    x = jnp.ones((4, 4))
    assert shard(x, "batch", "embed") is x


def test_shard_constraint_under_context():
    mesh = _mesh11()
    with sharding_context(mesh, RULES_TP_FSDP):
        y = shard(jnp.ones((4, 4)), "batch", "embed")
        assert y.shape == (4, 4)
