"""reprosan mutation tests: deliberately break each hand-maintained
runtime invariant and assert the shadow sanitizers catch it with a
precise diagnostic — plus a clean end-to-end sanitized run proving the
instrumentation reports nothing on the real runtime.

Factories read ``REPRO_SANITIZE`` once at construction, so every test
arms the env var BEFORE building its objects."""
import types

import jax
import numpy as np
import pytest

from conftest import sample_prompts as _prompts
from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.core.interfaces import Request
from repro.runtime import sanitize
from repro.runtime.fault import RetryPolicy
from repro.runtime.sanitize import (
    AdapterSanitizer, RequestLifecycle, SanitizeError,
)
from repro.runtime.serving_loop import (
    AdapterRegistry, ContinuousBatcher, GenRequest,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen1.5-0.5b").scaled()
    engine = make_engine(cfg, lr=3e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = jax.tree.map(lambda x: x + 0.01,
                        model.init_lora(jax.random.key(1)))
    return cfg, engine, model, params, lora


@pytest.fixture
def armed(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize.enabled()


def _batcher(engine, params, lora, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 24)
    kw.setdefault("prompt_pad", 8)
    return ContinuousBatcher(engine, params, lora, paged=True,
                             block_size=4, **kw)


# ------------------------------------------------------ block sanitizer ----
def test_use_after_free_gather_detected(setup, armed):
    """Freeing a slot's blocks behind the batcher's back (the classic
    lifetime bug) must fail the NEXT decode wave, not corrupt KV."""
    cfg, engine, model, params, lora = setup
    b = _batcher(engine, params, lora)
    b.submit(GenRequest(request_id=0, prompt=_prompts(cfg, 1, [6])[0],
                        max_new_tokens=8))
    b.step()                                  # admit + first decode tick
    victim = b.active_slots()[0]
    b.allocator.free(list(b.slot_blocks[victim]))   # the mutation
    with pytest.raises(SanitizeError, match="use-after-free-gather"):
        b.step()


def test_skipped_cow_shared_write_detected(setup, armed):
    """A write targeting a refcount>1 prefix block means copy-on-write
    was skipped — sharers would observe torn KV."""
    cfg, engine, model, params, lora = setup
    b = _batcher(engine, params, lora, prefix_cache=True)
    common = _prompts(cfg, 1, [8])[0]         # two full 4-token blocks
    r0 = GenRequest(request_id=0, prompt=common.copy(), max_new_tokens=6)
    b.run([r0])                               # registers the prefix
    r1 = GenRequest(request_id=1, prompt=common.copy(), max_new_tokens=6)
    r2 = GenRequest(request_id=2, prompt=common.copy(), max_new_tokens=6)
    b.submit(r1)
    b.submit(r2)
    b.step()                                  # both share prefix blocks
    a0 = b.active_slots()[0]
    shared = [k for k, bk in enumerate(b.slot_blocks[a0])
              if b.allocator.ref(bk) > 1]
    assert shared, "fixture bug: no shared prefix block materialized"
    # the mutation: skip the COW pre-pass (prefix_cache gates it) and
    # point the slot's write cursor into the still-shared block
    b.prefix_cache = None
    b.slot_pos[a0] = shared[0] * b.block_size
    with pytest.raises(SanitizeError, match="shared-write"):
        b.step()


def test_reservation_leak_detected(setup, armed):
    """Reserved headroom no slot accounts for is a leak that slowly
    starves admission."""
    cfg, engine, model, params, lora = setup
    b = _batcher(engine, params, lora)
    b.submit(GenRequest(request_id=0, prompt=_prompts(cfg, 1, [6])[0],
                        max_new_tokens=8))
    b.step()
    b.allocator.reserve(2)                    # the mutation
    with pytest.raises(SanitizeError, match="reservation-leak"):
        b.step()


def test_refcount_drift_detected(setup, armed):
    """The mirror cross-check pinpoints accounting bugs INSIDE the
    allocator: a refcount bumped without going through a hook."""
    cfg, engine, model, params, lora = setup
    b = _batcher(engine, params, lora)
    b.submit(GenRequest(request_id=0, prompt=_prompts(cfg, 1, [6])[0],
                        max_new_tokens=8))
    b.step()
    blk = b.slot_blocks[b.active_slots()[0]][0]
    b.allocator._ref[blk] += 1                # the mutation: silent bump
    with pytest.raises(SanitizeError, match="refcount-drift"):
        b.step()


# ---------------------------------------------------- adapter sanitizer ----
def _tenant_registry(model, n, capacity, armed_env=True):
    from repro.runtime.fabric import make_tenant_adapters
    reg = AdapterRegistry(model, capacity=capacity)
    for t, tree in enumerate(make_tenant_adapters(model, n, seed=1)):
        reg.register(f"tenant{t}", tree, version=1)
    return reg


def test_adapter_evict_with_live_refs_detected(setup, armed):
    """A pinned tenant leaking into the LRU cold list (a lost-refcount
    bug) must be caught at eviction, before its slot is reused."""
    cfg, engine, model, params, lora = setup
    reg = _tenant_registry(model, 2, capacity=1)
    reg.acquire("tenant0")                    # pinned: 1 live ref
    reg._lru["tenant0"] = reg._slot["tenant0"]   # the mutation
    with pytest.raises(SanitizeError, match="evict-live-refs"):
        reg.acquire("tenant1")                # needs the slot -> evicts


def test_adapter_version_regression_detected(setup, armed):
    """Publishing an older version after a newer one was served rolls
    tenants back silently — the sanitizer makes it loud."""
    cfg, engine, model, params, lora = setup
    reg = _tenant_registry(model, 1, capacity=1)
    tree = reg.host_tree("tenant0")
    reg.update("tenant0", tree, version=5)
    with pytest.raises(SanitizeError, match="version-regression"):
        reg.update("tenant0", tree, version=3)


def test_adapter_mid_publish_read_detected(setup, armed):
    """A decode wave reading a slot whose in-place publish is still in
    flight would see torn weights."""
    cfg, engine, model, params, lora = setup
    reg = _tenant_registry(model, 1, capacity=1)
    reg.acquire("tenant0")
    san = AdapterSanitizer()
    san.on_acquire("tenant0")
    san.begin_publish("tenant0", 2)           # publish never completed
    fake = types.SimpleNamespace(adapters=reg, slot_aid=["tenant0"])
    with pytest.raises(SanitizeError, match="mid-publish-read"):
        san.check_decode_wave(fake, [0])


def test_adapter_release_without_acquire_detected(setup, armed):
    cfg, engine, model, params, lora = setup
    san = AdapterSanitizer()
    with pytest.raises(SanitizeError, match="release-without-acquire"):
        san.on_release("tenant0")


# --------------------------------------------------- lifecycle sanitizer ---
def test_terminal_replay_detected(setup, armed):
    """Resubmitting a FINISHED request must fail at submit — its tokens
    would be regenerated and double-counted."""
    cfg, engine, model, params, lora = setup
    b = _batcher(engine, params, lora)
    req = GenRequest(request_id=0, prompt=_prompts(cfg, 1, [6])[0],
                     max_new_tokens=3)
    b.run([req])
    assert req.done
    with pytest.raises(SanitizeError, match="terminal-replay"):
        b.submit(req)


def test_evicted_slot_decoding_detected():
    """A decode wave advancing a slot whose request is not ACTIVE means
    the runtime generates tokens into freed state."""
    lsan = RequestLifecycle()
    req = GenRequest(request_id=7, prompt=np.zeros(4, np.int32))
    lsan.on_submit(req)
    lsan.on_admit(req)
    lsan.on_finish(req)                       # slot was evicted...
    fake = types.SimpleNamespace(slot_req=[req])   # ...but still decodes
    with pytest.raises(SanitizeError, match="evicted-decoding"):
        lsan.check_decode_wave(fake, [0])


def test_terminal_request_retried_detected(armed):
    """A served Request handed back to RetryPolicy.on_requeue is a
    control-plane lifecycle bug (the SLO clock must never restart)."""
    pol = RetryPolicy()
    req = Request(request_id=0, stream_id="s", arrival=0.0, deadline=9.0)
    req.completed_at = 1.0                    # terminal: already served
    with pytest.raises(SanitizeError, match="terminal-retried"):
        pol.on_requeue(req, now=2.0, replica_died=True)


# --------------------------------------------------------- clean run -------
def test_clean_sanitized_run_reports_nothing(setup, armed):
    """The full paged + prefix-cache + multi-tenant serving path runs
    under REPRO_SANITIZE=1 with zero reports — the sanitizers flag only
    injected mutations, never the real runtime."""
    cfg, engine, model, params, lora = setup
    baseline = len(sanitize.reports())
    reg = _tenant_registry(model, 2, capacity=2)
    b = _batcher(engine, params, lora, n_slots=2, prefix_cache=True,
                 adapters=reg)
    prompts = _prompts(cfg, 4, [6, 6, 7, 5])
    reqs = [GenRequest(request_id=i, prompt=p, max_new_tokens=4,
                       adapter_id=f"tenant{i % 2}")
            for i, p in enumerate(prompts)]
    b.run(reqs)
    assert all(r.done for r in reqs)
    assert len(sanitize.reports()) == baseline
