"""Checkpoint/restore: roundtrip, async writer, GC, resharding."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.launch.mesh import make_mesh_compat


def _tree():
    return {"w": jnp.arange(24.0).reshape(4, 6),
            "opt": {"m": jnp.ones((3,), jnp.float32),
                    "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(5, tree, extra={"loss": 1.25})
    restored, extra = ck.restore(jax.eval_shape(lambda: tree))
    assert extra["loss"] == 1.25
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_writer_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in [1, 2, 3, 4]:
        ck.save(s, _tree())
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=5)
    tree = _tree()
    ck.save(1, tree)
    ck.save(2, jax.tree.map(lambda x: x * 2, tree))
    ck.wait()
    r1, _ = ck.restore(jax.eval_shape(lambda: tree), step=1)
    r2, _ = ck.restore(jax.eval_shape(lambda: tree), step=2)
    assert float(r2["w"][0, 1]) == 2 * float(r1["w"][0, 1])


def test_incomplete_checkpoint_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    ck.wait()
    # simulate a crash mid-write: directory without the _COMPLETE flag
    os.makedirs(tmp_path / "step_0000000099")
    assert ck.all_steps() == [1]
    assert ck.latest_step() == 1


def test_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())
    ck.wait()
    bad = {"w": jnp.zeros((2, 2)), "opt": {"m": jnp.ones((3,)),
                                           "step": jnp.int32(0)}}
    with pytest.raises(ValueError):
        ck.restore(jax.eval_shape(lambda: bad))


def test_elastic_restore_mesh_change(tmp_path):
    """Restore under a different mesh/shardings (elastic restart)."""
    from jax.sharding import PartitionSpec as P
    from repro.runtime.elastic import elastic_restore
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, tree)
    ck.wait()
    mesh = make_mesh_compat((1,), ("data",))
    restored, _ = elastic_restore(ck, jax.eval_shape(lambda: tree), mesh,
                                  lambda key, leaf: P())
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
