import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import all_configs

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS in-process before importing jax) — nothing to do here, just
# never set xla_force_host_platform_device_count globally.


@pytest.fixture(scope="session")
def smoke_configs():
    return {name: cfg.scaled() for name, cfg in all_configs().items()}


def make_batch(cfg, batch=2, seq=32, seed=0):
    key = jax.random.key(seed)
    out = {
        "labels": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
        "mask": jnp.ones((batch, seq), jnp.float32),
    }
    if cfg.encoder_only:
        out["embeds"] = jax.random.normal(key, (batch, seq, cfg.d_model),
                                          jnp.float32)
    else:
        out["tokens"] = jax.random.randint(key, (batch, seq), 0,
                                           cfg.vocab_size)
    if cfg.family.value == "vlm":
        out["vision"] = jax.random.normal(
            key, (batch, cfg.vision_tokens, cfg.d_model), jnp.float32)
    return out
