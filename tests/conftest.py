import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import all_configs

# smoke tests and benches must see ONE device (the dry-run sets its own
# XLA_FLAGS in-process before importing jax) — nothing to do here, just
# never set xla_force_host_platform_device_count globally.


@pytest.fixture(scope="session")
def smoke_configs():
    return {name: cfg.scaled() for name, cfg in all_configs().items()}


def make_batch(cfg, batch=2, seq=32, seed=0):
    key = jax.random.key(seed)
    out = {
        "labels": jax.random.randint(key, (batch, seq), 0, cfg.vocab_size),
        "mask": jnp.ones((batch, seq), jnp.float32),
    }
    if cfg.encoder_only:
        out["embeds"] = jax.random.normal(key, (batch, seq, cfg.d_model),
                                          jnp.float32)
    else:
        out["tokens"] = jax.random.randint(key, (batch, seq), 0,
                                           cfg.vocab_size)
    if cfg.family.value == "vlm":
        out["vision"] = jax.random.normal(
            key, (batch, cfg.vision_tokens, cfg.d_model), jnp.float32)
    return out


# ---------------------------------------------------- serving helpers ------
def sample_prompts(cfg, n, lens, seed=3):
    """Synthetic ragged prompts shared by the serving/paging suites."""
    from repro.data.synthetic import SyntheticDataset
    data = SyntheticDataset("alpaca", vocab_size=cfg.vocab_size,
                            seq_len=max(lens), seed=seed)
    toks = data.sample_tokens(n)
    return [toks[i, :lens[i]].astype(np.int32) for i in range(n)]


def reference_greedy(model, params, lora, prompt, n_new):
    """Single-sequence prefill + decode: the unambiguous ground-truth
    oracle the batched serving runtimes are equivalence-tested against."""
    logits, caches = model.prefill(params, lora,
                                   {"tokens": jnp.asarray(prompt[None])})
    pool = model.init_caches(1, len(prompt) + n_new)
    pool = model.write_prefill_slot(pool, caches, 0)
    out = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    while len(out) < n_new:
        logits, pool = model.decode_step(
            params, lora, pool, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return out
