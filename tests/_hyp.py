"""Optional-hypothesis shim: property tests run under real hypothesis
when it is installed, and under a lightweight deterministic random
sampler otherwise (so `pytest` collects and exercises them either way —
the seed's hard `from hypothesis import ...` lines broke collection of
six modules on minimal installs).

Usage in tests:  ``from _hyp import given, settings, st``

The fallback implements just the strategy surface this repo uses
(integers / floats / booleans / sampled_from / lists / tuples) as
draw-callables over one seeded numpy Generator; ``@given`` replays
``max_examples`` random draws (default 20).  It does NOT shrink or
persist failing examples — it is a coverage fallback, not a hypothesis
replacement.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import zlib

    import numpy as _np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda r: int(r.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, allow_nan=False,
                   allow_infinity=False, **_kw):
            return _Strategy(
                lambda r: float(r.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda r: elements[int(r.integers(0, len(elements)))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            return _Strategy(lambda r: [
                elements.draw(r)
                for _ in range(int(r.integers(min_size, max_size + 1)))])

        @staticmethod
        def tuples(*strategies):
            return _Strategy(
                lambda r: tuple(s.draw(r) for s in strategies))

    st = _Strategies()

    def settings(max_examples=20, **_kw):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # NOT functools.wraps: pytest would see the wrapped
            # signature and demand fixtures for the drawn params —
            # like hypothesis, expose a zero-arg test function
            def run():
                n = getattr(run, "_hyp_max_examples",
                            getattr(fn, "_hyp_max_examples", 20))
                # crc32, not hash(): str hashing is salted per process,
                # and a failing draw must reproduce on rerun
                rng = _np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    fn(*(s.draw(rng) for s in strategies))
            run.__name__ = fn.__name__
            run.__qualname__ = fn.__qualname__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run
        return deco
