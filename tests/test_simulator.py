"""Discrete-event simulator + SimReplica mechanics."""
import pytest

from repro.core.interfaces import Request
from repro.runtime.replica import InterferenceSurface, SimReplica
from repro.runtime.simulator import Simulator


def test_event_ordering_deterministic():
    sim = Simulator()
    log = []
    sim.schedule(2.0, lambda t: log.append(("b", t)))
    sim.schedule(1.0, lambda t: log.append(("a", t)))
    sim.schedule(1.0, lambda t: log.append(("a2", t)))  # FIFO at same time
    sim.run(10.0)
    assert [x[0] for x in log] == ["a", "a2", "b"]
    assert sim.now == 10.0


def test_schedule_every_respects_until():
    sim = Simulator()
    hits = []
    sim.schedule_every(1.0, hits.append, until=3.5)
    sim.run(10.0)
    assert hits == [0.0, 1.0, 2.0, 3.0]


def _mk_replica(sim, results):
    return SimReplica("r0", "m", sim,
                      lambda res, sid: results.append(res),
                      InterferenceSurface(noise_frac=0.0), seed=1)


def test_replica_serializes_batches():
    """Eq. 13d: one batch at a time; later batch waits."""
    sim = Simulator()
    results = []
    r = _mk_replica(sim, results)
    reqs1 = [Request(i, "m", 0.0, 1.0) for i in range(4)]
    reqs2 = [Request(i + 4, "m", 0.0, 1.0) for i in range(4)]
    r.submit_batch(reqs1, 0.0)
    r.submit_batch(reqs2, 0.0)
    sim.run(5.0)
    assert len(results) == 2
    lat = 0.02 * 4 + 0.05
    assert results[0].finished_at == pytest.approx(lat, rel=1e-6)
    assert results[1].finished_at == pytest.approx(2 * lat, rel=1e-6)
    assert results[1].queue_latency == pytest.approx(lat, rel=1e-6)


def test_interference_slows_inference():
    sim = Simulator()
    results = []
    r = _mk_replica(sim, results)
    r.train_round(train_batch=16, infer_batch=4, steps=100, now=0.0)
    r.submit_batch([Request(0, "m", 0.0, 1.0)], 0.0)
    sim.run(5.0)
    base = 0.02 * 1 + 0.05
    assert results[0].infer_latency == pytest.approx(
        base + 0.008 * 16, rel=1e-6)
    assert results[0].train_batch == 16


def test_utilization_window():
    sim = Simulator()
    results = []
    r = _mk_replica(sim, results)
    for k in range(20):
        sim.schedule(k * 0.5, lambda t, rr=r: rr.submit_batch(
            [Request(int(t * 10), "m", t, t + 1.0)], t))
    sim.run(10.0)
    u = r.utilization(10.0)
    # each 1-request batch takes 0.07s every 0.5s => ~14% busy
    assert 0.05 < u < 0.30


def test_failure_drops_requests():
    sim = Simulator()
    results = []
    r = _mk_replica(sim, results)
    r.fail(0.0)
    r.submit_batch([Request(0, "m", 0.0, 1.0)], 0.0)
    sim.run(1.0)
    assert results == []
    r.recover(1.0)
    r.submit_batch([Request(1, "m", 1.0, 2.0)], 1.0)
    sim.run(3.0)
    assert len(results) == 1


def test_loss_curve_monotone():
    from repro.runtime.replica import LossCurve
    c = LossCurve()
    l0 = c.loss()
    c.advance(5000)
    assert c.loss() < l0
    assert c.loss() >= c.floor


def test_loss_curve_fractional_progress_monotone():
    """Regression: ``seen`` accumulates fractional ``samples * eff``
    (statistical efficiency < 1), so it is a float — and loss must stay
    monotone non-increasing in samples under batch-size scaling."""
    from repro.runtime.replica import LossCurve
    c = LossCurve()
    assert isinstance(c.seen, float)
    prev = c.loss()
    for _ in range(50):
        before, after = c.advance(2, batch_size=64)
        assert before == pytest.approx(prev)
        assert after <= before
        prev = after
    # large batches well past the noise scale => eff << 1: progress is
    # fractional, not floor-to-int
    assert 0.0 < c.seen < 50 * 2
    assert c.seen != int(c.seen)
