"""Chunked prefill (token-level co-scheduling): resumable-continuation
prefill must be BIT-IDENTICAL to monolithic prefill on greedy decode —
chunk K attends over the K/V chunks 1..K-1 wrote, through the same
``attention_prefix_suffix`` math the decode path uses — across the full
attention contiguous path, sliding-window attention, the paged pool,
and the paged prefix-cache suffix path.  Plus the lifecycle edges: a
mid-prefill eviction frees every pool block and reservation (checked
under the armed sanitizers), the SSM gate refuses chunking outright,
and the per-tick budget planner's pricing buckets."""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import sample_prompts as _prompts
from repro.configs.registry import get_config
from repro.core.engine import make_engine
from repro.runtime.serving_loop import (
    ContinuousBatcher, GenRequest, _TickBudget,
)


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen1.5-0.5b").scaled()
    engine = make_engine(cfg, lr=3e-3)
    model = engine.model
    params = model.init(jax.random.key(0))
    lora = jax.tree.map(lambda x: x + 0.01,
                        model.init_lora(jax.random.key(1)))
    return cfg, engine, model, params, lora


def _reqs(prompts, gen=6):
    return [GenRequest(request_id=i, prompt=p.copy(), max_new_tokens=gen)
            for i, p in enumerate(prompts)]


def _tokens(engine, params, lora, prompts, chunk, **kw):
    reqs = _reqs(prompts)
    ContinuousBatcher(engine, params, lora, prefill_chunk=chunk,
                      **kw).run(reqs)
    return [list(r.tokens) for r in reqs]


# ------------------------------------------------ greedy bit-identity -----
def test_chunked_matches_monolithic_contiguous(setup):
    cfg, engine, model, params, lora = setup
    prompts = _prompts(cfg, 6, [7, 24, 13, 24, 6, 19])
    kw = dict(n_slots=3, max_seq=32, prompt_pad=24)
    mono = _tokens(engine, params, lora, prompts, 0, **kw)
    for chunk in (8, 10):       # chunk dividing AND straddling prompts
        assert _tokens(engine, params, lora, prompts, chunk,
                       **kw) == mono


def test_chunked_matches_monolithic_sliding_window(setup):
    cfg, engine, model, params, lora = setup
    wcfg = dataclasses.replace(cfg, sliding_window=16)
    wengine = make_engine(wcfg, lr=3e-3)
    wparams = wengine.model.init(jax.random.key(0))
    wlora = jax.tree.map(lambda x: x + 0.01,
                         wengine.model.init_lora(jax.random.key(1)))
    prompts = _prompts(wcfg, 5, [5, 16, 9, 16, 12])
    kw = dict(n_slots=3, max_seq=24, prompt_pad=16)
    mono = _tokens(wengine, wparams, wlora, prompts, 0, **kw)
    assert _tokens(wengine, wparams, wlora, prompts, 6, **kw) == mono


def test_chunked_matches_monolithic_paged(setup):
    cfg, engine, model, params, lora = setup
    prompts = _prompts(cfg, 6, [7, 24, 13, 24, 6, 19])
    kw = dict(n_slots=3, max_seq=32, prompt_pad=24, paged=True,
              block_size=8)
    mono = _tokens(engine, params, lora, prompts, 0, **kw)
    # 8 = block-aligned; 12 exercises the ctor's round-up to 16
    for chunk in (8, 12):
        assert _tokens(engine, params, lora, prompts, chunk,
                       **kw) == mono


def test_chunked_matches_monolithic_prefix_cache(setup):
    cfg, engine, model, params, lora = setup
    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    prompts = []
    for i in range(6):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, 9))).astype(np.int32)
        prompts.append(np.concatenate([shared, tail]) if i % 2 == 0
                       else rng.integers(0, cfg.vocab_size,
                                         size=int(rng.integers(6, 25)))
                       .astype(np.int32))
    kw = dict(n_slots=3, max_seq=32, prompt_pad=24, paged=True,
              block_size=8, prefix_cache=True)
    mono_reqs = _reqs(prompts)
    b0 = ContinuousBatcher(engine, params, lora, prefill_chunk=0, **kw)
    s0 = b0.run(mono_reqs)
    ch_reqs = _reqs(prompts)
    b1 = ContinuousBatcher(engine, params, lora, prefill_chunk=8, **kw)
    s1 = b1.run(ch_reqs)
    assert [list(r.tokens) for r in ch_reqs] \
        == [list(r.tokens) for r in mono_reqs]
    # chunked admission matches the same cached prefixes (suffix path
    # continues FROM the matched blocks, it does not re-prefill them)
    assert s1.cached_prefix_tokens == s0.cached_prefix_tokens > 0


# ------------------------------------------------------ lifecycle edges ----
def test_mid_chunk_eviction_frees_everything(setup, monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cfg, engine, model, params, lora = setup
    prompts = _prompts(cfg, 2, [24, 24])
    b = ContinuousBatcher(engine, params, lora, n_slots=2, max_seq=32,
                          prompt_pad=24, paged=True, block_size=8,
                          prefill_chunk=8)
    for r in _reqs(prompts):
        b.submit(r)
    b.step()                    # one chunk in: slots parked mid-prefill
    assert b.prefilling_slots(), "expected mid-prefill slots"
    assert b.allocator.n_used > 0
    b.drain_all()               # teardown while prefill is incomplete
    assert b.allocator.n_used == 0
    assert b.allocator.reserved == 0
    assert not b.prefilling_slots()


def test_preempt_during_chunked_prefill_frees_everything(setup,
                                                         monkeypatch):
    """Oversubscribed pool: an urgent decoder crosses a block boundary
    while a slack late arrival is still mid-chunked-prefill — the
    prefilling victim MUST take the drop+re-prefill path (its partial
    KV is never swapped), every block and reservation it held returns
    to the pool, and both requests still finish bit-identically to the
    unconstrained run — all under armed sanitizers."""
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    cfg, engine, model, params, lora = setup
    prompts = _prompts(cfg, 2, [6, 28])

    def serve(nb, **kw):
        r0 = GenRequest(request_id=0, prompt=prompts[0].copy(),
                        max_new_tokens=24, deadline=1.0)
        r1 = GenRequest(request_id=1, prompt=prompts[1].copy(),
                        max_new_tokens=8)   # inf deadline = most slack
        b = ContinuousBatcher(engine, params, lora, n_slots=2,
                              max_seq=32, prompt_pad=28, paged=True,
                              block_size=4, prefill_chunk=4,
                              n_blocks=nb, **kw)
        b.submit(r0)
        b.step(); b.step()      # r0 decoding before r1 even arrives
        b.submit(r1)
        for _ in range(300):
            if b.idle():
                break
            b.step()
        return [list(r0.tokens), list(r1.tokens)], b

    ref, _ = serve(64)
    toks, b = serve(12, oversubscribe=1.0)
    assert toks == ref
    assert b.stats.preemptions > 0
    assert b.stats.reprefill_tokens > 0     # drop path, not swap:
    assert b.stats.swap_out_blocks == 0     # partial prefill KV is
    assert b.allocator.n_used == 0          # recomputed, never copied
    assert b.allocator.reserved == 0
    assert b.idle()


def test_ssm_arch_rejects_chunked_prefill():
    cfg = get_config("mamba2-780m").scaled()
    engine = make_engine(cfg, lr=3e-3)
    params = engine.model.init(jax.random.key(0))
    lora = engine.model.init_lora(jax.random.key(1))
    with pytest.raises(NotImplementedError, match="attention-only"):
        ContinuousBatcher(engine, params, lora, n_slots=2, max_seq=24,
                          prompt_pad=16, prefill_chunk=8)


def test_paged_chunk_rounds_up_to_block_multiple(setup):
    cfg, engine, model, params, lora = setup
    b = ContinuousBatcher(engine, params, lora, n_slots=2, max_seq=32,
                          prompt_pad=24, paged=True, block_size=8,
                          prefill_chunk=10)
    assert b.prefill_chunk == 16        # blocks stay aligned mid-prefill
    b2 = ContinuousBatcher(engine, params, lora, n_slots=2, max_seq=32,
                           prompt_pad=24, prefill_chunk=10)
    assert b2.prefill_chunk == 10       # contiguous: no alignment need


# -------------------------------------------------- budget planner units ---
def test_tick_budget_pricing():
    bud = _TickBudget(0.010)
    # unknown train cost: never probe on a tick carrying serving work
    assert bud.train_tokens(4, 16, 0.0) is None
    bud.observe_decode(0.004)
    assert bud.train_tokens(4, 16, 0.0) is None
    # measured cheap training fits full in the 6ms slack
    bud.observe_train(64, 0.0016)       # 25us/token
    assert bud.train_tokens(4, 16, 0.0) == 0
    # prefill spend eats the slack: full costs 1.6ms > 1.0ms left but
    # the 32-token half microbatch (0.8ms) still fits -> half
    assert bud.train_tokens(4, 16, 0.005) == 32
    # nothing left -> skip
    assert bud.train_tokens(4, 16, 0.0092) is None
    # prefill allowance: whole tick when nothing decodes, the residual
    # budget otherwise, zero when decode alone exceeds the target
    assert bud.prefill_allowance(0) == float("inf")
    bud.observe_prefill(32, 0.0032)     # 100us/token
    assert bud.prefill_allowance(2) == pytest.approx(60.0)
    bud.observe_decode(0.030)           # EMA jumps past the target
    assert bud.prefill_allowance(2) == 0.0


def test_budget_stats_and_latency_distributions(setup):
    cfg, engine, model, params, lora = setup
    prompts = _prompts(cfg, 4, [7, 24, 13, 18])
    reqs = _reqs(prompts)
    b = ContinuousBatcher(engine, params, lora, n_slots=2, max_seq=32,
                          prompt_pad=24, prefill_chunk=8,
                          tpot_target=0.004)
    stats = b.run(reqs)
    assert stats.finished == 4
    assert stats.budget_ticks > 0
    assert stats.budget_target_s == pytest.approx(
        0.004 * stats.budget_ticks)
    assert stats.budget_spent_s > 0
    assert len(stats.ttft) == 4 and all(t >= 0 for t in stats.ttft)
    assert len(stats.tpot) == 4 and all(t >= 0 for t in stats.tpot)
