"""Multi-stream dispatching: requests for different models (streams) are
routed to the right replica pools with independent subflow state —
'requests querying the same model and having the same SLO form a
stream' (paper §6.1)."""
import pytest

from repro.core.cluster import ClusterConfig, ClusterController
from repro.core.interfaces import Request
from repro.runtime.replica import SimReplica
from repro.runtime.simulator import Simulator


def test_streams_route_to_matching_model_pools():
    sim = Simulator()
    cluster = ClusterController(ClusterConfig())
    completions = {"m1": 0, "m2": 0}

    def on_result(res, sid):
        completions[sid.split("/")[0]] += res.batch_size
        cluster.on_batch_result(res, sid)

    for i in range(2):
        cluster.add_replica(SimReplica(f"a{i}", "m1", sim, on_result,
                                       seed=i))
        cluster.add_replica(SimReplica(f"b{i}", "m2", sim, on_result,
                                       seed=10 + i))

    rid = 0
    for t in range(50):
        now = t * 0.1
        for stream in ("m1", "m2"):
            cluster.submit_request(Request(rid, stream, now, now + 0.5))
            rid += 1
    sim.schedule_every(0.05, cluster.tick, until=8.0)
    sim.run(8.0)

    assert completions["m1"] > 0 and completions["m2"] > 0
    # stream isolation: each dispatcher only owns its model's replicas
    assert set(cluster.dispatchers["m1"].replicas) == {"a0", "a1"}
    assert set(cluster.dispatchers["m2"].replicas) == {"b0", "b1"}


def test_idle_pools_are_per_model():
    """FL cohorts must not mix models (§4.2: 'same model')."""
    from repro.core.states import ReplicaState
    sim = Simulator()
    cluster = ClusterController(ClusterConfig())
    for i in range(3):
        cluster.add_replica(SimReplica(f"a{i}", "m1", sim,
                                       lambda r, s: None, seed=i))
    for i in range(2):
        cluster.add_replica(SimReplica(f"b{i}", "m2", sim,
                                       lambda r, s: None, seed=i))
    for rid in list(cluster.replicas):
        cluster.states.transition(rid, ReplicaState.IDLE, 0.0)
    cluster.launcher.maybe_launch(1.0)
    models = {a.session.model_id: sorted(a.session.members)
              for a in cluster.launcher.sessions.values()}
    assert models == {"m1": ["a0", "a1", "a2"]}  # m2 below min_cohort=3
