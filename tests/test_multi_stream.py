"""Multi-stream dispatching: requests for different models (streams) are
routed to the right replica pools with independent subflow state —
'requests querying the same model and having the same SLO form a
stream' (paper §6.1)."""
import pytest

from repro.core.cluster import ClusterConfig, ClusterController
from repro.core.interfaces import Request
from repro.runtime.replica import SimReplica
from repro.runtime.simulator import Simulator


def test_streams_route_to_matching_model_pools():
    sim = Simulator()
    cluster = ClusterController(ClusterConfig())
    completions = {"m1": 0, "m2": 0}

    def on_result(res, sid):
        completions[sid.split("/")[0]] += res.batch_size
        cluster.on_batch_result(res, sid)

    for i in range(2):
        cluster.add_replica(SimReplica(f"a{i}", "m1", sim, on_result,
                                       seed=i))
        cluster.add_replica(SimReplica(f"b{i}", "m2", sim, on_result,
                                       seed=10 + i))

    rid = 0
    for t in range(50):
        now = t * 0.1
        for stream in ("m1", "m2"):
            cluster.submit_request(Request(rid, stream, now, now + 0.5))
            rid += 1
    sim.schedule_every(0.05, cluster.tick, until=8.0)
    sim.run(8.0)

    assert completions["m1"] > 0 and completions["m2"] > 0
    # stream isolation: each dispatcher only owns its model's replicas
    assert set(cluster.dispatchers["m1"].replicas) == {"a0", "a1"}
    assert set(cluster.dispatchers["m2"].replicas) == {"b0", "b1"}


def test_registry_add_after_dispatcher_exists_receives_traffic():
    """Regression: ``dispatcher_for`` used to hand each dispatcher a
    one-time dict snapshot of the registry, so a replica added AFTER the
    dispatcher existed never received traffic.  The replica view is live
    now: add-then-submit must route to the newcomer."""
    sim = Simulator()
    cluster = ClusterController(ClusterConfig())
    cluster.add_replica(SimReplica("a0", "m1", sim,
                                   cluster.on_batch_result, seed=0))
    d = cluster.dispatcher_for("m1")          # dispatcher exists first
    assert set(d.replicas) == {"a0"}
    late = SimReplica("a1", "m1", sim, cluster.on_batch_result, seed=1)
    cluster.add_replica(late)
    assert set(d.replicas) == {"a0", "a1"}    # live view, no snapshot
    for i in range(40):
        cluster.submit_request(Request(i, "m1", 0.0, 10.0))
    sim.schedule_every(0.05, cluster.tick, until=5.0)
    sim.run(5.0)
    assert late.served_requests > 0, \
        "late-added replica never received traffic (stale registry)"
    assert "a1" in d.subflows


def test_registry_remove_then_tick_stops_routing():
    """Removed replicas must leave every dispatcher structure — the old
    code only popped subflows/latency_models, so ``d.replicas`` kept a
    dead handle and kept routing to it."""
    sim = Simulator()
    cluster = ClusterController(ClusterConfig())
    reps = [SimReplica(f"a{i}", "m1", sim, cluster.on_batch_result,
                       seed=i) for i in range(2)]
    for r in reps:
        cluster.add_replica(r)
    d = cluster.dispatcher_for("m1")
    cluster.tick(0.0)                          # subflows exist for both
    cluster.remove_replica("a0", 0.1)
    assert set(d.replicas) == {"a1"}
    assert "a0" not in d.subflows and "a0" not in d.latency_models
    served_before = reps[0].served_requests
    for i in range(20):
        cluster.submit_request(Request(i, "m1", 0.2, 10.0))
    sim.schedule_every(0.05, cluster.tick, until=4.0)
    sim.run(4.0)
    assert reps[0].served_requests == served_before
    assert reps[1].served_requests > 0


def test_idle_pools_are_per_model():
    """FL cohorts must not mix models (§4.2: 'same model')."""
    from repro.core.states import ReplicaState
    sim = Simulator()
    cluster = ClusterController(ClusterConfig())
    for i in range(3):
        cluster.add_replica(SimReplica(f"a{i}", "m1", sim,
                                       lambda r, s: None, seed=i))
    for i in range(2):
        cluster.add_replica(SimReplica(f"b{i}", "m2", sim,
                                       lambda r, s: None, seed=i))
    for rid in list(cluster.replicas):
        cluster.states.transition(rid, ReplicaState.IDLE, 0.0)
    cluster.launcher.maybe_launch(1.0)
    models = {a.session.model_id: sorted(a.session.members)
              for a in cluster.launcher.sessions.values()}
    assert models == {"m1": ["a0", "a1", "a2"]}  # m2 below min_cohort=3
