"""Pallas kernel validation: interpret-mode execution vs the pure-jnp
oracles in kernels/ref.py, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import (
    decode_attention, paged_decode_attention,
)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.ssd_scan import ssd_scan


@pytest.mark.parametrize("m,k,n,r", [(128, 256, 128, 8), (256, 512, 384, 16),
                                     (128, 128, 128, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul(m, k, n, r, dtype):
    ks = jax.random.split(jax.random.key(0), 4)
    x = jax.random.normal(ks[0], (m, k), jnp.float32).astype(dtype)
    w = (jax.random.normal(ks[1], (k, n), jnp.float32) * 0.05).astype(dtype)
    a = (jax.random.normal(ks[2], (k, r), jnp.float32) * 0.05).astype(dtype)
    b = (jax.random.normal(ks[3], (r, n), jnp.float32) * 0.05).astype(dtype)
    y = lora_matmul(x, w, a, b, 2.0, bm=128, bn=128, bk=128, interpret=True)
    yr = ref.lora_matmul(x, w, a, b, 2.0)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,hkv,sq,skv,causal,window", [
    (2, 4, 2, 256, 256, True, 0),
    (1, 8, 2, 300, 300, True, 0),       # ragged / padded path
    (2, 4, 4, 128, 384, False, 0),      # cross-attention style
    (1, 4, 2, 512, 512, True, 128),     # sliding window
])
def test_flash_attention(b, h, hkv, sq, skv, causal, window):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, h, sq, 64), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, skv, 64), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, skv, 64), jnp.float32)
    y = flash_attention(q, k, v, causal=causal, window=window,
                        bq=128, bk=128, interpret=True)
    yr = ref.flash_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,h,hkv,s,d", [
    (2, 8, 2, 512, 64), (3, 4, 4, 300, 128), (1, 16, 2, 1024, 64)])
def test_decode_attention(b, h, hkv, s, d):
    ks = jax.random.split(jax.random.key(2), 4)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32)
    kl = jax.random.randint(ks[3], (b,), 1, s + 1)
    y = decode_attention(q, kc, vc, kl, bk=128, interpret=True)
    yr = ref.decode_attention(q, kc, vc, kl)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,h,hkv,nb_pool,bs,nb,d", [
    (2, 8, 2, 16, 16, 4, 64),       # GQA, short tables
    (3, 4, 4, 12, 8, 8, 128),       # MHA, longer walk
    (1, 16, 2, 32, 32, 6, 64),      # wide grouping
])
def test_paged_decode_attention(b, h, hkv, nb_pool, bs, nb, d):
    """Block-table walk over a shuffled pool == dense attention over
    the gathered logical cache."""
    ks = jax.random.split(jax.random.key(6), 4)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32)
    kp = jax.random.normal(ks[1], (nb_pool, bs, hkv, d), jnp.float32)
    vp = jax.random.normal(ks[2], (nb_pool, bs, hkv, d), jnp.float32)
    rng = np.random.default_rng(7)
    # distinct non-scratch blocks per sequence, shuffled pool order
    tables = np.stack([rng.permutation(np.arange(1, nb_pool))[:nb]
                       for _ in range(b)]).astype(np.int32)
    kl = jax.random.randint(ks[3], (b,), 1, nb * bs + 1)
    y = paged_decode_attention(q, kp, vp, jnp.asarray(tables), kl,
                               interpret=True)
    k_log = kp[tables].reshape(b, nb * bs, hkv, d).transpose(0, 2, 1, 3)
    v_log = vp[tables].reshape(b, nb * bs, hkv, d).transpose(0, 2, 1, 3)
    yr = ref.decode_attention(q, k_log, v_log, kl)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_contiguous_decode_dispatches_to_paged_kernel():
    """layers.attention_decode with the kernel forced on (identity
    block tables) must match its jnp path."""
    from repro.models.layers import attention_decode
    ks = jax.random.split(jax.random.key(8), 3)
    b, s, hq, hkv, d = 3, 48, 8, 2, 64
    q = jax.random.normal(ks[0], (b, 1, hq, d), jnp.float32)
    kc = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    vc = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    klen = jnp.asarray([1, 17, 48], jnp.int32)
    y_jnp = attention_decode(q, kc, vc, klen, backend="jnp")
    y_ker = attention_decode(q, kc, vc, klen, backend="interpret")
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_jnp),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,h,s,p,n,chunk", [
    (2, 4, 256, 32, 16, 64), (1, 2, 300, 64, 32, 128),
    (2, 3, 128, 16, 8, 32)])
def test_ssd_scan(b, h, s, p, n, chunk):
    ks = jax.random.split(jax.random.key(3), 5)
    x = jax.random.normal(ks[0], (b, h, s, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, h, s), jnp.float32))
    a = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n), jnp.float32) * 0.3
    cm = jax.random.normal(ks[4], (b, s, n), jnp.float32) * 0.3
    y, fin = ssd_scan(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    yr, finr = ref.ssd_scan(x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1),
                            a, bm, cm)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(yr.transpose(0, 2, 1, 3)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fin), np.asarray(finr),
                               rtol=1e-4, atol=1e-4)


def test_ops_dispatch_cpu_uses_ref():
    """On CPU without force_kernel, ops must route to the oracle."""
    from repro.kernels import ops
    ks = jax.random.split(jax.random.key(4), 4)
    x = jax.random.normal(ks[0], (4, 64, 32), jnp.float32)
    w = jax.random.normal(ks[1], (32, 48), jnp.float32)
    a = jax.random.normal(ks[2], (32, 8), jnp.float32)
    b = jax.random.normal(ks[3], (8, 48), jnp.float32)
    y = ops.lora_matmul(x, w, a, b, 1.5)
    yr = ref.lora_matmul(x.reshape(-1, 32), w, a, b, 1.5).reshape(4, 64, 48)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-6)


def test_ops_force_kernel_pads_odd_shapes():
    from repro.kernels import ops
    ks = jax.random.split(jax.random.key(5), 4)
    x = jax.random.normal(ks[0], (3, 50, 70), jnp.float32)
    w = jax.random.normal(ks[1], (70, 90), jnp.float32) * 0.1
    a = jax.random.normal(ks[2], (70, 4), jnp.float32) * 0.1
    b = jax.random.normal(ks[3], (4, 90), jnp.float32) * 0.1
    y = ops.lora_matmul(x, w, a, b, 1.0, force_kernel=True, block=64)
    yr = ref.lora_matmul(x.reshape(-1, 70), w, a, b, 1.0).reshape(3, 50, 90)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)
