"""Fault tolerance: detection, elastic pool membership, stragglers."""
import pytest

from repro.core.cluster import ClusterConfig, ClusterController
from repro.core.interfaces import BatchResult
from repro.runtime.elastic import ElasticServingPool
from repro.runtime.fault import FailureDetector, StragglerWatch
from repro.runtime.replica import InterferenceSurface, SimReplica
from repro.runtime.simulator import Simulator


def _cluster(n=4):
    sim = Simulator()
    cluster = ClusterController(ClusterConfig())
    results = []
    for i in range(n):
        r = SimReplica(f"r{i}", "m", sim,
                       lambda res, sid: results.append(res), seed=i)
        cluster.add_replica(r)
    return sim, cluster, results


def test_failure_detector_removes_dead_replica():
    sim, cluster, _ = _cluster()
    det = FailureDetector(cluster, timeout=1.0, max_misses=2)
    cluster.replicas["r1"].fail(0.0)
    det.poll(0.5)
    assert "r1" in cluster.replicas        # within timeout
    det.poll(2.0)
    det.poll(3.5)
    assert "r1" not in cluster.replicas
    assert det.removed == ["r1"]


def test_elastic_join_leave():
    sim, cluster, results = _cluster(2)
    pool = ElasticServingPool(cluster)
    cluster.dispatcher_for("m")
    newr = SimReplica("r9", "m", sim, lambda res, sid: None, seed=9)
    pool.join(newr, now=1.0)
    assert "r9" in cluster.replicas
    assert "r9" in cluster.dispatchers["m"].replicas
    pool.leave("r9", now=2.0)
    assert "r9" not in cluster.replicas
    assert "r9" not in cluster.dispatchers["m"].replicas


def test_straggler_watch_flags_outlier():
    w = StragglerWatch(threshold=2.0, window=16)
    for _ in range(10):
        for rid, lat in [("a", 1.0), ("b", 1.1), ("c", 0.9), ("d", 5.0)]:
            w.observe(rid, lat)
    assert w.stragglers() == ["d"]


def test_remove_replica_mid_session():
    """Losing a COMBINED replica must not wedge the FL session."""
    from repro.core.states import ReplicaState
    sim, cluster, _ = _cluster(4)
    for rid in cluster.replicas:
        cluster.states.transition(rid, ReplicaState.IDLE, 0.0)
    cluster.launcher.maybe_launch(0.0)
    assert cluster.launcher.sessions
    some = next(iter(cluster.launcher.sessions.values()))
    victim = some.session.members[0]
    cluster.remove_replica(victim, 1.0)
    assert victim not in cluster.replicas
    for a in cluster.launcher.sessions.values():
        assert victim not in a.session.members
